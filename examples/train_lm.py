"""End-to-end training example: train a (reduced) assigned architecture for a
few hundred steps on the synthetic LM pipeline with fault-tolerant
checkpointing, then kill/resume to demonstrate recovery.

Run:  python examples/train_lm.py [--arch gemma3-1b] [--steps 200]
"""
import argparse
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import train  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-0.5b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

ckpt = tempfile.mkdtemp(prefix="repro_train_")
try:
    half = args.steps // 2
    print(f"=== phase 1: train to step {half} (simulated preemption) ===")
    train(args.arch, steps=half, batch=8, seq=128, ckpt_dir=ckpt,
          ckpt_every=20)
    print("=== phase 2: 'restart' — auto-resume from the last atomic "
          "checkpoint ===")
    _, losses = train(args.arch, steps=args.steps, batch=8, seq=128,
                      ckpt_dir=ckpt, ckpt_every=20)
    print(f"final loss {losses[-1]:.4f} (started ~{losses[0]:.4f})")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
