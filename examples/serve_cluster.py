"""End-to-end serving example: three REAL model engines (reduced configs of
assigned architectures) as a cloud-edge continuum behind the QLMIO router,
with continuous batching, health tracking, hedged requests, and a mid-run
server failure that the router drains around.

Run:  python examples/serve_cluster.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.launch.serve import build_cluster  # noqa: E402
from repro.serving.router import QLMIORouter  # noqa: E402

servers = build_cluster()
speeds = np.array([s.speed for s in servers])
milp = lambda task, s: 8.0 / speeds[s]  # noqa: E731
mgqp = lambda task, s: [0.7, 0.85, 0.95][s]  # noqa: E731
router = QLMIORouter(list(servers), milp, mgqp, quality_weight=0.3)

print("phase 1: healthy cluster")
for task in range(8):
    rec = router.dispatch(task)
    print(f"  task {task} -> {servers[rec['server']].name} "
          f"lat={rec['latency']:.2f} ok={rec['ok']}")

print("phase 2: edge-1 dies mid-run")
servers[1].fail = True
for task in range(8, 20):
    rec = router.dispatch(task)
    mark = " <- failed box" if rec["server"] == 1 else ""
    print(f"  task {task} -> {servers[rec['server']].name} "
          f"ok={rec['ok']}{mark}")
counts = np.bincount([r["server"] for r in router.log],
                     minlength=len(servers))
fails_after = sum(1 for r in router.log[8:] if r["server"] == 1)
print(f"dispatch counts: {counts.tolist()}; "
      f"post-failure hits on dead box: {fails_after} "
      f"(<= health threshold {router.health.fail_threshold})")
assert fails_after <= router.health.fail_threshold
print("fault tolerance OK: traffic drained from the failed server")
