"""Quickstart: the full QLMIO pipeline in ~2 minutes on CPU.

1. Synthesize MIOBench (3,377 tasks x 3 server classes).
2. Compute frozen encoder features, train MGQP + MILP predictor heads.
3. Train the QLMIO D3QN offloading agent on CEMLLM-Sim.
4. Compare against All-Cloud / Greedy baselines on the test split.

Scale knobs at the top; the paper-scale run lives in benchmarks/.
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import baselines as B  # noqa: E402
from repro.core.d3qn import D3QNConfig  # noqa: E402
from repro.core.feature_store import compute_features  # noqa: E402
from repro.core.predictors import Predictor, PredictorConfig  # noqa: E402
from repro.core.qlmio import QLMIO, QLMIOConfig  # noqa: E402
from repro.data.taskgen import splits  # noqa: E402
from repro.sim.cemllm import make_servers  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate, summary  # noqa: E402

N_TASKS = 600          # full bench: 3377
ENCODER_PROFILE = "tiny"  # paper fidelity: "fast" or "paper"
EPISODES = 120         # paper: 12000
USERS = 15
SERVERS = 5

t0 = time.time()
bench = generate(seed=0, n_tasks=N_TASKS)
print("MIOBench:", {k: v for k, v in summary(bench).items()
                    if k in ("n_tasks", "n_records")})
tr, va, te = splits(bench.tasks.n)
f_img, f_text = compute_features(bench.tasks, profile=ENCODER_PROFILE,
                                 cache_dir=None)


def flat(ids):
    C = len(SERVER_CLASSES)
    t = np.repeat(ids, C)
    c = np.tile(np.arange(C), len(ids))
    return {"f_text": f_text[t], "f_img": f_img[t],
            "model_id": bench.model_id[c], "device_id": bench.device_id[c],
            "label": (bench.score[t, c] == 1).astype(np.int64),
            "latency_s": bench.latency_s[t, c].astype(np.float32)}


pc = PredictorConfig(epochs=10, batch=256)
milp = Predictor("latency", 8, 8, pc, feat_dim=f_text.shape[1])
h = milp.fit(flat(tr), flat(va))
print(f"[{time.time()-t0:.0f}s] MILP  val MAE  {h[-1]['val_mae_s']:.2f}s")
mgqp = Predictor("quality", 8, 8, pc, feat_dim=f_text.shape[1])
h = mgqp.fit(flat(tr), flat(va))
print(f"[{time.time()-t0:.0f}s] MGQP  val acc  {h[-1]['val_acc']:.3f}")

C = len(SERVER_CLASSES)
allb = {"f_text": np.repeat(f_text, C, 0), "f_img": np.repeat(f_img, C, 0),
        "model_id": np.tile(bench.model_id, bench.tasks.n),
        "device_id": np.tile(bench.device_id, bench.tasks.n)}
milp_preds = milp.predict(allb).reshape(-1, C)
mgqp_preds = mgqp.predict(allb).reshape(-1, C)

servers = make_servers(SERVERS, bench)
q = QLMIO(bench, servers, (f_img, f_text), milp_preds, mgqp_preds,
          QLMIOConfig(episodes=EPISODES, users=USERS, seed=0,
                      agent=D3QNConfig(eps_decay_steps=EPISODES * USERS // 2)))
q.train(tr, verbose=True, log_every=40)
res = q.evaluate(te, trials=10)
print(f"[{time.time()-t0:.0f}s] QLMIO  : {res}")
for name, r in B.evaluate_heuristics(bench, servers, te, USERS, 10).items():
    print(f"         {name:10s}: {r}")
