"""Cloud-edge continuum replay: QLMIO offloading over REAL ServingEngines.

Three live engines (paged KV + chunked prefill, reduced configs) form a
continuum — a jetson-class and a 3090-class edge running the small config,
a 5090-class cloud running the larger one — under a shared virtual clock.
A MIOBench arrival trace is replayed twice: all-cloud vs. the QLMIO
scoring policy.  Latency is measured from real token generation (virtual
seconds); quality comes from the success predictors.

Run:  python examples/serve_continuum.py
"""
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402

from repro.core.baselines import all_cloud_policy  # noqa: E402
from repro.serving.cluster import (  # noqa: E402
    Cluster,
    EngineBackend,
    build_continuum,
)
from repro.sim.cemllm import make_servers_from_spec, run_policy  # noqa: E402
from repro.sim.miobench import generate  # noqa: E402

SPEC = [(2, 1), (1, 1), (0, 1)]  # 1 cloud + 2 edge tiers

bench = generate(seed=0, n_tasks=200)
servers = make_servers_from_spec(SPEC, bench)
handles = build_continuum(SPEC, seed=0)
cluster = Cluster(handles)
rng = np.random.default_rng(0)
tasks = rng.choice(bench.tasks.n, 24, replace=False)

# QLMIO scoring policy over the idealized cost-model predictors
from benchmarks.fig10_continuum_replay import (  # noqa: E402
    analytic_predictors,
    qlmio_policy,
)

t_hat, b_hat = analytic_predictors(bench)

for name, policy in [("all_cloud", all_cloud_policy(servers)),
                     ("qlmio", qlmio_policy(t_hat, b_hat, servers, w=1.0))]:
    cluster.reset()
    backend = EngineBackend(cluster, bench, servers, arrival_dt=0.01)
    out = run_policy(policy, bench, servers, tasks,
                     np.random.default_rng(1), backend=backend)
    print(f"[{name}] mean e2e {out['avg_latency_s']:.3f}s  "
          f"ttft {out.get('avg_ttft_s', 0.0):.3f}s  "
          f"completion {out['completion_rate']:.2f}")
    for h in handles:
        st = h.engine.latency_stats()
        if st["n_requests"]:
            print(f"    {h.name}: {st['n_requests']} reqs, "
                  f"e2e p95 {st['e2e_p95_s']:.3f}s (virtual clock), "
                  f"ticks {h.engine.ticks}")

# the router's live-load probe: each handle reports its real congestion
print("live load probes (post-drain, all idle):")
for h in handles:
    print(f"    {h.name}: {h.load()}")

# the streaming front end: per-token delivery on the same virtual clock —
# tokens surface as they decode, TTFT is measured at the first streamed
# chunk instead of the drained response payload
from repro.serving.request import ContinuumRequest  # noqa: E402

cluster.reset()
prompt = rng.integers(1, handles[0].cfg.vocab, 16).astype(np.int32)
uid = cluster.submit(ContinuumRequest(tokens=prompt, max_new_tokens=6,
                                      task=0, server=1, stream=True))
print("streamed tokens:")
for ev in cluster.stream(until=30.0):
    print(f"    #{ev.index} tok={ev.token} t_user={ev.t_user:.4f}s"
          + ("  (first)" if ev.first else "")
          + ("  (final)" if ev.final else ""))
rec = [r for r in cluster.collect() if r["uid"] == uid][0]
print(f"    streamed ttft {rec['ttft_s']:.4f}s  e2e {rec['e2e_s']:.4f}s")
