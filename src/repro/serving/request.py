"""Typed submission API for the continuum serving stack.

``Cluster.submit`` accreted one positional/keyword argument per PR
(tokens, segments, media_delay_s, decode_server, ...).  This module is
the stable, typed replacement: a frozen ``ContinuumRequest`` carries
everything a request needs across the router -> cluster -> engine path,
and router decisions *annotate* the request (``with_plan``) instead of
re-threading positional args.  The legacy kwarg form still works through
a back-compat shim in ``Cluster.submit`` that builds one of these and
emits a ``DeprecationWarning``.

``StreamEvent`` is the unit of the streaming serving surface (saxml's
per-request stream-output queue, adapted to the virtual clock): the
engine emits one per decoded token, *as it decodes*, instead of holding
tokens until drain.  ``t_emit`` is on the engine's clock (virtual
seconds under the continuum harness); the cluster adds ``t_user`` — the
time the token chunk lands at the user after the streamed downlink
chunk priced by ``cost_model.stream_chunk_s``.

Deliberately light: imports nothing from the engine/cluster modules so
router-only and cost-model-only consumers can use the types without
pulling in model building.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["ContinuumRequest", "StreamEvent"]


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One streamed decode token.

    ``index`` is the token's 0-based position in the request's output —
    contiguous and in-order per request, *including across a mid-stream
    migration* (the resumed engine continues the count).  ``first`` marks
    the TTFT token, ``final`` the EOS/budget end of stream (the saxml
    ``None`` end-of-stream sentinel, carried in-band)."""
    uid: int
    index: int
    token: int
    t_emit: float  # engine clock (virtual seconds under the harness)
    first: bool
    final: bool
    # set by the cluster: t_emit + the streamed downlink chunk's link time
    t_user: float | None = None


@dataclasses.dataclass(frozen=True)
class ContinuumRequest:
    """Everything one request carries through the continuum.

    Frozen: the router returns an *annotated copy* (``with_plan``) rather
    than mutating shared state, so a request can be re-planned, hedged,
    or replayed without aliasing surprises.

    Fields mirror the legacy ``Cluster.submit`` kwargs one-to-one:

    * ``tokens`` / ``segments`` — the prompt: plain token ids, or typed
      modality spans (repro/serving/segments.py; ``tokens`` is then
      derived by the engine).
    * ``max_new_tokens`` — generation budget.
    * ``arrival_s`` — virtual arrival time at the user's device.
    * ``task`` / ``quality_ok`` — replay bookkeeping: MIOBench task id
      and the success-predictor verdict for the chosen server.
    * ``media`` / ``media_delay_s`` — the media spec
      (cost_model.MediaSpec) and the chosen split point's extra virtual
      seconds (edge-encode + serialization) charged before the uplink.
    * ``stream`` — per-token delivery: a callable receiving each
      ``StreamEvent``, or True to buffer events for ``Cluster.stream()``.
      None keeps the legacy drain-based collection.
    * ``extra`` — passed through to the engine (e.g. encoder_frames).

    Router/plan annotations (``with_plan`` fills these):

    * ``server`` — dispatch target (required by ``Cluster.submit``).
    * ``decode_server`` — disaggregated shape: prefill on ``server``,
      KV-migrate, decode there.
    * ``draft_server`` — speculative shape: ``server`` (or
      ``decode_server``) runs prefill + multi-token verify, while this
      server's device prices the ``spec_k`` draft steps per tick — the
      edge-drafts/cloud-verifies offloading mode (only token ids ride
      the uplink).  Equal to the decode server = colocated speculation.
    * ``predicted_s`` / ``utility`` — the router's predicted e2e seconds
      and Eq. 21 utility for the chosen shape (audit trail).
    """
    tokens: Any = None
    segments: "list | None" = None
    max_new_tokens: int = 32
    arrival_s: float = 0.0
    task: int = -1
    quality_ok: bool = True
    media: Any = None
    media_delay_s: float = 0.0
    stream: "Callable[[StreamEvent], None] | bool | None" = None
    extra: "dict | None" = None
    # --- router / plan annotations
    server: "int | None" = None
    decode_server: "int | None" = None
    draft_server: "int | None" = None
    predicted_s: "float | None" = None
    utility: "float | None" = None

    def with_plan(self, **changes) -> "ContinuumRequest":
        """Annotated copy — the router's way of recording its decision
        (``server=``, ``decode_server=``, ``predicted_s=``, ``utility=``)
        on the request itself."""
        return dataclasses.replace(self, **changes)
