"""Slot-based continuous-batching serving engine.

A fixed decode batch of ``max_batch`` slots steps in lockstep (one
``serve_step`` per tick).  Arriving requests are prefilled individually and
spliced into a free slot's cache region; finished slots are freed
immediately, so long requests never block short ones (continuous batching).

Works for every arch family — per-leaf cache batch dims are keyed by the
cache layout names in repro/models/api.py.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model

# batch-dim index per cache leaf name (see Model.abstract_cache layouts)
_BATCH_DIM = {"k": 1, "v": 1, "xk": 1, "xv": 1, "pos_map": 0,
              "conv": 2, "ssm": 2, "mconv": 2, "mC": 2, "mn": 2, "mm": 2,
              "sc": 1, "sn": 1, "sm": 1, "sh": 1}
# leaves whose (L, B, S, ...) seq dim must be grown to max_seq on insert
_SEQ_DIM = {"k": 2, "v": 2, "pos_map": 1}


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray  # prompt token ids
    max_new_tokens: int = 32
    extra: dict | None = None  # e.g. encoder_frames for whisper
    # filled during serving:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, eos_id: int | None = None,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int64)  # next position per slot
        self.budget = np.zeros(max_batch, np.int64)
        self.cache = self._empty_cache()
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.serve_step)
        self.ticks = 0
        self.finished: list[Request] = []

    # ----------------------------------------------------------- internals
    def _empty_cache(self):
        abstract = self.model.abstract_cache(self.max_batch, self.max_seq)
        return {k: jnp.zeros(v.shape, v.dtype) if k != "pos_map"
                else jnp.full(v.shape, -1, v.dtype)
                for k, v in abstract.items()}

    def _splice(self, slot: int, req_cache: dict, prompt_len: int):
        """Insert a single-request prefill cache into batch slot ``slot``."""
        new = {}
        for name, leaf in self.cache.items():
            rc = req_cache[name]
            bdim = _BATCH_DIM[name]
            if name in _SEQ_DIM:  # pad request cache S' -> max_seq
                sdim = _SEQ_DIM[name]
                pad = [(0, 0)] * rc.ndim
                pad[sdim] = (0, leaf.shape[sdim] - rc.shape[sdim])
                rc = jnp.pad(rc, pad, constant_values=(
                    -1 if name == "pos_map" else 0))
            idx = [slice(None)] * leaf.ndim
            idx[bdim] = slice(slot, slot + 1)
            new[name] = leaf.at[tuple(idx)].set(rc.astype(leaf.dtype))
        self.cache = new

    # ------------------------------------------------------------- public
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            toks = jnp.asarray(req.tokens, jnp.int32)[None]
            batch = {"tokens": toks, **(req.extra or {})}
            logits, rc = self._prefill(self.params, batch)
            first = int(jnp.argmax(logits[0]))
            self._splice(slot, rc, len(req.tokens))
            req.output.append(first)
            self.slots[slot] = req
            self.pos[slot] = len(req.tokens)
            self.budget[slot] = req.max_new_tokens - 1

    def step(self) -> int:
        """One engine tick: admit + one batched decode step.
        Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        tokens = np.zeros(self.max_batch, np.int32)
        for i in active:
            tokens[i] = self.slots[i].output[-1]
        logits, self.cache = self._step(
            self.params, self.cache,
            {"tokens": jnp.asarray(tokens),
             "pos": jnp.asarray(self.pos, jnp.int32)})
        nxt = np.asarray(jnp.argmax(logits, -1))
        self.ticks += 1
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.output.append(tok)
            self.pos[i] += 1
            self.budget[i] -= 1
            if (self.budget[i] <= 0 or tok == self.eos_id
                    or self.pos[i] >= self.max_seq - 1):
                req.done = True
                self.finished.append(req)
                self.slots[i] = None  # free the slot (continuous batching)
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000):
        while self.queue or any(s is not None for s in self.slots):
            self.step()
            if self.ticks > max_ticks:
                raise RuntimeError("engine did not drain")
        out, self.finished = self.finished, []
        return out
