"""Slot-based continuous-batching serving engine with a paged KV cache.

A fixed decode batch of ``max_batch`` slots steps in lockstep (one
``serve_step`` per tick).  Arriving requests are prefilled into a free slot;
finished slots are freed immediately, so long requests never block short
ones (continuous batching).

Two cache backends:

  * **paged** (default for the pure-attention family) — K/V live in a
    shared page pool (``repro/serving/kv_cache.py``); each slot holds a
    block table instead of a dense ``max_seq`` region, freed requests
    return their pages, and identical prompt prefixes across requests are
    served from the prefix trie without recomputation (copy-on-write).
    ``kv_dtype="int8"`` stores the pool quantized (symmetric per-row
    int8 + fp32 scales, ``repro/kernels/quant.py``): decode streams half
    the KV bytes per tick through the fused-dequant kernels, and a fixed
    ``kv_budget_bytes`` buys ~2x the pages — so admission control sees a
    doubled page budget on edge-sized devices.
  * **dense** — the original one-region-per-slot layout, still used for
    recurrent/hybrid/cross-attention cache families (zamba2, xlstm,
    whisper) whose state is not an append-only token sequence.

Decode-loop overhead: the jitted decode and chunked-prefill steps donate
their cache argument (``donate_argnums``), so XLA updates the pool
in-place instead of copying the full KV cache every tick, and the decode
step argmaxes on device — one ``[B]`` int32 token-id transfer per tick
instead of ``[B, vocab]`` logits (``return_logits=True`` restores the
logits for tests).

Prefill scheduling (attention family): prompts are **shape-bucketed** —
right-padded to power-of-two lengths with the true length threaded through
``Model.prefill``/``prefill_chunk_*`` — so a mixed-length workload traces
O(log max_seq) XLA variants instead of one per distinct prompt length, and
**chunked** — long prompts append into the cache ``prefill_chunk`` tokens
at a time under a per-tick ``prefill_budget``, sharing ticks with decode
steps so a long prompt no longer stalls every running decode for its whole
prefill (mixed prefill/decode continuous batching).  Recurrent/hybrid
families keep exact-shape monolithic prefill: their state integrates every
input token, so padding would corrupt it.

Multimodal requests (attention family): a ``Request`` may carry typed
``segments`` (repro/serving/segments.py) — text token spans interleaved
with precomputed embedding spans (image patches / audio frames from
repro/models/mm_encoder.py).  The engine books everything (lengths,
buckets, the prefix trie) against the per-position *key ids* (token ids /
negative content-digest ids), and hands the embedding rows + injection
mask to the prefill entry points, which embed-and-inject once at the
boundary (``lm.embed_inputs``).  Two requests carrying the same image hit
each other's prefix-cache blocks exactly like identical text would.

Disaggregated prefill/decode (paged path): a decoding request can be
checkpointed as a portable ``KVSnapshot`` (``export_kv``) or evacuated
between ticks (``evacuate``), and a snapshot-carrying request submitted
to another engine is admitted *straight into decode phase* — its pages
adopted into the local pool (converted to the local ``kv_dtype``), its
prompt blocks re-registered in the prefix trie, no prefill pass — and
resumes at exactly ``output[-1]``.  The continuum harness
(repro/serving/cluster.py) charges the transfer on the device link under
its virtual clock.

Works for every arch family — per-leaf cache batch dims are keyed by the
cache layout names in repro/models/api.py.

Observability (repro/serving/telemetry.py): every engine owns a
``MetricsRegistry`` (request/token counters, TTFT/ITL/e2e histograms,
KV-pool and XLA-trace views) — ``latency_stats()``/``stats()`` are thin
views over it.  Passing ``telemetry=`` additionally records request
lifecycle spans (submit→queue→prefill-chunk[i]→decode→finish) and
per-tick batch/KV-occupancy counter samples against the engine's clock,
exportable as Perfetto-loadable Chrome trace JSON
(``Telemetry.export``).  With ``telemetry=None`` (default) the decode
hot path performs no tracing work at all beyond plain counter adds.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant import dequantize_kv, quantize_kv
from repro.models.api import Model
from repro.serving import segments as sg
from repro.serving.kv_cache import (BlockPool, BlockTable, KVSnapshot,
                                    OutOfPagesError, ceil_blocks,
                                    full_blocks, kv_page_bytes)
from repro.serving.request import ContinuumRequest, StreamEvent
from repro.serving.telemetry import MetricsRegistry, latency_summary


def bucket_length(n: int, *, minimum: int = 16, maximum: int | None = None
                  ) -> int:
    """Smallest power-of-two >= n, clamped to [minimum, maximum].

    Prefill shapes are padded to these buckets so the number of distinct
    XLA traces is O(log max_seq) rather than one per prompt length.
    """
    if n < 1:
        raise ValueError(f"bucket_length needs n >= 1, got {n}")
    if maximum is not None and n > maximum:
        raise ValueError(f"bucket_length: n={n} exceeds maximum={maximum}")
    b = max(minimum, 1 << (n - 1).bit_length())
    return b if maximum is None else min(b, maximum)

# batch-dim index per cache leaf name (see Model.abstract_cache layouts)
_BATCH_DIM = {"k": 1, "v": 1, "xk": 1, "xv": 1, "pos_map": 0,
              "conv": 2, "ssm": 2, "mconv": 2, "mC": 2, "mn": 2, "mm": 2,
              "sc": 1, "sn": 1, "sm": 1, "sh": 1}
# leaves whose (L, B, S, ...) seq dim must be grown to max_seq on insert
_SEQ_DIM = {"k": 2, "v": 2, "pos_map": 1}


@dataclasses.dataclass
class Request:
    uid: int
    # prompt token ids; for a multimodal request (``segments`` given) this
    # is derived automatically: the per-position bookkeeping *key ids*
    # (text token ids, negative content-digest ids for embedding
    # positions — repro/serving/segments.py), which drive prompt length,
    # bucket shapes and the paged prefix-cache trie uniformly
    tokens: np.ndarray | None = None
    max_new_tokens: int = 32
    extra: dict | None = None  # e.g. encoder_frames for whisper
    # ordered modality spans (TextSegment / EmbedSegment); None = text-only
    segments: "list | None" = None
    # filled during serving:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_admit: float = 0.0  # when prefill work started (ends the queue span)
    token_times: list = dataclasses.field(default_factory=list)
    # derived for multimodal requests: [T, d] float32 embedding rows and
    # the [T] bool injection mask handed to the model entry points
    features: np.ndarray | None = dataclasses.field(default=None,
                                                    repr=False)
    embed_mask: np.ndarray | None = dataclasses.field(default=None,
                                                      repr=False)
    # checkpointed KV state from another engine (kv_cache.KVSnapshot): the
    # request is admitted straight into decode phase from these pages —
    # no prefill pass — resuming at exactly ``output[-1]``
    imported: "KVSnapshot | None" = dataclasses.field(default=None,
                                                      repr=False)
    # per-token delivery callback (StreamEvent per decoded token, emitted
    # inside step() as the token is sampled); None = drain-based only.
    # Survives evacuate/resubmit, so a mid-stream migration keeps
    # streaming to the same consumer with contiguous indices.
    stream: "Callable[[StreamEvent], None] | None" = \
        dataclasses.field(default=None, repr=False)
    # admission-group id under the saxml batching knobs (None = admitted
    # on the legacy unrestricted path); engine-internal
    group: "int | None" = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        if self.segments is None:
            return
        self.tokens = sg.key_ids(self.segments)
        media = sg.media_segments(self.segments)
        if media:
            d = np.asarray(media[0].features).shape[-1]
            self.features, self.embed_mask = sg.dense_features(
                self.segments, d)

    def ttft_s(self) -> float:
        """Time-to-first-token (prefill + queueing), on the engine clock."""
        return self.token_times[0] - self.t_submit

    def itl_s(self) -> list:
        """Inter-token latencies of the decode phase (engine clock)."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    def e2e_s(self) -> float:
        """Submit-to-last-token latency, on the engine clock."""
        return self.token_times[-1] - self.t_submit


@dataclasses.dataclass
class _PrefillTask:
    """In-flight chunked prefill of one slot (prompt partially in cache)."""
    req: Request
    done: int  # prompt tokens already in the cache (incl. prefix reuse)
    reused: int = 0  # prefix-cache tokens among ``done``
    logits: Any = None  # last chunk's next-token logits [1, V]


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, eos_id: int | None = None,
                 greedy: bool = True, paged: bool | None = None,
                 page_size: int = 16, num_pages: int | None = None,
                 kv_dtype: str = "bf16", kv_budget_bytes: int | None = None,
                 prefix_caching: bool = True, prefill_chunk: int = 64,
                 prefill_budget: int | None = None,
                 bucket_prompts: bool = True, min_bucket: int = 16,
                 return_logits: bool = False,
                 draft_config=None, draft_params=None, draft_seed: int = 0,
                 spec_k: int = 3,
                 sorted_batch_sizes: "list[int] | None" = None,
                 max_live_batches: "int | None" = None,
                 batching_wait_secs: float = 0.0,
                 clock: "Callable[[], float] | None" = None,
                 telemetry=None, trace_name: str = "engine",
                 mesh=None):
        """``prefill_chunk`` — tokens appended to the cache per chunked
        prefill call (0 disables chunking: one monolithic, still bucketed,
        prefill per admission).  ``prefill_budget`` — prefill tokens spent
        per engine tick before the decode step runs (default
        ``2 * prefill_chunk``); bounds how long any prompt can stall
        running decodes.  ``bucket_prompts`` — pad prompt (and chunk)
        shapes to power-of-two buckets >= ``min_bucket`` so XLA compiles
        O(log max_seq) prefill variants instead of one per prompt length.
        Both knobs apply to the attention family only; recurrent/hybrid
        caches always use exact-shape monolithic prefill.

        ``kv_dtype`` — precision of the paged KV pool: ``"bf16"``
        (default, token-identical to the historical engine) or ``"int8"``
        (quantized pages + fp32 scale rows, fused-dequant decode; paged
        backend only).  ``kv_budget_bytes`` — size the page pool to a
        device KV byte budget instead of the worst-case slot count: the
        pool gets ``budget // page_bytes()`` pages, so the same budget
        admits ~2x the pages under int8 (the admission-control headroom
        the continuum's edge tiers trade precision for).

        ``return_logits`` — the decode step normally argmaxes on device
        and returns ``[B]`` token ids (one int32 per slot per tick over
        the host link); True restores the full ``[B, vocab]`` logits
        transfer for tests/inspection.

        ``draft_config`` — an ``ArchConfig`` for a small draft model
        turns on **speculative decoding** (paged backend only): each
        tick the draft model proposes ``spec_k`` tokens per active slot
        (dense draft cache, one cheap decode step per proposal), the
        target model scores all of them in *one* multi-token verify pass
        (``Model.verify_step_paged`` over the Pallas paged-verify
        kernel, amortized across the batch), and the longest agreeing
        prefix plus the target's correction token is emitted — 1 to
        ``spec_k + 1`` tokens per slot per tick, **bit-identical** to
        plain greedy decode regardless of draft quality.  Rejected
        draft positions keep their scattered K/V: they sit past the
        accepted position, every causal read masks them, and the next
        tick overwrites them — rollback is positional, never a page
        copy.  ``draft_params`` supplies the draft weights (default: a
        fresh init from ``draft_seed``).  The draft model must be
        attention-family with the same vocab as the target.

        ``sorted_batch_sizes`` / ``max_live_batches`` /
        ``batching_wait_secs`` — saxml-style admission batching (the
        ``ServableMethod`` knobs).  None (default) keeps the legacy
        per-request admission.  With a sorted list of allowed admission
        batch sizes, queued requests are admitted in *groups*: as soon
        as the queue can fill the largest bucket ``<= len(queue)``, that
        many are admitted together; a partial group is only released
        once the oldest queued request has waited ``batching_wait_secs``
        on the engine clock (so admission delay is bounded), and is
        padded *conceptually* to the smallest bucket ``>= count`` (the
        group never exceeds its bucket).  ``max_live_batches`` caps how
        many admitted groups may be in flight (prefilling or decoding)
        at once; further admission holds until a group fully finishes.

        ``clock`` — time source for request timestamps (``t_submit`` /
        ``token_times``).  Default is ``time.perf_counter`` (wall clock); an
        external driver stepping this engine tick-by-tick (the cloud-edge
        continuum harness, repro/serving/cluster.py) passes its virtual
        clock instead, so ``latency_stats()`` reports TTFT/ITL/e2e in
        virtual-clock seconds rather than host wall time.

        ``mesh`` — a ``jax.sharding.Mesh`` with a ``model`` axis
        (``repro.distributed.tp.serving_mesh``) turns on tensor-parallel
        serving: weights and the paged KV pool are sharded across the
        mesh (``distributed/tp.ShardedServing``) and every hot jitted
        step runs under ``shard_map``.  Paged backend only.  Host-side
        page bookkeeping (CoW, scatters, snapshot export/import) indexes
        the unsharded page axis, so prefix caching, migration and
        speculative decoding all work unchanged; the draft model stays
        unsharded (draft/verify traffic crosses the host anyway).

        ``telemetry`` — optional ``repro.serving.telemetry.Telemetry``.
        When given (and its tracer enabled), the engine records request
        lifecycle spans and per-tick occupancy counter samples against its
        clock under process ``trace_name``, and registers its metrics
        registry for export.  ``None`` keeps tracing fully off: the hot
        path does a single ``is None`` check and no event allocation.
        """
        self.model = model
        self.params = params
        self._now = clock if clock is not None else time.perf_counter
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int64)  # next position per slot
        self.budget = np.zeros(max_batch, np.int64)
        self.paged = model.supports_paged if paged is None else paged
        if self.paged and not model.supports_paged:
            raise ValueError(
                f"{model.cfg.name}: paged serving needs an attention-family "
                "cache; use paged=False")
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(
                f"kv_dtype must be 'bf16' or 'int8', got {kv_dtype!r}")
        if kv_dtype != "bf16" and not self.paged:
            raise ValueError(
                "kv_dtype='int8' needs the paged cache backend (dense/"
                "recurrent caches stay bf16)")
        self.kv_dtype = kv_dtype
        # ---- tensor-parallel serving (mesh= -> shard_map'd jit surface)
        self.mesh = mesh
        if mesh is not None:
            if not self.paged:
                raise ValueError(
                    "mesh= (tensor-parallel serving) needs the paged cache "
                    "backend; use paged=True")
            from repro.distributed.tp import ShardedServing
            self._tp = ShardedServing(model, mesh)
            self.params = self._tp.shard_params(params)
        else:
            self._tp = None
        serving = self._tp if self._tp is not None else model
        self.return_logits = return_logits
        self.bucketing = bucket_prompts and model.supports_bucketed_prefill
        self.chunked = prefill_chunk > 0 and model.supports_chunked_prefill
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = (prefill_budget if prefill_budget is not None
                               else 2 * max(prefill_chunk, 1))
        self.min_bucket = min_bucket
        self.prefill_tasks: list[_PrefillTask | None] = [None] * max_batch
        # ---- saxml-style admission batching (None = legacy per-request)
        if sorted_batch_sizes is not None:
            sizes = sorted(set(int(b) for b in sorted_batch_sizes))
            if not sizes or sizes[0] < 1:
                raise ValueError("sorted_batch_sizes needs sizes >= 1, got "
                                 f"{sorted_batch_sizes!r}")
            if sizes[-1] > max_batch:
                raise ValueError(
                    f"sorted_batch_sizes max {sizes[-1]} exceeds "
                    f"max_batch={max_batch}")
            sorted_batch_sizes = sizes
        self.sorted_batch_sizes = sorted_batch_sizes
        self.max_live_batches = max_live_batches
        self.batching_wait_secs = float(batching_wait_secs)
        self._group_left: dict[int, int] = {}  # group id -> unfinished
        self._next_group = 0
        self._admit_quota: "int | None" = None  # per-tick, set in step()
        self._cur_group: "int | None" = None
        self._admission_held = False  # tick ended with queue held back
        self._traced: set = set()  # distinct prefill-path trace shapes
        self._prefill = jax.jit(serving.prefill)
        # ---- metrics registry: counters the hot paths increment directly
        # (bound attributes, no dict lookups), everything else views/hists.
        # latency_stats()/stats() are thin views over this registry.
        self.telemetry = telemetry
        self.metrics = m = MetricsRegistry()
        self._c_prefill_computed = m.counter("prefill_tokens_computed")
        self._c_prefill_padded = m.counter("prefill_tokens_padded")
        self._c_prefix_reused = m.counter("prefix_tokens_reused")
        self._c_submitted = m.counter("requests_submitted")
        self._c_finished = m.counter("requests_finished")
        self._c_decode_tokens = m.counter("decode_tokens")
        # KV snapshot traffic (disaggregated prefill/decode): pages and
        # bytes exported to / imported from other engines, at this
        # engine's own page precision
        self._c_kv_exported_pages = m.counter("kv_exported_pages")
        self._c_kv_imported_pages = m.counter("kv_imported_pages")
        self._c_kv_export_bytes = m.counter("kv_export_bytes")
        self._c_kv_import_bytes = m.counter("kv_import_bytes")
        # new XLA traces since the last metrics.reset() — the steady-state
        # recompile guard asserts this stays 0 on a warmed engine
        self._c_trace_events = m.counter("xla_trace_events")
        self._h_ttft = m.histogram("ttft_s")
        self._h_itl = m.histogram("itl_s")
        self._h_e2e = m.histogram("e2e_s")
        self._h_queue = m.histogram("queue_s")
        # fraction of the per-tick prefill token budget actually spent
        # (can slightly exceed 1.0: chunks are charged at bucket size);
        # observed only on ticks that did prefill work, telemetry only
        self._h_budget_util = m.histogram("prefill_budget_util")
        # admission-group sizes under the saxml batching knobs, and the
        # streamed-token counter (0 for drain-only workloads)
        self._h_admit_size = m.histogram("batch_admit_size")
        self._c_stream_tokens = m.counter("stream_tokens")
        # speculative decoding: drafted = spec_k per active slot per tick;
        # accepted = drafts consumed into the output stream; wasted =
        # drafted - accepted (verify compute spent on rejected tokens).
        # acceptance_rate() and the router's spec-shape pricing read these.
        self._c_spec_drafted = m.counter("spec_tokens_drafted")
        self._c_spec_accepted = m.counter("spec_tokens_accepted")
        self._c_spec_wasted = m.counter("spec_tokens_wasted")
        self._g_accept_rate = m.gauge("spec_acceptance_rate")
        self._g_queue_depth = m.gauge("queue_depth")
        m.view("ticks", lambda: self.ticks)
        m.view("kv_cache_bytes", self.kv_cache_bytes)
        m.view("prefill_trace_count", self.prefill_trace_count)
        tr = telemetry.tracer if telemetry is not None else None
        self._tr = tr if (tr is not None and tr.enabled) else None
        self._pid = self._tr.process(trace_name) if self._tr else 0
        if telemetry is not None:
            telemetry.register_metrics(trace_name, m)
        if self.paged:
            self.page_size = page_size
            self.max_blocks = ceil_blocks(max_seq, page_size)
            if num_pages is None:
                if kv_budget_bytes is not None:
                    # device KV byte budget -> page count at this
                    # precision: int8 pages are ~half the bytes, so the
                    # same budget admits ~2x the pages
                    num_pages = max(2, 1 + kv_budget_bytes
                                    // self.page_bytes())
                else:
                    # worst case (== dense capacity): admission/decode can
                    # never run out; size smaller to trade safety for
                    # memory
                    num_pages = 1 + max_batch * self.max_blocks
            self.prefix_caching = prefix_caching
            self.pool = BlockPool(num_pages, page_size)
            # pool occupancy/hit/eviction/CoW stats as live registry views
            # (survive reset_prefix_cache swapping the pool object)
            for key in ("num_pages", "block_size", "pages_in_use",
                        "pages_cached", "prefix_hits", "prefix_misses",
                        "evictions", "cow_copies"):
                m.view(key, lambda k=key: self.pool.stats()[k])
            abstract = model.abstract_paged_cache(num_pages, page_size,
                                                  kv_dtype=kv_dtype)
            self.cache = {name: jnp.zeros(s.shape, s.dtype)
                          for name, s in abstract.items()}
            if self._tp is not None:
                shardings = self._tp.cache_shardings(abstract)
                self.cache = {name: jax.device_put(leaf, shardings[name])
                              for name, leaf in self.cache.items()}
            self.tables = np.full((max_batch, self.max_blocks), -1, np.int32)
            self.block_tables: list[BlockTable | None] = [None] * max_batch
            self._step = self._make_step(serving.serve_step_paged)
            self._prefill_sfx = jax.jit(serving.prefill_with_prefix)
            self._prefill_chunk = jax.jit(serving.prefill_chunk_paged,
                                          donate_argnums=(1,))
        else:
            self.cache = self._empty_cache()
            self._step = self._make_step(model.serve_step)
            if self.chunked:
                self._prefill_chunk = jax.jit(model.prefill_chunk_dense,
                                              donate_argnums=(1,))
        # ---- speculative decoding (draft model + multi-token verify)
        self.spec_k = int(spec_k)
        self.speculative = draft_config is not None
        if self.speculative:
            if not self.paged:
                raise ValueError(
                    "speculative decoding needs the paged cache backend "
                    "(the verify pass writes draft K/V through block "
                    "tables); use paged=True")
            if self.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            self.draft_model = Model(draft_config)
            if not self.draft_model.supports_paged:
                raise ValueError(
                    f"{draft_config.name}: the draft model must be "
                    "attention-family (dense-cache decode)")
            if draft_config.vocab != model.cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_config.vocab} != target vocab "
                    f"{model.cfg.vocab}: token-level rejection sampling "
                    "needs a shared vocabulary")
            self.draft_params = (draft_params if draft_params is not None
                                 else self.draft_model.init(
                                     jax.random.PRNGKey(int(draft_seed))))
            # the draft runs a plain dense cache: its KV is tiny, it never
            # shares pages, and stale entries past a rejection are masked
            # by position then overwritten by the next draft chain
            dab = self.draft_model.abstract_cache(max_batch, max_seq)
            self._draft_cache = {
                k: (jnp.full(v.shape, -1, v.dtype) if k == "pos_map"
                    else jnp.zeros(v.shape, v.dtype))
                for k, v in dab.items()}
            self._draft_prefill = jax.jit(self.draft_model.prefill)

            def _dstep(params, cache, batch,
                       _base=self.draft_model.serve_step):
                logits, cache = _base(params, cache, batch)
                return jnp.argmax(logits, -1).astype(jnp.int32), cache

            def _vstep(params, cache, batch,
                       _base=serving.verify_step_paged):
                logits, cache = _base(params, cache, batch)
                return jnp.argmax(logits, -1).astype(jnp.int32), cache

            self._draft_step = jax.jit(_dstep, donate_argnums=(1,))
            self._verify_step = jax.jit(_vstep, donate_argnums=(1,))
        self.ticks = 0
        self._progress = False
        self.finished: list[Request] = []
        # engine-assigned uids for ContinuumRequest submissions (cluster
        # submissions carry their own positive uids; legacy sync-execute
        # requests use small negatives — this range collides with neither)
        self._auto_uid = 1_000_000_000

    def _make_step(self, base_step):
        """Jit the per-tick decode step with the two per-tick-overhead
        fixes: the cache pytree is donated (``donate_argnums``) so XLA
        reuses its buffers instead of materializing a full KV-cache copy
        every tick, and — unless ``return_logits`` — the greedy argmax
        runs on device so only ``[B]`` int32 token ids cross the host
        link instead of ``[B, vocab]`` logits."""
        if self.return_logits:
            return jax.jit(base_step, donate_argnums=(1,))

        def step_fn(params, cache, batch):
            logits, cache = base_step(params, cache, batch)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        return jax.jit(step_fn, donate_argnums=(1,))

    def page_bytes(self) -> int:
        """Bytes one page pool entry costs across all layers (K+V values
        plus int8 scale rows) — the ``kv_budget_bytes`` unit."""
        cfg = self.model.cfg
        return kv_page_bytes(cfg.n_layers, cfg.n_kv_heads, cfg.hd,
                             self.page_size, self.kv_dtype)

    # ----------------------------------------------------- dense internals
    def _empty_cache(self):
        abstract = self.model.abstract_cache(self.max_batch, self.max_seq)
        return {k: jnp.zeros(v.shape, v.dtype) if k != "pos_map"
                else jnp.full(v.shape, -1, v.dtype)
                for k, v in abstract.items()}

    def _splice(self, slot: int, req_cache: dict, prompt_len: int):
        """Insert a single-request prefill cache into batch slot ``slot``."""
        self.cache = self._splice_cache(self.cache, slot, req_cache)

    @staticmethod
    def _splice_cache(cache: dict, slot: int, req_cache: dict) -> dict:
        """Insert a single-request prefill cache into slot ``slot`` of a
        dense batch cache (the engine's own, or the draft model's)."""
        new = {}
        for name, leaf in cache.items():
            rc = req_cache[name]
            bdim = _BATCH_DIM[name]
            if name in _SEQ_DIM:  # pad request cache S' -> max_seq
                sdim = _SEQ_DIM[name]
                pad = [(0, 0)] * rc.ndim
                pad[sdim] = (0, leaf.shape[sdim] - rc.shape[sdim])
                rc = jnp.pad(rc, pad, constant_values=(
                    -1 if name == "pos_map" else 0))
            idx = [slice(None)] * leaf.ndim
            idx[bdim] = slice(slot, slot + 1)
            new[name] = leaf.at[tuple(idx)].set(rc.astype(leaf.dtype))
        return new

    def _bucket(self, n: int, *, cap: int | None = None) -> int:
        if not self.bucketing:
            return n
        return bucket_length(n, minimum=self.min_bucket,
                             maximum=self.max_seq if cap is None else cap)

    def _padded_prompt(self, toks: np.ndarray, n_pad: int) -> jnp.ndarray:
        out = np.zeros(n_pad, np.int32)
        # clamp: embedding positions carry negative int64 key ids for the
        # prefix trie; the model reads their rows from ``embeds`` instead
        out[:len(toks)] = np.maximum(toks, 0)
        return jnp.asarray(out)[None]

    def _padded_embeds(self, feats: np.ndarray, mask: np.ndarray,
                       n_pad: int):
        """Right-pad a request's embedding rows + mask to the shape bucket
        (zeros / False: padded positions are already masked everywhere)."""
        f = np.zeros((n_pad, feats.shape[1]), np.float32)
        f[:len(feats)] = feats
        m = np.zeros(n_pad, bool)
        m[:len(mask)] = mask
        return jnp.asarray(f)[None], jnp.asarray(m)[None]

    def _with_embeds(self, batch: dict, req: Request, start: int, stop: int,
                     n_pad: int) -> bool:
        """Attach the ``[start, stop)`` slice of a multimodal request's
        embedding rows to a prefill batch; returns whether it did (the
        flag keys the extra XLA trace variant).  A slice with no
        embedding positions — a pure-text chunk past the media span, or a
        suffix whose prefix hit covered the media — stays on the plain
        token trace."""
        if req.features is None or not req.embed_mask[start:stop].any():
            return False
        e, m = self._padded_embeds(req.features[start:stop],
                                   req.embed_mask[start:stop], n_pad)
        batch["embeds"], batch["embed_mask"] = e, m
        return True

    def _note_trace(self, key: tuple):
        """Book a prefill-path shape about to be handed to XLA.  First
        sightings bump the ``xla_trace_events`` counter — the signal the
        steady-state recompile guard gates on (``metrics.reset()`` zeroes
        the counter but never ``self._traced``, matching XLA's persistent
        compile cache)."""
        if key not in self._traced:
            self._traced.add(key)
            self._c_trace_events.inc()

    def _admit_dense(self, slot: int, req: Request) -> "int | None":
        """Monolithic (bucketed) prefill into a dense slot; returns the
        first sampled token."""
        req.t_admit = self._now()
        T = len(req.tokens)
        Sb = self._bucket(T)
        batch = {"tokens": self._padded_prompt(req.tokens, Sb),
                 **(req.extra or {})}
        if self.bucketing:
            batch["length"] = jnp.asarray([T], jnp.int32)
        mm = self._with_embeds(batch, req, 0, T, Sb)
        self._note_trace(("prefill", Sb, mm))
        logits, rc = self._prefill(self.params, batch)
        self._splice(slot, rc, T)
        self._c_prefill_computed.inc(T)
        self._c_prefill_padded.inc(Sb - T)
        return int(jnp.argmax(logits[0]))

    # ----------------------------------------------------- paged internals
    def _cow_page(self, table: BlockTable, blk: int):
        """Make ``table.pages[blk]`` privately writable, copying if shared.
        Every cache leaf is indexed by page id on axis 1 — int8 scale
        tensors included — so the copy moves values and scales together."""
        old = table.pages[blk]
        new, copied = self.pool.ensure_writable(old)
        if copied:
            for name, leaf in self.cache.items():
                self.cache[name] = leaf.at[:, new].set(leaf[:, old])
            self.pool.release(old)
            table.pages[blk] = new

    def _total_blocks(self, req: Request) -> int:
        """Worst-case pages this request can ever hold (prompt + decode;
        speculation adds ``spec_k`` scratch positions so the verify pass
        can always scatter its draft K/V one tick ahead of acceptance)."""
        horizon = len(req.tokens) + req.max_new_tokens
        if self.speculative:
            horizon += self.spec_k
        horizon = min(horizon, self.max_seq)
        return ceil_blocks(horizon, self.page_size)

    def _growth_outstanding(self) -> int:
        """Pages occupied slots may still allocate: decode growth of active
        requests plus the full remaining horizon of mid-chunked-prefill
        slots (their tables hold prompt pages only so far) — admission must
        count both or a promoted request's decode-time ensure_capacity can
        hit an exhausted pool."""
        out = sum(self._total_blocks(r) - len(self.block_tables[i].pages)
                  for i, r in enumerate(self.slots) if r is not None)
        out += sum(self._total_blocks(t.req)
                   - len(self.block_tables[i].pages)
                   for i, t in enumerate(self.prefill_tasks)
                   if t is not None)
        return out

    def _clip_reuse(self, n_reuse: int) -> int:
        """Bound the prefill_with_prefix trace variants on the monolithic
        path: the reused prefix length is a shape dim of that call, so round
        it down to a power-of-two number of pages — O(log max_seq) prefix
        shapes instead of one per distinct hit length.  The chunked path
        has no shape dependence on the reuse length and keeps every token.
        """
        if self.chunked or not self.bucketing or n_reuse <= 0:
            return n_reuse
        blocks = n_reuse // self.page_size
        if blocks == 0:
            return 0
        return (1 << (blocks.bit_length() - 1)) * self.page_size

    def _reserve_table(self, req: Request) -> "tuple[BlockTable, int] | None":
        """Admission control + page reservation for a paged request.

        Returns ``(table, n_reuse)`` with the prefix-hit pages retained and
        capacity for the whole prompt allocated, or None (request must wait)
        when the pool cannot cover this request's worst case on top of every
        active slot's remaining decode growth — so mid-stream page
        allocation can never fail.  Uses the side-effect-free peek first so
        queued retries don't inflate hit stats or churn the LRU.  ``need``
        counts every page this admission removes from the allocatable
        supply: fresh allocations, plus hit pages currently parked in the
        LRU (retaining those shrinks ``num_free`` even though they need no
        allocation), plus the copy-on-write page of a fully-cached prompt.
        """
        toks = np.asarray(req.tokens, np.int64)
        T = len(toks)
        bs = self.page_size
        hit_pages = self.pool.peek_prefix(toks) if self.prefix_caching \
            else []
        est = self._clip_reuse(min(len(hit_pages) * bs, T - 1))
        used = hit_pages[:ceil_blocks(est, bs)] if est else []
        need = self._total_blocks(req) - len(used)
        need += sum(1 for p in used if self.pool.ref[p] == 0)
        if est and est % bs:
            need += 1  # fully-cached prompt: copy-on-write of the last page
        if self.pool.num_free() - self._growth_outstanding() < need:
            return None
        table = BlockTable(self.pool)
        n_reuse = 0
        if self.prefix_caching:
            table.pages, n_hit = self.pool.lookup_prefix(toks)
            # a fully-cached prompt still needs its last token recomputed
            # for the next-token logits -> copy-on-write on the final page
            n_reuse = self._clip_reuse(min(n_hit, T - 1))
            keep = ceil_blocks(n_reuse, bs)
            for p in table.pages[keep:]:  # rounded-off / unused hit pages
                self.pool.release(p)
            table.pages = table.pages[:keep]
        try:
            first_blk = n_reuse // bs
            if n_reuse and first_blk < len(table.pages):
                self._cow_page(table, first_blk)
            table.ensure_capacity(T)
        except OutOfPagesError:  # admission control should prevent this
            table.free()
            return None
        return table, n_reuse

    def _scatter_kv(self, table: BlockTable, positions: np.ndarray, sk, sv,
                    n: int):
        """Scatter ``n`` computed K/V columns ([L, 1, >=n, Hkv, Dh]) into
        the request's pages at the given logical positions.  The int8
        pool is write-then-quantize: monolithic prefill computes exact
        bf16 K/V, rows are quantized here and their scales scattered at
        the same (page, offset) indices."""
        pages, offs = table.rows_for(positions)
        if self.kv_dtype == "int8":
            for vname, sname, leaves in (("k_pages", "k_scales", sk),
                                         ("v_pages", "v_scales", sv)):
                rows, scales = quantize_kv(leaves[:, 0, :n])  # [L,n,Hkv,*]
                self.cache[vname] = \
                    self.cache[vname].at[:, pages, offs].set(rows)
                self.cache[sname] = \
                    self.cache[sname].at[:, pages, offs].set(scales)
            return
        for name, leaves in (("k_pages", sk), ("v_pages", sv)):
            leaf = self.cache[name]
            self.cache[name] = leaf.at[:, pages, offs].set(
                leaves[:, 0, :n].astype(leaf.dtype))

    def _admit_paged(self, slot: int, req: Request) -> "int | None":
        """Monolithic (bucketed) paged prefill; returns the first sampled
        token, or None when the pool cannot admit the request yet."""
        reserved = self._reserve_table(req)
        if reserved is None:
            return None
        req.t_admit = self._now()
        table, n_reuse = reserved
        toks = np.asarray(req.tokens, np.int64)
        T = len(toks)
        n_sfx = T - n_reuse
        Sb = self._bucket(n_sfx)
        if n_reuse == 0:
            batch = {"tokens": self._padded_prompt(toks, Sb),
                     **(req.extra or {})}
            if self.bucketing:
                batch["length"] = jnp.asarray([T], jnp.int32)
            mm = self._with_embeds(batch, req, 0, T, Sb)
            self._note_trace(("prefill", Sb, mm))
            logits, rc = self._prefill(self.params, batch)
            sk, sv = rc["k"], rc["v"]  # [L, 1, Sb, Hkv, Dh]
        else:
            kp, vp = self.cache["k_pages"], self.cache["v_pages"]
            pre = np.asarray(table.pages, np.int32)
            L, _, _, Hkv, Dh = kp.shape
            if self.kv_dtype == "int8":
                # suffix prefill attends the cached prefix dequantized —
                # the same values decode reads through the fused kernels
                kg = dequantize_kv(kp[:, pre], self.cache["k_scales"][:, pre],
                                   dtype=jnp.bfloat16)
                vg = dequantize_kv(vp[:, pre], self.cache["v_scales"][:, pre],
                                   dtype=jnp.bfloat16)
            else:
                kg, vg = kp[:, pre], vp[:, pre]
            pk = kg.reshape(L, -1, Hkv, Dh)[:, :n_reuse][:, None]
            pv = vg.reshape(L, -1, Hkv, Dh)[:, :n_reuse][:, None]
            batch = {"tokens": self._padded_prompt(toks[n_reuse:], Sb)}
            if self.bucketing:
                batch["length"] = jnp.asarray([n_sfx], jnp.int32)
            mm = self._with_embeds(batch, req, n_reuse, T, Sb)
            self._note_trace(("prefill_sfx", n_reuse, Sb, mm))
            logits, (sk, sv) = self._prefill_sfx(self.params, batch, pk, pv)
        self._scatter_kv(table, np.arange(n_reuse, T), sk, sv, n_sfx)
        if self.prefix_caching:
            self.pool.register_prefix(
                toks, table.pages[:full_blocks(T, self.page_size)])
        self._c_prefill_computed.inc(n_sfx)
        self._c_prefill_padded.inc(Sb - n_sfx)
        self._c_prefix_reused.inc(n_reuse)
        self.block_tables[slot] = table
        self.tables[slot] = table.as_row(self.max_blocks)
        return int(jnp.argmax(logits[0]))

    def _free_slot(self, slot: int):
        self.slots[slot] = None
        if self.paged:
            self.block_tables[slot].free()
            self.block_tables[slot] = None
            self.tables[slot] = -1
            self.pos[slot] = 0

    # ------------------- KV snapshot export / import (disaggregation)
    def slot_of_request(self, uid: int) -> "int | None":
        """Decode slot currently holding request ``uid``, or None.  A
        request mid-chunked-prefill is *not* found (``slots[slot]`` stays
        None until promotion), so a hit means the request is exportable."""
        for i, r in enumerate(self.slots):
            if r is not None and r.uid == uid:
                return i
        return None

    def export_kv(self, uid: int) -> KVSnapshot:
        """Checkpoint a decoding request's KV state as a portable
        ``KVSnapshot`` (host-side copy; the request keeps running here).

        The snapshot covers every cache position written so far — the
        prompt plus the generated tokens already fed back through the
        model, i.e. positions ``[0, pos)`` — and records the prompt's
        prefix-trie chain hashes so the importer can re-register (or
        dedupe against) the receiving pool's trie.  Page refcounts are
        held across the device->host copy, so a concurrent eviction on
        this engine cannot recycle a page mid-export."""
        if not self.paged:
            raise ValueError("export_kv needs the paged cache backend")
        slot = self.slot_of_request(uid)
        if slot is None:
            raise ValueError(
                f"request {uid} is not in decode phase on this engine "
                "(queued, mid-prefill, or finished)")
        req = self.slots[slot]
        bs = self.page_size
        n_ctx = int(self.pos[slot])
        pages = list(self.block_tables[slot].pages[:ceil_blocks(n_ctx, bs)])
        for p in pages:
            self.pool.retain(p)
        try:
            leaves = self.model.export_paged_kv(self.cache, pages)
        finally:
            for p in pages:
                self.pool.release(p)
        toks = np.asarray(req.tokens, np.int64)
        n_out = n_ctx - len(toks)
        tokens = np.concatenate(
            [toks, np.asarray(req.output[:n_out], np.int64)])
        snap = KVSnapshot(tokens=tokens, n_prompt=len(toks), block_size=bs,
                          kv_dtype=self.kv_dtype,
                          geometry=self.model.kv_geometry, leaves=leaves,
                          prefix_hashes=BlockPool.chain_hashes(toks, bs),
                          src_pages=pages)
        self._c_kv_exported_pages.inc(len(pages))
        self._c_kv_export_bytes.inc(len(pages) * self.page_bytes())
        return snap

    def evacuate(self, uid: int) -> "tuple[Request, KVSnapshot]":
        """Checkpoint a decoding request and remove it from this engine,
        freeing its slot and pages.  The returned ``Request`` carries the
        snapshot in ``req.imported`` and can be submitted to another
        (KV-compatible) engine, which resumes decode at exactly
        ``output[-1]`` — no tokens are lost or recomputed.  The request
        is *not* added to ``finished``; the caller owns it."""
        snap = self.export_kv(uid)
        slot = self.slot_of_request(uid)
        req = self.slots[slot]
        req.imported = snap
        self._release_group(req)  # it will not finish on this engine
        self._free_slot(slot)
        return req, snap

    def _admit_imported(self, slot: int, req: Request) -> bool:
        """Admit a snapshot-carrying request straight into decode phase:
        adopt its pages into this pool (prefix-trie hits satisfied from
        local cache, the rest imported and converted to this engine's
        ``kv_dtype``) and install the slot at the snapshot's position —
        no prefill pass.  False => pool cannot cover it yet (caller
        requeues).

        CoW safety: decode writes land at logical block
        ``pos // page_size`` with ``pos >= num_tokens >= n_prompt``, i.e.
        strictly past every block this method registers in the trie — so
        adopted/registered pages are never written and need no
        copy-on-write here."""
        snap = req.imported
        n_ctx = snap.num_tokens
        nb = snap.num_pages
        hits = (self.pool.peek_hashes(snap.prefix_hashes)
                if self.prefix_caching else [])
        need = self._total_blocks(req) - len(hits)
        need += sum(1 for p in hits if self.pool.ref[p] == 0)
        if self.pool.num_free() - self._growth_outstanding() < need:
            return False
        table = BlockTable(self.pool)
        if self.prefix_caching:
            table.pages = self.pool.lookup_hashes(snap.prefix_hashes)
        n_hit = len(table.pages)
        try:
            table.ensure_capacity(n_ctx)
        except OutOfPagesError:  # admission control should prevent this
            table.free()
            return False
        if n_hit < nb:
            self.cache = self.model.import_paged_kv(
                self.cache, table.pages[n_hit:nb], snap.leaves,
                snap.kv_dtype, from_block=n_hit)
        if self.prefix_caching:
            self.pool.register_blocks(
                snap.prefix_hashes, table.pages[:len(snap.prefix_hashes)])
        self.block_tables[slot] = table
        self.tables[slot] = table.as_row(self.max_blocks)
        self.slots[slot] = req
        self.pos[slot] = n_ctx
        self.budget[slot] = req.max_new_tokens - len(req.output)
        req.t_admit = self._now()
        self._c_kv_imported_pages.inc(nb - n_hit)
        self._c_kv_import_bytes.inc((nb - n_hit) * self.page_bytes())
        self._c_prefix_reused.inc(n_hit * self.page_size)
        if self.speculative:
            # the snapshot carries no draft-model state: rebuild it by
            # draft-prefilling the context (prompt + emitted tokens)
            self._draft_install(slot, snap.tokens)
        self._progress = True
        return True

    # -------------------------------------------------- chunked prefill
    def _start_prefill(self, slot: int, req: Request) -> bool:
        """Begin a chunked prefill in ``slot``; False => requeued (paged
        pool cannot cover the request yet)."""
        if req.imported is not None:
            if not self._admit_imported(slot, req):
                self.queue.appendleft(req)
                return False
            return True
        if self.paged:
            reserved = self._reserve_table(req)
            if reserved is None:
                self.queue.appendleft(req)
                return False
            table, n_reuse = reserved
            self.block_tables[slot] = table
            self.tables[slot] = table.as_row(self.max_blocks)
            self._c_prefix_reused.inc(n_reuse)
        else:
            n_reuse = 0
            # chunk writes no longer overwrite the whole slot region, so
            # stale pos_map entries from the previous occupant must be
            # cleared up front (stale K/V is then masked everywhere)
            self.cache["pos_map"] = self.cache["pos_map"].at[slot].set(-1)
        req.t_admit = self._now()
        self.prefill_tasks[slot] = _PrefillTask(req, done=n_reuse,
                                                reused=n_reuse)
        return True

    def _advance_prefill(self, slot: int) -> int:
        """Run the next chunk of the slot's in-flight prefill; returns the
        number of token positions computed (charged against the tick's
        prefill budget)."""
        task = self.prefill_tasks[slot]
        req = task.req
        toks = np.asarray(req.tokens, np.int64)
        T = len(toks)
        n = min(self.prefill_chunk, T - task.done)
        Cb = self._bucket(n, cap=self.prefill_chunk)
        batch = {"tokens": self._padded_prompt(toks[task.done:task.done + n],
                                               Cb),
                 "pos": jnp.asarray(task.done, jnp.int32),
                 "length": jnp.asarray(n, jnp.int32)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(self.tables[slot][None])
        else:
            batch["slot"] = jnp.asarray(slot, jnp.int32)
        mm = self._with_embeds(batch, req, task.done, task.done + n, Cb)
        self._note_trace(("prefill_chunk", Cb, mm))
        t0 = self._now() if self._tr is not None else 0.0
        task.logits, self.cache = self._prefill_chunk(
            self.params, self.cache, batch)
        if self._tr is not None:
            self._tr.span("prefill_chunk", "prefill", t0, self._now(),
                          pid=self._pid, tid=req.uid,
                          args={"tokens": n, "done": task.done + n,
                                "total": T})
        task.done += n
        self._c_prefill_computed.inc(n)
        self._c_prefill_padded.inc(Cb - n)
        if self.paged and self.prefix_caching:
            # publish fully-written prompt blocks as they complete, so a
            # request admitted later this tick already hits them
            self.pool.register_prefix(
                toks[:task.done],
                self.block_tables[slot].pages[
                    :full_blocks(task.done, self.page_size)])
        if task.done >= T:  # prompt complete: promote to decoding
            self.prefill_tasks[slot] = None
            self._activate(slot, req, int(jnp.argmax(task.logits[0])))
        return Cb

    def _schedule_prefill(self):
        """Spend this tick's prefill token budget: advance in-flight chunked
        prefills and admit queued requests into free slots, oldest first.
        Decode steps for already-running slots happen in the same tick, so
        a long prompt can no longer stall them for its whole prefill."""
        budget = self.prefill_budget
        blocked = False  # paged admission failed this tick: stop admitting
        while budget > 0:
            progressed = False
            # admit at most one request per round, then advance every
            # in-flight prefill: a short prompt admitted behind a finished
            # one sees its freshly registered prefix blocks (the admission
            # lookup runs after the earlier prompt's chunks completed)
            if (not blocked and self.queue
                    and (self._admit_quota is None or self._admit_quota > 0)):
                free = next((i for i in range(self.max_batch)
                             if self.slots[i] is None
                             and self.prefill_tasks[i] is None), None)
                if free is not None:
                    req = self.queue.popleft()
                    if self._start_prefill(free, req):
                        progressed = True
                        self._tag_group(req)
                        if self._admit_quota is not None:
                            self._admit_quota -= 1
                    else:
                        blocked = True
            for slot in range(self.max_batch):
                if budget <= 0:
                    break
                if self.prefill_tasks[slot] is None:
                    continue
                budget -= self._advance_prefill(slot)
                progressed = True
            self._progress |= progressed
            if not progressed:
                break
        spent = self.prefill_budget - budget
        if spent and self.telemetry is not None:
            self._h_budget_util.observe(spent / self.prefill_budget)

    # ------------------------------------------- streaming + batched admission
    def _emit_stream(self, req: Request, tok: int, t: float, final: bool):
        """Deliver the token just appended to ``req.output``: a
        ``first_token`` trace instant for the TTFT token, and — when the
        request streams — one ``StreamEvent`` to its callback, as the
        token is decoded rather than at drain."""
        idx = len(req.output) - 1
        if idx == 0 and self._tr is not None:
            self._tr.instant("first_token", "lifecycle", t,
                             pid=self._pid, tid=req.uid)
        if req.stream is None:
            return
        self._c_stream_tokens.inc()
        req.stream(StreamEvent(uid=req.uid, index=idx, token=tok, t_emit=t,
                               first=idx == 0, final=final))

    def _compute_admit_quota(self) -> "int | None":
        """Queued requests that may start prefill this tick under the
        saxml batching knobs (None = unlimited, legacy admission).  Sets
        ``_admission_held`` when the knobs — not resource pressure — are
        what is holding the queue back."""
        self._admission_held = False
        if self.sorted_batch_sizes is None:
            return None
        if not self.queue:
            return 0
        if (self.max_live_batches is not None
                and len(self._group_left) >= self.max_live_batches):
            self._admission_held = True
            return 0
        n = len(self.queue)
        full = max((b for b in self.sorted_batch_sizes if b <= n), default=0)
        if full:
            return full  # fill the largest bucket the queue can cover
        # partial group: released only once the oldest queued request has
        # waited out batching_wait_secs on the engine clock; its bucket is
        # the smallest allowed size >= n, so no group exceeds its bucket
        if (self._now() - self.queue[0].t_submit
                >= self.batching_wait_secs - 1e-12):
            return n
        self._admission_held = True
        return 0

    def _tag_group(self, req: Request):
        """Book a just-admitted request into this tick's admission group
        (live-batch accounting for ``max_live_batches``)."""
        if self.sorted_batch_sizes is None:
            return
        if self._cur_group is None:
            self._cur_group = self._next_group
            self._next_group += 1
            self._group_left[self._cur_group] = 0
            self._cur_size = 0
        req.group = self._cur_group
        self._group_left[self._cur_group] += 1
        self._cur_size += 1

    def _close_admit_group(self):
        if self._cur_group is not None:
            self._h_admit_size.observe(self._cur_size)
            self._cur_group = None

    # ------------------------------------------------------------- public
    def busy(self) -> bool:
        """Any work left: queued, mid-chunked-prefill, or decoding.  The
        single source of idle truth for drain loops and external drivers
        (continuum harness) alike."""
        return bool(self.queue or any(s is not None for s in self.slots)
                    or any(t is not None for t in self.prefill_tasks))

    def make_request(self, creq: ContinuumRequest,
                     uid: "int | None" = None) -> Request:
        """Materialize a typed ``ContinuumRequest`` as this engine's
        internal ``Request`` (uid engine-assigned unless given; a bool
        ``stream`` marker is a cluster-level buffering directive and
        resolves to None here)."""
        if uid is None:
            self._auto_uid += 1
            uid = self._auto_uid
        tokens = (None if creq.tokens is None
                  else np.asarray(creq.tokens, np.int32))
        return Request(uid, tokens, max_new_tokens=int(creq.max_new_tokens),
                       extra=creq.extra, segments=creq.segments,
                       stream=creq.stream if callable(creq.stream) else None)

    def submit(self, req: "Request | ContinuumRequest") -> Request:
        """Queue a request; accepts the internal ``Request`` or the typed
        ``ContinuumRequest`` (converted via ``make_request``).  Returns
        the queued internal request."""
        if isinstance(req, ContinuumRequest):
            req = self.make_request(req)
        if req.tokens is None:
            raise ValueError(f"request {req.uid}: no tokens or segments")
        if req.features is not None:
            if not self.model.supports_embed_spans:
                raise ValueError(
                    f"request {req.uid}: embedding-span prompts need an "
                    f"attention-family model, not {self.model.cfg.name}")
            if req.features.shape[1] != self.model.cfg.d_model:
                raise ValueError(
                    f"request {req.uid}: segment features of dim "
                    f"{req.features.shape[1]} do not match the model's "
                    f"d_model={self.model.cfg.d_model}")
        if len(req.tokens) > self.max_seq - 1:
            raise ValueError(
                f"request {req.uid}: prompt of {len(req.tokens)} tokens "
                f"exceeds the engine's capacity — max_seq={self.max_seq} "
                f"leaves room for at most {self.max_seq - 1} prompt tokens "
                "plus one generated token; raise max_seq or truncate the "
                "prompt")
        if len(req.tokens) < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.imported is not None:
            snap = req.imported
            if not self.paged:
                raise ValueError(
                    f"request {req.uid}: KV snapshot import needs the "
                    "paged cache backend")
            if snap.geometry != self.model.kv_geometry:
                raise ValueError(
                    f"request {req.uid}: snapshot KV geometry "
                    f"{snap.geometry} does not match this engine's "
                    f"{self.model.kv_geometry}")
            if snap.block_size != self.page_size:
                raise ValueError(
                    f"request {req.uid}: snapshot block_size "
                    f"{snap.block_size} != engine page_size "
                    f"{self.page_size}")
            if snap.num_tokens > self.max_seq - 1:
                raise ValueError(
                    f"request {req.uid}: snapshot of {snap.num_tokens} "
                    f"tokens exceeds max_seq={self.max_seq} - 1")
            if not req.output or req.done:
                raise ValueError(
                    f"request {req.uid}: a snapshot-carrying request must "
                    "be mid-decode (non-empty output, not done)")
        # a migrated request keeps its original submit stamp so queue-time
        # and e2e span the source engine too (shared virtual-clock base)
        if not req.token_times:
            req.t_submit = self._now()
        self._c_submitted.inc()
        if self._tr is not None:
            self._tr.instant("submit", "lifecycle", req.t_submit,
                             pid=self._pid, tid=req.uid)
        self.queue.append(req)
        return req

    def _finish(self, req: Request):
        """Request complete: move to ``finished``, fold its latencies into
        the registry histograms (so ``latency_stats`` survives drain loops
        popping ``self.finished``), and emit its lifecycle spans."""
        req.done = True
        self.finished.append(req)
        self._c_finished.inc()
        self._release_group(req)
        tt = req.token_times
        imported = req.imported is not None
        ta = req.t_admit if req.t_admit >= req.t_submit else req.t_submit
        if not imported:
            # a migrated request's queue/prefill phases ran on the source
            # engine (its t_admit here postdates tt[0]); only the decode
            # span and the end-to-end latencies are meaningful locally
            self._h_queue.observe(ta - req.t_submit)
        self._h_ttft.observe(tt[0] - req.t_submit)
        self._h_e2e.observe(tt[-1] - req.t_submit)
        if len(tt) > 1:
            self._h_itl.extend(b - a for a, b in zip(tt, tt[1:]))
        tr = self._tr
        if tr is not None:
            pid, tid = self._pid, req.uid
            if not imported:
                tr.span("queue", "lifecycle", req.t_submit, ta,
                        pid=pid, tid=tid)
                tr.span("prefill", "lifecycle", ta, tt[0], pid=pid, tid=tid,
                        args={"prompt_tokens": len(req.tokens)})
            tr.span("decode", "lifecycle", tt[0], tt[-1], pid=pid, tid=tid,
                    args={"new_tokens": len(req.output)})

    def _release_group(self, req: Request):
        """Retire a request from its admission group; a fully-retired
        group frees a ``max_live_batches`` slot."""
        if req.group is None:
            return
        left = self._group_left.get(req.group, 1) - 1
        if left <= 0:
            self._group_left.pop(req.group, None)
        else:
            self._group_left[req.group] = left
        req.group = None

    def _activate(self, slot: int, req: Request, first_tok: int):
        """Install an admitted request into its decode slot, honoring EOS
        and the generation budget at admission: a request whose first
        prefill-sampled token already ends it (eos, or max_new_tokens == 1)
        finishes immediately instead of decoding its full budget."""
        req.output.append(first_tok)
        req.token_times.append(self._now())
        ends = (req.max_new_tokens <= 1
                or (self.eos_id is not None and first_tok == self.eos_id))
        self._emit_stream(req, first_tok, req.token_times[-1], ends)
        if ends:
            self._finish(req)
            if self.paged and self.block_tables[slot] is not None:
                self.block_tables[slot].free()
                self.block_tables[slot] = None
                self.tables[slot] = -1
            return
        self.slots[slot] = req
        self.pos[slot] = len(req.tokens)
        self.budget[slot] = req.max_new_tokens - 1
        if self.speculative:
            self._draft_install(slot, req.tokens)

    def _draft_install(self, slot: int, tokens):
        """(Re)build the draft model's dense-cache state for ``slot`` by
        prefilling ``tokens`` (the prompt — or, for an imported snapshot,
        prompt + already-emitted output) with the draft weights.  Media
        key ids are clamped to token 0, so draft quality may drop over
        embedding spans; verification makes the emitted stream
        independent of draft quality either way."""
        toks = np.asarray(tokens, np.int64)
        T = len(toks)
        Sb = self._bucket(T)
        batch = {"tokens": self._padded_prompt(toks, Sb)}
        if self.bucketing:
            batch["length"] = jnp.asarray([T], jnp.int32)
        self._note_trace(("draft_prefill", Sb))
        _, rc = self._draft_prefill(self.draft_params, batch)
        self._draft_cache = self._splice_cache(self._draft_cache, slot, rc)

    def acceptance_rate(self, default: float = 0.6) -> float:
        """Live draft-token acceptance rate (accepted / drafted) since the
        last ``metrics.reset()``; ``default`` until any tokens have been
        drafted.  The router's speculative-shape pricing reads this."""
        drafted = self._c_spec_drafted.value
        if drafted <= 0:
            return float(default)
        return self._c_spec_accepted.value / drafted

    def _admit(self):
        """Monolithic admission path (chunking disabled, or recurrent/
        hybrid families whose state cannot be chunk-prefilled)."""
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            if self._admit_quota is not None and self._admit_quota <= 0:
                break  # this tick's admission group is full
            req = self.queue.popleft()
            if req.imported is not None:
                if self._admit_imported(slot, req):
                    self._tag_group(req)
                    if self._admit_quota is not None:
                        self._admit_quota -= 1
                    continue
                self.queue.appendleft(req)
                break  # out of pages: wait for running requests to finish
            admit = self._admit_paged if self.paged else self._admit_dense
            first = admit(slot, req)
            if first is None:
                self.queue.appendleft(req)
                break  # out of pages: wait for running requests to finish
            self._progress = True
            self._tag_group(req)
            if self._admit_quota is not None:
                self._admit_quota -= 1
            self._activate(slot, req, first)

    def step(self) -> int:
        """One engine tick: spend the prefill budget (chunked path) or
        admit monolithically, then one batched decode step for every
        fully-prefilled slot.  Returns the number of occupied slots.

        **Single-tick contract** (external drivers — e.g. the continuum
        harness — rely on this): one call performs at most one batched
        decode step, is safe to call with no work pending (it is then a
        cheap no-op returning 0), and only mutates ``self.ticks`` by one
        when any slot is occupied or prefilling.  An external scheduler may
        therefore interleave ``step()`` calls across several engines under
        a shared virtual clock; ``run_until_drained`` is just a loop over
        this method with a *relative* ``drain_deadline`` guard, so the two
        driving styles compose (draining never depends on the global tick
        count accumulated by earlier external stepping)."""
        self._progress = False  # any admission/prefill advance this tick
        self._admit_quota = self._compute_admit_quota()
        if self.chunked:
            self._schedule_prefill()
        else:
            self._admit()
        self._close_admit_group()
        self._g_queue_depth.set(len(self.queue))
        active = [i for i, r in enumerate(self.slots) if r is not None]
        n_prefilling = sum(t is not None for t in self.prefill_tasks)
        if self._tr is not None and (active or n_prefilling or self.queue):
            self._sample_tick(len(active), n_prefilling)
        if not active:
            if n_prefilling:
                self.ticks += 1
            return n_prefilling
        if self.speculative:
            self._spec_tick(active)
            self.ticks += 1
            return len(active) + n_prefilling
        tokens = np.zeros(self.max_batch, np.int32)
        # slots without a decodable request (free, or still prefilling) are
        # masked out of the decode step: dense writes land at the
        # out-of-bounds position max_seq (XLA drops them), paged rows get a
        # null block table, so a mid-prefill slot's cache is never touched
        pos = np.full(self.max_batch, self.max_seq, np.int64)
        for i in active:
            tokens[i] = self.slots[i].output[-1]
            pos[i] = self.pos[i]
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(pos, jnp.int32)}
        if self.paged:
            for i in active:  # grow block tables across page boundaries
                bt = self.block_tables[i]
                if self.pos[i] >= bt.num_tokens_capacity():
                    bt.ensure_capacity(self.pos[i] + 1)
                    self.tables[i] = bt.as_row(self.max_blocks)
            tables = np.full_like(self.tables, -1)
            for i in active:
                tables[i] = self.tables[i]
            pos[pos >= self.max_seq] = 0  # clamp masked rows (null table)
            batch["pos"] = jnp.asarray(pos, jnp.int32)
            batch["block_tables"] = jnp.asarray(tables)
        t0 = self._now() if self._tr is not None else 0.0
        out, self.cache = self._step(self.params, self.cache, batch)
        # default path: ``out`` is already the [B] argmax token ids,
        # computed on device — one int32 per slot crosses the host link
        nxt = np.asarray(jnp.argmax(out, -1) if self.return_logits else out)
        self.ticks += 1
        self._c_decode_tokens.inc(len(active))
        t_now = self._now()
        if self._tr is not None:
            self._tr.span("decode_tick", "engine", t0, t_now, pid=self._pid,
                          args={"active": len(active)})
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.output.append(tok)
            req.token_times.append(t_now)
            self.pos[i] += 1
            self.budget[i] -= 1
            ends = bool(self.budget[i] <= 0 or tok == self.eos_id
                        or self.pos[i] >= self.max_seq - 1)
            self._emit_stream(req, tok, t_now, ends)
            if ends:
                self._finish(req)
                self._free_slot(i)  # free slot/pages (continuous batching)
        return len(active) + n_prefilling

    def _spec_tick(self, active: "list[int]"):
        """One speculative decode tick: the draft model proposes ``spec_k``
        tokens per active slot (``spec_k`` cheap dense decode steps), the
        target model scores the last accepted token plus all drafts in one
        multi-token verify pass, and each slot emits the longest agreeing
        prefix plus the target's correction token — 1 to ``spec_k + 1``
        tokens, bit-identical to plain greedy decode.

        Rejected drafts leave stale K/V at positions past the new ``pos``
        in both caches; every read masks ``cache_pos <= query_pos`` and the
        next tick's writes overwrite them in order, so rollback costs
        nothing.  Stream events are emitted per token with contiguous
        indices and timestamps interpolated across the tick (monotone
        non-decreasing), and ``final`` only on the true last token."""
        k = self.spec_k
        B = self.max_batch
        t0 = self._now()
        # masked slots (free / mid-prefill): pos = max_seq puts every dense
        # draft write out of bounds (dropped) and, with a null block table,
        # every verify write/read on an invalid page (dropped/masked)
        cur = np.zeros(B, np.int32)
        base = np.full(B, self.max_seq, np.int64)
        for i in active:
            cur[i] = self.slots[i].output[-1]
            base[i] = self.pos[i]
        drafts = np.zeros((B, k), np.int32)
        for t in range(k):
            dpos = np.minimum(base + t, self.max_seq)
            ids, self._draft_cache = self._draft_step(
                self.draft_params, self._draft_cache,
                {"tokens": jnp.asarray(cur),
                 "pos": jnp.asarray(dpos, jnp.int32)})
            cur = np.asarray(ids)
            drafts[:, t] = cur
        t_draft = self._now() if self._tr is not None else t0
        # grow block tables to cover the k+1 verify positions; admission
        # reserved spec_k slack in _total_blocks, so this cannot exhaust
        # the pool (positions clamped at max_seq simply drop their writes)
        for i in active:
            bt = self.block_tables[i]
            cap = min(int(base[i]) + k + 1, self.max_seq)
            if cap > bt.num_tokens_capacity():
                bt.ensure_capacity(cap)
                self.tables[i] = bt.as_row(self.max_blocks)
        vt = np.zeros((B, k + 1), np.int32)
        for i in active:
            vt[i, 0] = self.slots[i].output[-1]
            vt[i, 1:] = drafts[i]
        tables = np.full_like(self.tables, -1)
        for i in active:
            tables[i] = self.tables[i]
        ids, self.cache = self._verify_step(
            self.params, self.cache,
            {"tokens": jnp.asarray(vt),
             "pos": jnp.asarray(np.minimum(base, self.max_seq), jnp.int32),
             "block_tables": jnp.asarray(tables)})
        ids = np.asarray(ids)  # [B, k+1] target argmax per verify position
        t_now = self._now()
        if self._tr is not None:
            self._tr.span("draft_tick", "engine", t0, t_draft,
                          pid=self._pid, args={"active": len(active),
                                               "k": k})
            self._tr.span("verify_tick", "engine", t_draft, t_now,
                          pid=self._pid, args={"active": len(active),
                                               "k": k})
        n_tok = tick_acc = 0
        for i in active:
            req = self.slots[i]
            # ids[i, j] is the target's token after consuming vt[i, :j+1];
            # draft j (= vt[i, j+1]) is accepted iff it equals ids[i, j]
            n_acc = 0
            while n_acc < k and drafts[i, n_acc] == ids[i, n_acc]:
                n_acc += 1
            emit = [int(x) for x in ids[i, :n_acc + 1]]
            n_emit = len(emit)
            emitted = 0
            for tok in emit:
                emitted += 1
                req.output.append(tok)
                ts = t0 + (t_now - t0) * emitted / n_emit
                req.token_times.append(ts)
                self.pos[i] += 1
                self.budget[i] -= 1
                ends = bool(self.budget[i] <= 0 or tok == self.eos_id
                            or self.pos[i] >= self.max_seq - 1)
                self._emit_stream(req, tok, ts, ends)
                if ends:
                    self._finish(req)
                    self._free_slot(i)
                    break
            # drafts consumed into the stream; accepted-but-unemitted
            # drafts past an eos/budget stop count as wasted
            acc = emitted - 1
            self._c_spec_drafted.inc(k)
            self._c_spec_accepted.inc(acc)
            self._c_spec_wasted.inc(k - acc)
            n_tok += emitted
            tick_acc += acc
        self._c_decode_tokens.inc(n_tok)
        drafted = self._c_spec_drafted.value
        if drafted:
            self._g_accept_rate.set(self._c_spec_accepted.value / drafted)
        if self._tr is not None:
            self._tr.counter("spec_tokens", t_now,
                             {"drafted": k * len(active),
                              "accepted": tick_acc,
                              "emitted": n_tok}, pid=self._pid)

    def _sample_tick(self, n_active: int, n_prefilling: int):
        """Per-tick occupancy counter samples (tracing enabled only)."""
        tr, now = self._tr, self._now()
        tr.counter("batch_occupancy", now,
                   {"decoding": n_active, "prefilling": n_prefilling},
                   pid=self._pid)
        tr.counter("queue_depth", now,
                   {"queued": len(self.queue),
                    "live_batches": len(self._group_left)}, pid=self._pid)
        if self.paged:
            tr.counter("kv_pages", now,
                       {"in_use": self.pool.pages_in_use(),
                        "cached": len(self.pool.lru)}, pid=self._pid)

    def run_until_drained(self, max_ticks: int = 10_000,
                          keep_finished: bool = False):
        """Step until queue, prefill tasks and slots are all empty.

        Returns the finished requests; ``keep_finished=True`` leaves them
        on ``self.finished`` too (so ``latency_stats`` still sees them).

        ``max_ticks`` bounds the ticks spent *inside this call* (a
        ``drain_deadline`` relative to the current ``self.ticks``), so an
        engine that has already been stepped externally for a long run —
        the continuum harness advances engines tick-by-tick — can still be
        drained afterwards.  The guard used to compare against the global
        tick counter and tripped immediately in that case.
        """
        drain_deadline = self.ticks + max_ticks
        spins = 0  # ticks spent holding admission (batching knobs)
        while self.busy():
            if self.step() == 0 and self.queue and not self._progress:
                if self._admission_held:
                    # the batching knobs — not resource pressure — are
                    # holding the queue: with a wall clock the wait simply
                    # elapses; a virtual clock needs an external driver,
                    # so spinning is bounded rather than diagnosed as OOM
                    spins += 1
                    if spins > max(max_ticks, 100_000):
                        raise RuntimeError(
                            "engine did not drain: admission held by the "
                            "batching knobs but the clock never advanced "
                            "(virtual-clock engines must be driven "
                            "externally when batching_wait_secs > 0)")
                    continue
                # nothing active yet admission failed: the head request can
                # never fit (its worst case exceeds the whole pool)
                head = self.queue[0]
                raise OutOfPagesError(
                    f"request {head.uid} needs {self._total_blocks(head)} "
                    f"pages but the pool only has {self.pool.num_pages - 1}")
            if self.ticks > drain_deadline:
                raise RuntimeError("engine did not drain")
        if keep_finished:
            return list(self.finished)
        out, self.finished = self.finished, []
        return out

    def reset_prefix_cache(self):
        """Drop every parked prefix block (paged path): the next admission
        sees a cold cache.  The continuum replay harness calls this
        between replays so runs are independent and deterministic (a warm
        trie would hand later replays prefix hits the first one paid for).
        K/V pages are only ever read through block tables, so the stale
        device arrays need no zeroing.  Requires an idle engine."""
        if not self.paged:
            return
        if self.busy():
            raise RuntimeError("reset_prefix_cache needs an idle engine")
        self.pool = BlockPool(self.pool.num_pages, self.page_size)

    # -------------------------------------------------------------- stats
    # back-compat: these were plain attributes before the registry existed
    @property
    def prefill_tokens_computed(self) -> int:
        return self._c_prefill_computed.value

    @property
    def prefill_tokens_padded(self) -> int:
        return self._c_prefill_padded.value

    @property
    def prefix_tokens_reused(self) -> int:
        return self._c_prefix_reused.value

    def kv_cache_bytes(self) -> int:
        """Current KV-cache footprint (allocated device arrays)."""
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.cache.values())

    def prefill_trace_count(self) -> int:
        """Distinct prefill-path shapes handed to XLA so far.  With
        bucketing this is bounded by the bucket count (O(log max_seq));
        without it every distinct prompt length is a fresh compile."""
        return len(self._traced)

    def jit_cache_sizes(self) -> dict:
        """Actual XLA trace counts per jitted entry point (when the jax
        version exposes them) — ground truth for the recompile-storm
        regression test."""
        out = {}
        for name in ("_prefill", "_prefill_sfx", "_prefill_chunk", "_step",
                     "_draft_prefill", "_draft_step", "_verify_step"):
            fn = getattr(self, name, None)
            size = getattr(fn, "_cache_size", None)
            if size is not None:
                out[name] = size()
        return out

    def latency_stats(self) -> dict:
        """TTFT / inter-token / end-to-end latency percentiles (seconds).

        Alias for ``stats()["latency"]`` kept for callers that only want
        the latency block without the full registry snapshot; both are
        thin views over the registry's ``ttft_s``/``itl_s``/``e2e_s``
        histograms, observed as each request finishes (so the numbers
        survive ``run_until_drained`` popping ``self.finished``;
        accumulation is scoped by ``metrics.reset()``, which
        ``Cluster.reset`` calls between replays).  Timestamps come from
        the engine's ``clock``: wall seconds by default, **virtual-clock
        seconds** when an external driver (the continuum harness) steps
        the engine under its own clock."""
        return latency_summary(self._h_ttft.values, self._h_itl.values,
                               self._h_e2e.values)

    def stats(self) -> dict:
        """The one-stop engine accessor: static configuration, a full
        metrics-registry snapshot (counters as ints, histograms as
        summary dicts, pool/trace views evaluated live), and the latency
        percentiles under ``"latency"`` (the ``latency_stats()`` block —
        that method remains as a documented alias)."""
        out = {"paged": self.paged, "kv_dtype": self.kv_dtype,
               "bucketed": self.bucketing, "chunked": self.chunked,
               "speculative": self.speculative,
               "spec_k": self.spec_k if self.speculative else 0,
               "acceptance_rate": (self.acceptance_rate()
                                   if self.speculative else None)}
        out.update(self.metrics.snapshot())
        out["latency"] = self.latency_stats()
        return out
