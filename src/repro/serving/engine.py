"""Slot-based continuous-batching serving engine with a paged KV cache.

A fixed decode batch of ``max_batch`` slots steps in lockstep (one
``serve_step`` per tick).  Arriving requests are prefilled individually and
spliced into a free slot; finished slots are freed immediately, so long
requests never block short ones (continuous batching).

Two cache backends:

  * **paged** (default for the pure-attention family) — K/V live in a
    shared page pool (``repro/serving/kv_cache.py``); each slot holds a
    block table instead of a dense ``max_seq`` region, prefill is never
    padded, freed requests return their pages, and identical prompt
    prefixes across requests are served from the prefix trie without
    recomputation (suffix-only prefill + copy-on-write).
  * **dense** — the original one-region-per-slot layout, still used for
    recurrent/hybrid/cross-attention cache families (zamba2, xlstm,
    whisper) whose state is not an append-only token sequence.

Works for every arch family — per-leaf cache batch dims are keyed by the
cache layout names in repro/models/api.py.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serving.kv_cache import BlockPool, BlockTable, OutOfPagesError

# batch-dim index per cache leaf name (see Model.abstract_cache layouts)
_BATCH_DIM = {"k": 1, "v": 1, "xk": 1, "xv": 1, "pos_map": 0,
              "conv": 2, "ssm": 2, "mconv": 2, "mC": 2, "mn": 2, "mm": 2,
              "sc": 1, "sn": 1, "sm": 1, "sh": 1}
# leaves whose (L, B, S, ...) seq dim must be grown to max_seq on insert
_SEQ_DIM = {"k": 2, "v": 2, "pos_map": 1}


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray  # prompt token ids
    max_new_tokens: int = 32
    extra: dict | None = None  # e.g. encoder_frames for whisper
    # filled during serving:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, eos_id: int | None = None,
                 greedy: bool = True, paged: bool | None = None,
                 page_size: int = 16, num_pages: int | None = None,
                 prefix_caching: bool = True):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int64)  # next position per slot
        self.budget = np.zeros(max_batch, np.int64)
        self.paged = model.supports_paged if paged is None else paged
        if self.paged and not model.supports_paged:
            raise ValueError(
                f"{model.cfg.name}: paged serving needs an attention-family "
                "cache; use paged=False")
        self._prefill = jax.jit(model.prefill)
        if self.paged:
            self.page_size = page_size
            self.max_blocks = -(-max_seq // page_size)
            if num_pages is None:
                # worst case (== dense capacity): admission/decode can
                # never run out; size smaller to trade safety for memory
                num_pages = 1 + max_batch * self.max_blocks
            self.prefix_caching = prefix_caching
            self.pool = BlockPool(num_pages, page_size)
            abstract = model.abstract_paged_cache(num_pages, page_size)
            self.cache = {name: jnp.zeros(s.shape, s.dtype)
                          for name, s in abstract.items()}
            self.tables = np.full((max_batch, self.max_blocks), -1, np.int32)
            self.block_tables: list[BlockTable | None] = [None] * max_batch
            self._step = jax.jit(model.serve_step_paged)
            self._prefill_sfx = jax.jit(model.prefill_with_prefix)
            self.prefill_tokens_computed = 0
            self.prefix_tokens_reused = 0
        else:
            self.cache = self._empty_cache()
            self._step = jax.jit(model.serve_step)
        self.ticks = 0
        self.finished: list[Request] = []

    # ----------------------------------------------------- dense internals
    def _empty_cache(self):
        abstract = self.model.abstract_cache(self.max_batch, self.max_seq)
        return {k: jnp.zeros(v.shape, v.dtype) if k != "pos_map"
                else jnp.full(v.shape, -1, v.dtype)
                for k, v in abstract.items()}

    def _splice(self, slot: int, req_cache: dict, prompt_len: int):
        """Insert a single-request prefill cache into batch slot ``slot``."""
        new = {}
        for name, leaf in self.cache.items():
            rc = req_cache[name]
            bdim = _BATCH_DIM[name]
            if name in _SEQ_DIM:  # pad request cache S' -> max_seq
                sdim = _SEQ_DIM[name]
                pad = [(0, 0)] * rc.ndim
                pad[sdim] = (0, leaf.shape[sdim] - rc.shape[sdim])
                rc = jnp.pad(rc, pad, constant_values=(
                    -1 if name == "pos_map" else 0))
            idx = [slice(None)] * leaf.ndim
            idx[bdim] = slice(slot, slot + 1)
            new[name] = leaf.at[tuple(idx)].set(rc.astype(leaf.dtype))
        self.cache = new

    def _admit_dense(self, slot: int, req: Request) -> bool:
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        batch = {"tokens": toks, **(req.extra or {})}
        logits, rc = self._prefill(self.params, batch)
        first = int(jnp.argmax(logits[0]))
        self._splice(slot, rc, len(req.tokens))
        req.output.append(first)
        return True

    # ----------------------------------------------------- paged internals
    def _cow_page(self, table: BlockTable, blk: int):
        """Make ``table.pages[blk]`` privately writable, copying if shared."""
        old = table.pages[blk]
        new, copied = self.pool.ensure_writable(old)
        if copied:
            for name in ("k_pages", "v_pages"):
                leaf = self.cache[name]
                self.cache[name] = leaf.at[:, new].set(leaf[:, old])
            self.pool.release(old)
            table.pages[blk] = new

    def _total_blocks(self, req: Request) -> int:
        """Worst-case pages this request can ever hold (prompt + decode)."""
        horizon = min(len(req.tokens) + req.max_new_tokens, self.max_seq)
        return -(-horizon // self.page_size)

    def _growth_outstanding(self) -> int:
        """Pages active slots may still allocate as their decodes grow."""
        return sum(self._total_blocks(r) - len(self.block_tables[i].pages)
                   for i, r in enumerate(self.slots) if r is not None)

    def _admit_paged(self, slot: int, req: Request) -> bool:
        toks = np.asarray(req.tokens, np.int64)
        T = len(toks)
        bs = self.page_size
        # admission control: admit only if the pool can cover this request's
        # worst case on top of every active slot's remaining decode growth,
        # so mid-stream page allocation can never fail.  Uses the
        # side-effect-free peek so queued retries don't inflate hit stats
        # or churn the LRU.  ``need`` counts every page this admission
        # removes from the allocatable supply: fresh allocations, plus hit
        # pages currently parked in the LRU (retaining those shrinks
        # ``num_free`` even though they need no allocation), plus the
        # copy-on-write page of a fully-cached prompt.
        hit_pages = self.pool.peek_prefix(toks) if self.prefix_caching \
            else []
        n_hit_pages = len(hit_pages)
        need = self._total_blocks(req) - n_hit_pages
        need += sum(1 for p in hit_pages if self.pool.ref[p] == 0)
        if n_hit_pages * bs >= T:
            need += 1  # fully-cached prompt: copy-on-write of the last page
        if self.pool.num_free() - self._growth_outstanding() < need:
            self.queue.appendleft(req)
            return False
        table = BlockTable(self.pool)
        n_reuse = 0
        if self.prefix_caching:
            table.pages, n_hit = self.pool.lookup_prefix(toks)
            # a fully-cached prompt still needs its last token recomputed
            # for the next-token logits -> copy-on-write on the final page
            n_reuse = min(n_hit, T - 1)
        try:
            if n_reuse == 0:
                if table.pages:
                    table.free()
                logits, rc = self._prefill(
                    self.params,
                    {"tokens": jnp.asarray(toks, jnp.int32)[None],
                     **(req.extra or {})})
                sk, sv = rc["k"], rc["v"]  # [L, 1, T, Hkv, Dh]
            else:
                kp, vp = self.cache["k_pages"], self.cache["v_pages"]
                pre = np.asarray(table.pages, np.int32)
                L, _, _, Hkv, Dh = kp.shape
                pk = kp[:, pre].reshape(L, -1, Hkv, Dh)[:, :n_reuse][:, None]
                pv = vp[:, pre].reshape(L, -1, Hkv, Dh)[:, :n_reuse][:, None]
                logits, (sk, sv) = self._prefill_sfx(
                    self.params,
                    {"tokens": jnp.asarray(toks[n_reuse:], jnp.int32)[None]},
                    pk, pv)
            first_blk = n_reuse // bs
            if first_blk < len(table.pages):
                self._cow_page(table, first_blk)
            table.ensure_capacity(T)
        except OutOfPagesError:
            table.free()
            self.queue.appendleft(req)  # retry once capacity frees up
            return False
        # scatter the computed suffix K/V into this request's pages
        sfx_pos = np.arange(n_reuse, T)
        pages = np.asarray([table.pages[p // bs] for p in sfx_pos], np.int32)
        offs = (sfx_pos % bs).astype(np.int32)
        for name, leaves in (("k_pages", sk), ("v_pages", sv)):
            leaf = self.cache[name]
            self.cache[name] = leaf.at[:, pages, offs].set(
                leaves[:, 0].astype(leaf.dtype))
        if self.prefix_caching:
            self.pool.register_prefix(toks, table.pages[:T // bs])
        self.prefill_tokens_computed += T - n_reuse
        self.prefix_tokens_reused += n_reuse
        req.output.append(int(jnp.argmax(logits[0])))
        self.block_tables[slot] = table
        self.tables[slot] = table.as_row(self.max_blocks)
        return True

    def _free_slot(self, slot: int):
        self.slots[slot] = None
        if self.paged:
            self.block_tables[slot].free()
            self.block_tables[slot] = None
            self.tables[slot] = -1
            self.pos[slot] = 0

    # ------------------------------------------------------------- public
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            admit = self._admit_paged if self.paged else self._admit_dense
            if not admit(slot, req):
                break  # out of pages: wait for running requests to finish
            self.slots[slot] = req
            self.pos[slot] = len(req.tokens)
            self.budget[slot] = req.max_new_tokens - 1

    def step(self) -> int:
        """One engine tick: admit + one batched decode step.
        Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        tokens = np.zeros(self.max_batch, np.int32)
        for i in active:
            tokens[i] = self.slots[i].output[-1]
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.pos, jnp.int32)}
        if self.paged:
            for i in active:  # grow block tables across page boundaries
                bt = self.block_tables[i]
                if self.pos[i] >= bt.num_tokens_capacity():
                    bt.ensure_capacity(self.pos[i] + 1)
                    self.tables[i] = bt.as_row(self.max_blocks)
            batch["block_tables"] = jnp.asarray(self.tables)
        logits, self.cache = self._step(self.params, self.cache, batch)
        nxt = np.asarray(jnp.argmax(logits, -1))
        self.ticks += 1
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.output.append(tok)
            self.pos[i] += 1
            self.budget[i] -= 1
            if (self.budget[i] <= 0 or tok == self.eos_id
                    or self.pos[i] >= self.max_seq - 1):
                req.done = True
                self.finished.append(req)
                self._free_slot(i)  # free slot/pages (continuous batching)
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000):
        while self.queue or any(s is not None for s in self.slots):
            if self.step() == 0 and self.queue:
                # nothing active yet admission failed: the head request can
                # never fit (its worst case exceeds the whole pool)
                head = self.queue[0]
                raise OutOfPagesError(
                    f"request {head.uid} needs {self._total_blocks(head)} "
                    f"pages but the pool only has {self.pool.num_pages - 1}")
            if self.ticks > max_ticks:
                raise RuntimeError("engine did not drain")
        out, self.finished = self.finished, []
        return out

    # -------------------------------------------------------------- stats
    def kv_cache_bytes(self) -> int:
        """Current KV-cache footprint (allocated device arrays)."""
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.cache.values())

    def stats(self) -> dict:
        out = {"ticks": self.ticks, "paged": self.paged,
               "kv_cache_bytes": self.kv_cache_bytes()}
        if self.paged:
            out.update(self.pool.stats(),
                       prefill_tokens_computed=self.prefill_tokens_computed,
                       prefix_tokens_reused=self.prefix_tokens_reused)
        return out
