"""Paged KV-cache bookkeeping: block pool, prefix trie, copy-on-write.

The serving engine stores attention K/V in fixed-size *pages* (blocks of
``block_size`` token slots shared by all layers) instead of one dense
``[L, B, max_seq]`` region per slot.  This module is the pure-host side of
that subsystem — numpy/python bookkeeping only, no device arrays — so its
invariants are testable without touching jax:

  * ``BlockPool``   — refcounted allocator over a fixed set of page ids.
    Page 0 is reserved as the *null page*: inactive batch slots point at it
    so batched scatter/gather in the decode step never aliases live data.
  * prefix trie     — full prompt blocks are registered under a chained
    hash ``h_j = H(h_{j-1}, tokens[j*bs:(j+1)*bs])``; a later request with
    the same prompt prefix re-uses those pages (refcount++) and skips
    recomputing their K/V.  ``tokens`` here are the engine's per-position
    *key ids*: real token ids for text positions, negative
    content-digest-derived ids for embedding spans
    (repro/serving/segments.key_ids) — so a repeated image hits the trie
    like repeated text, while media can never alias a vocab id.
  * LRU eviction    — a registered page whose refcount drops to zero is
    *not* freed: it parks in an LRU so future prefix hits still find it,
    and is evicted (trie entry dropped, page recycled) only when the pool
    runs dry.
  * copy-on-write   — a request may need to write into a page it shares
    with the trie or another request (e.g. recomputing the final prompt
    token of a fully-cached prompt).  ``ensure_writable`` hands back a
    private replacement page and tells the caller to copy the contents.
  * KV snapshots    — ``KVSnapshot`` is the portable, self-describing form
    of a request's KV state (host-resident page contents + int8 scale
    rows + prefix-trie chain hashes + geometry): the engine exports one to
    checkpoint/evacuate a live request, and a *foreign* engine adopts it
    straight into decode phase (``BlockPool.lookup_hashes`` +
    ``register_blocks`` re-register the prompt blocks in the receiving
    trie, so repeated prompts hit the destination's cache afterwards).

Device-side layout (owned by the engine): ``k_pages``/``v_pages`` are
``[L, num_pages, block_size, Hkv, Dh]`` and a per-slot block table maps
logical block ``j`` (token positions ``[j*bs, (j+1)*bs)``) to a page id.
With ``kv_dtype="int8"`` the pools are stored quantized (symmetric
per-row int8, repro/kernels/quant.py) and fp32 scale tensors
``k_scales``/``v_scales`` ``[L, num_pages, block_size, Hkv]`` ride
alongside them.  The pool bookkeeping here is unchanged by precision —
pages are identified by id, and every device array (values *and* scales)
is indexed by that id, so copy-on-write, LRU eviction and prefix-trie
reuse carry the scales for free.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

import numpy as np

NULL_PAGE = 0

# bytes per stored K/V element per precision, plus the per-row (per token
# position, per kv head) fp32 scale the int8 layout adds
KV_DTYPE_BYTES = {"bf16": 2, "int8": 1}
SCALE_ITEMSIZE = 4


def ceil_blocks(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions (last may be partial).

    The one source of truth for block-capacity math — the engine's batch
    assembly (``max_blocks``, admission horizons) and the snapshot
    import path both use it, so their row/padding arithmetic can never
    drift apart."""
    return -(-int(n_tokens) // int(block_size))


def full_blocks(n_tokens: int, block_size: int) -> int:
    """Blocks *fully covered* by ``n_tokens`` positions — the only blocks
    the prefix trie may register (partial blocks are still writable)."""
    return int(n_tokens) // int(block_size)


def kv_token_bytes(n_layers: int, n_kv_heads: int, head_dim: int,
                   kv_dtype: str = "bf16") -> int:
    """Bytes one token position occupies across the K+V pools of all
    layers — the unit both the engine's ``kv_budget_bytes`` admission
    sizing and the kernel_bench int8-vs-bf16 rows are denominated in.
    int8 pays ``head_dim + 4`` bytes per head row (values + fp32 scale)
    against bf16's ``2 * head_dim``: a ``2*Dh / (Dh+4)`` reduction, e.g.
    1.94x at Dh=128."""
    if kv_dtype not in KV_DTYPE_BYTES:
        raise ValueError(f"kv_dtype must be one of {list(KV_DTYPE_BYTES)}, "
                         f"got {kv_dtype!r}")
    per_head = head_dim * KV_DTYPE_BYTES[kv_dtype]
    if kv_dtype == "int8":
        per_head += SCALE_ITEMSIZE
    return 2 * n_layers * n_kv_heads * per_head  # K + V


def kv_page_bytes(n_layers: int, n_kv_heads: int, head_dim: int,
                  block_size: int, kv_dtype: str = "bf16") -> int:
    """Bytes one page (all layers, K+V, scales included) occupies."""
    return kv_token_bytes(n_layers, n_kv_heads, head_dim,
                          kv_dtype) * block_size


class OutOfPagesError(RuntimeError):
    """Raised when the pool is exhausted and nothing is evictable."""


class BlockPool:
    """Refcounted page allocator with prefix registry and LRU eviction."""

    def __init__(self, num_pages: int, block_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_pages = num_pages
        self.block_size = block_size
        self.ref = np.zeros(num_pages, np.int64)
        # page 0 reserved: never allocated, never written by live requests
        self.free_list: deque[int] = deque(range(1, num_pages))
        self.lru: "OrderedDict[int, bool]" = OrderedDict()  # evictable pages
        self.page_hash: dict[int, int] = {}  # page -> chain hash
        self.hash_page: dict[int, int] = {}  # chain hash -> page
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.cow_copies = 0

    # ------------------------------------------------------------ allocation
    def num_free(self) -> int:
        """Pages allocatable right now (free + evictable)."""
        return len(self.free_list) + len(self.lru)

    def pages_in_use(self) -> int:
        return int((self.ref > 0).sum())

    def alloc(self) -> int:
        """Grab a private page (ref=1), evicting a cached prefix if needed."""
        if self.free_list:
            page = self.free_list.popleft()
        elif self.lru:
            page, _ = self.lru.popitem(last=False)  # least recently used
            self._drop_registration(page)
            self.evictions += 1
        else:
            raise OutOfPagesError(
                f"all {self.num_pages - 1} pages referenced by live requests")
        assert self.ref[page] == 0
        self.ref[page] = 1
        return page

    def retain(self, page: int):
        """A new request starts sharing ``page``."""
        if self.ref[page] == 0:
            self.lru.pop(page, None)  # back in live use
        self.ref[page] += 1

    def release(self, page: int):
        """Drop one reference; unregistered pages go back to the free list,
        registered ones park in the LRU (data kept for future prefix hits)."""
        if self.ref[page] <= 0:
            raise ValueError(f"release of unreferenced page {page}")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            if page in self.page_hash:
                self.lru[page] = True
                self.lru.move_to_end(page)
            else:
                self.free_list.append(page)

    def ensure_writable(self, page: int) -> tuple[int, bool]:
        """Copy-on-write gate for a page about to receive K/V writes.

        Returns ``(page, False)`` when the caller holds the only reference
        and the page is not a registered prefix, else allocates a private
        replacement and returns ``(new_page, True)`` — the caller must copy
        the device contents ``old -> new`` and then ``release(old)``.
        """
        if self.ref[page] == 1 and page not in self.page_hash:
            return page, False
        new = self.alloc()
        self.cow_copies += 1
        return new, True

    # ---------------------------------------------------------- prefix trie
    @staticmethod
    def chain_hash(parent: int | None, block_tokens) -> int:
        return hash((parent, bytes(np.asarray(block_tokens, np.int64).data)))

    @classmethod
    def chain_hashes(cls, tokens, block_size: int) -> list[int]:
        """Chain hash of every *full* block of ``tokens`` — the trie keys a
        registered prompt lives under.  A ``KVSnapshot`` carries these, so
        a foreign pool can look up / re-register the snapshot's prompt
        blocks without recomputing token bytes."""
        tokens = np.asarray(tokens)
        h: int | None = None
        out: list[int] = []
        for j in range(full_blocks(len(tokens), block_size)):
            h = cls.chain_hash(h, tokens[j * block_size:(j + 1) * block_size])
            out.append(h)
        return out

    def peek_prefix(self, tokens) -> list[int]:
        """Pages of the cached prefix, without side effects.

        Unlike ``lookup_prefix`` this takes no references and records no
        hit/miss stats — use it for admission-control checks that may be
        retried many times before the real lookup.
        """
        return self.peek_hashes(self.chain_hashes(tokens, self.block_size))

    def peek_hashes(self, hashes: "list[int]") -> list[int]:
        """Pages resident under a leading run of precomputed chain hashes,
        without side effects — ``peek_prefix`` for callers that already
        hold the hashes (snapshot import admission)."""
        pages: list[int] = []
        for h in hashes:
            page = self.hash_page.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def lookup_prefix(self, tokens) -> tuple[list[int], int]:
        """Longest cached block-aligned prefix of ``tokens``.

        Returns ``(pages, n_tokens)``; every returned page has been
        ``retain``-ed for the caller (caller owns one reference each).
        """
        pages = self.lookup_hashes(self.chain_hashes(tokens,
                                                     self.block_size))
        return pages, len(pages) * self.block_size

    def lookup_hashes(self, hashes: "list[int]") -> list[int]:
        """``lookup_prefix`` over precomputed chain hashes: the leading
        resident run is ``retain``-ed for the caller (one reference each)
        and hit/miss stats are recorded."""
        pages: list[int] = []
        for h in hashes:
            page = self.hash_page.get(h)
            if page is None:
                self.misses += 1
                break
            self.hits += 1
            self.retain(page)
            pages.append(page)
        return pages

    def register_prefix(self, tokens, pages: list[int]):
        """Publish the full prompt blocks of a request into the trie.

        ``pages[j]`` holds K/V for ``tokens[j*bs:(j+1)*bs]``; only blocks
        fully covered by prompt tokens may be passed (they are immutable for
        the rest of the request's life, so sharing is safe).  Pages already
        registered (prefix hits) are no-ops; a hash collision with a
        different live page keeps the first registration.
        """
        hashes = self.chain_hashes(tokens, self.block_size)
        self.register_blocks(hashes[:len(pages)], pages)

    def register_blocks(self, hashes: "list[int]", pages: list[int]):
        """``register_prefix`` over precomputed chain hashes — the adoption
        path of an imported ``KVSnapshot`` re-registers its prompt blocks
        under the hashes the snapshot carries, so the receiving engine's
        trie serves repeated prompts from the migrated pages."""
        for h, page in zip(hashes, pages):
            if h in self.hash_page:
                continue  # already published (e.g. this request's own hit)
            if page in self.page_hash:
                continue  # page already published under another chain
            self.hash_page[h] = page
            self.page_hash[page] = h

    def _drop_registration(self, page: int):
        h = self.page_hash.pop(page, None)
        if h is not None:
            self.hash_page.pop(h, None)

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "num_pages": self.num_pages,
            "block_size": self.block_size,
            "pages_in_use": self.pages_in_use(),
            "pages_cached": len(self.lru),
            "prefix_hits": self.hits,
            "prefix_misses": self.misses,
            "evictions": self.evictions,
            "cow_copies": self.cow_copies,
        }


@dataclasses.dataclass
class BlockTable:
    """Logical-block -> page mapping for one request/slot."""

    pool: BlockPool
    pages: list[int] = dataclasses.field(default_factory=list)

    def num_tokens_capacity(self) -> int:
        return len(self.pages) * self.pool.block_size

    def ensure_capacity(self, n_tokens: int):
        """Allocate fresh pages until ``n_tokens`` positions are addressable."""
        while len(self.pages) < ceil_blocks(n_tokens, self.pool.block_size):
            self.pages.append(self.pool.alloc())

    def page_of(self, position: int) -> int:
        return self.pages[position // self.pool.block_size]

    def rows_for(self, positions) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized (page_ids, offsets) for an array of logical positions
        — the host side of the K/V scatter path used by (chunked) prefill."""
        positions = np.asarray(positions)
        bs = self.pool.block_size
        pages = np.asarray(self.pages, np.int32)[positions // bs]
        return pages, (positions % bs).astype(np.int32)

    def slot_of(self, position: int) -> tuple[int, int]:
        return self.page_of(position), position % self.pool.block_size

    def as_row(self, max_blocks: int) -> np.ndarray:
        row = np.full(max_blocks, -1, np.int32)
        row[:len(self.pages)] = self.pages
        return row

    def free(self):
        for page in self.pages:
            self.pool.release(page)
        self.pages = []


@dataclasses.dataclass
class KVSnapshot:
    """Portable, self-describing KV state of one (partially decoded)
    request — the unit of cross-engine migration.

    The engine exports a snapshot by gathering the request's contiguous
    logical block range to host numpy (refcounts held during the gather;
    the snapshot is a *copy*, so source-side eviction or page recycling
    can never corrupt it), and a foreign engine adopts it straight into
    decode phase: resident prompt blocks are reused from the receiving
    trie, missing blocks are scattered into freshly allocated pages
    (precision-converted if the pools disagree), and the prompt blocks are
    re-registered under the carried chain hashes.

    ``leaves`` holds the page contents in the source pool's storage form,
    keyed like the device cache (``k_pages``/``v_pages`` ``[L, NB, bs,
    Hkv, Dh]``, plus ``k_scales``/``v_scales`` ``[L, NB, bs, Hkv]`` for
    int8) with the page axis in *logical block order* — block ``j`` of
    ``tokens`` lives at index ``j``, so the implied block-table row is
    ``arange(NB)`` and the importer never needs the source's page ids
    (``src_pages`` rides along for provenance only).
    """

    tokens: np.ndarray  # [n_ctx] int64 key ids of every written position
    n_prompt: int  # leading prompt key ids among ``tokens``
    block_size: int
    kv_dtype: str  # storage form of ``leaves`` ("bf16" | "int8")
    geometry: "tuple[int, int, int]"  # (n_layers, n_kv_heads, head_dim)
    leaves: "dict[str, np.ndarray]"
    prefix_hashes: "list[int]"  # chain hash per full *prompt* block
    src_pages: "list[int]" = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int64)
        L, hkv, dh = self.geometry
        want = (L, self.num_pages, self.block_size, hkv, dh)
        got = tuple(self.leaves["k_pages"].shape)
        if got != want:
            raise ValueError(f"KVSnapshot: k_pages shape {got} does not "
                             f"match geometry/context {want}")
        if len(self.prefix_hashes) != full_blocks(self.n_prompt,
                                                  self.block_size):
            raise ValueError(
                f"KVSnapshot: {len(self.prefix_hashes)} prefix hashes for "
                f"{full_blocks(self.n_prompt, self.block_size)} full prompt "
                "blocks")

    @property
    def num_tokens(self) -> int:
        """Context positions the snapshot covers (prompt + generated)."""
        return len(self.tokens)

    @property
    def num_pages(self) -> int:
        return ceil_blocks(len(self.tokens), self.block_size)

    def page_bytes(self) -> int:
        """Bytes per page in the snapshot's *own* storage form.  Migration
        pricing instead uses the destination engine's ``page_bytes()`` —
        the importer converts precision on adoption, so only
        destination-form bytes need to cross a link."""
        L, hkv, dh = self.geometry
        return kv_page_bytes(L, hkv, dh, self.block_size, self.kv_dtype)

    def nbytes(self) -> int:
        return self.num_pages * self.page_bytes()
