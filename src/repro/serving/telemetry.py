"""Continuum telemetry: request tracing, metrics registry, dispatch audit.

The paper's central difficulty is that generation quality and inference
latency are *highly difficult to predict* for MLLM offloading — but the
harness used to report only end-of-run aggregates, so there was no way to
see where a request's virtual seconds went, why the router picked a
server, or how wrong the dispatch-time latency prediction was.  This
module is the shared observability substrate for the serving stack:

  * ``Tracer``          — per-request lifecycle spans
    (uplink→queue→prefill→decode→downlink, plus per-chunk prefill spans,
    engine ticks and media-encode transfers), recorded against whatever
    clock the engine runs on — wall time for a standalone
    ``ServingEngine``, the shared **virtual clock** for the continuum
    replay harness — so live and replayed runs produce comparable traces.
    Export is Chrome trace-event JSON (``Telemetry.export``): open the
    file in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.
    One *process* per engine, one *thread row* per request uid.
  * ``MetricsRegistry`` — counters / gauges / histograms replacing the
    scattered ad-hoc stats dicts: every ``ServingEngine`` owns one, and
    ``latency_stats()`` / ``stats()`` are thin views over it.  ``view``
    registers zero-cost callback metrics (KV pool occupancy, XLA trace
    counts) evaluated only at snapshot time.
  * dispatch audit      — one ``DispatchRecord`` per routed request with
    the predicted end-to-end latency and its per-term breakdown (queue,
    prefill, decode, media, link) plus every candidate server's score;
    ``join_measured`` patches in the measured e2e when the request
    finishes, making the paper's "latency is hard to predict" claim a
    measured, regression-gated number (``prediction_error``).

Zero-cost-when-off contract: components accept ``telemetry=None`` and
guard every tracing site behind a single attribute check; with tracing
disabled no span/event objects are allocated on the decode hot path.
``Telemetry(trace=False)`` keeps the metrics registry and the dispatch
audit live (both are O(1) per *request*, not per tick) while recording no
trace events at all.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

# ---------------------------------------------------------------- metrics


class Counter:
    """Monotonic int counter (``inc``); cheap enough for per-tick paths."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = v


class Histogram:
    """Value-retaining histogram: keeps raw observations so percentiles
    are exact and per-tier rollups can merge raw samples."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: list[float] = []

    def observe(self, v: float):
        self.values.append(v)

    def extend(self, vs):
        self.values.extend(vs)

    @property
    def count(self) -> int:
        return len(self.values)

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.values, q)) if self.values else 0.0

    def mean(self) -> float:
        return float(np.mean(self.values)) if self.values else 0.0

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean(),
                "p50": self.percentile(50), "p95": self.percentile(95)}


class MetricsRegistry:
    """Named counters/gauges/histograms plus callback views.

    ``view(name, fn)`` registers a zero-storage metric evaluated only at
    ``snapshot()`` time — used for values another subsystem already
    tracks (KV pool occupancy, XLA cache sizes), so hot paths pay
    nothing.  ``reset()`` zeroes the stored metrics but keeps the view
    registrations (their backing state has its own lifecycle).
    """

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.views: dict[str, "callable"] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    def view(self, name: str, fn):
        self.views[name] = fn

    def reset(self):
        for c in self.counters.values():
            c.value = 0
        for g in self.gauges.values():
            g.value = 0.0
        for h in self.histograms.values():
            h.values.clear()

    def snapshot(self) -> dict:
        """Plain-value dict: counters/gauges as scalars, histograms as
        summary dicts, views evaluated now."""
        out: dict = {n: c.value for n, c in self.counters.items()}
        out.update((n, g.value) for n, g in self.gauges.items())
        out.update((n, h.summary()) for n, h in self.histograms.items())
        out.update((n, fn()) for n, fn in self.views.items())
        return out


def latency_summary(ttft, itl, e2e) -> dict:
    """The engine's historical ``latency_stats()`` shape, computed from
    raw samples — shared by the per-engine view and the per-tier rollups
    (``Cluster.latency_stats``)."""
    pct = lambda xs, q: float(np.percentile(xs, q)) if len(xs) else 0.0
    return {"n_requests": len(e2e),
            "ttft_p50_s": pct(ttft, 50), "ttft_p95_s": pct(ttft, 95),
            "itl_p50_s": pct(itl, 50), "itl_p95_s": pct(itl, 95),
            "e2e_p50_s": pct(e2e, 50), "e2e_p95_s": pct(e2e, 95),
            "e2e_mean_s": float(np.mean(e2e)) if len(e2e) else 0.0}


# ----------------------------------------------------------------- tracer

_US = 1e6  # chrome trace-event timestamps are microseconds


class Tracer:
    """Chrome-trace-event recorder against caller-supplied timestamps.

    Callers pass explicit ``t0``/``t1`` seconds from *their* clock (wall
    or virtual), so the tracer itself never reads time — replayed runs
    are bit-deterministic.  ``enabled=False`` turns every record call
    into an immediate return; hot paths should additionally skip the call
    entirely (bind the tracer to a local, check once per tick).
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: list[dict] = []
        self._pids: dict[str, int] = {}

    def process(self, name: str) -> int:
        """Stable pid for a named event source (engine/handle/cluster);
        registering is idempotent and metadata is emitted at export."""
        pid = self._pids.get(name)
        if pid is None:
            pid = self._pids[name] = len(self._pids) + 1
        return pid

    def span(self, name: str, cat: str, t0: float, t1: float, *,
             pid: int = 0, tid: int = 0, args: dict | None = None):
        """Complete event ("X") covering ``[t0, t1]`` seconds."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "X", "pid": pid, "tid": tid,
              "ts": t0 * _US, "dur": max(t1 - t0, 0.0) * _US}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, cat: str, t: float, *, pid: int = 0,
                tid: int = 0, args: dict | None = None):
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t", "pid": pid,
              "tid": tid, "ts": t * _US}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, t: float, values: dict, *, pid: int = 0):
        """Counter sample ("C"): Perfetto renders a stacked timeline —
        used for batch occupancy and KV-pool occupancy per tick."""
        if not self.enabled:
            return
        self.events.append({"name": name, "cat": "counter", "ph": "C",
                            "pid": pid, "tid": 0, "ts": t * _US,
                            "args": values})

    def clear(self):
        """Drop recorded events; process registrations survive (the
        fleet does not change between replays)."""
        self.events.clear()

    def chrome_events(self) -> list[dict]:
        """Events plus process/thread metadata, ready for Perfetto."""
        meta = []
        for name, pid in self._pids.items():
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": name}})
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": "engine"}})
        return meta + self.events


# ---------------------------------------------------------- dispatch audit


@dataclasses.dataclass
class DispatchRecord:
    """One routed request: what the router predicted vs. what happened."""

    uid: int
    task: int
    server: int
    t_dispatch_s: float
    predicted_s: float  # predicted end-to-end seconds for the chosen server
    # per-term breakdown of ``predicted_s``: queue / prefill / decode /
    # media / link (whichever the caller can decompose)
    terms: dict = dataclasses.field(default_factory=dict)
    candidates: "list[float] | None" = None  # per-server predicted totals
    policy_est_s: "float | None" = None  # the policy's own estimate, if any
    measured_e2e_s: "float | None" = None  # joined at finalize
    completed: bool = False  # False until joined; timeouts stay False


class Telemetry:
    """Facade bundling one ``Tracer``, the dispatch audit, and the
    metrics registries of every engine that attached itself.

    ``trace=False`` keeps metrics + audit live but records no trace
    events (the per-tick hot path then stays allocation-free).
    """

    def __init__(self, trace: bool = True):
        self.tracer = Tracer(enabled=trace)
        self.registries: dict[str, MetricsRegistry] = {}
        self._audit: dict[int, DispatchRecord] = {}
        self._auto_uid = 0

    # ------------------------------------------------------------ metrics
    def register_metrics(self, name: str, registry: MetricsRegistry):
        self.registries[name] = registry

    # -------------------------------------------------------------- audit
    def record_dispatch(self, *, task: int, server: int, t: float,
                        predicted_s: float, uid: "int | None" = None,
                        terms: dict | None = None, candidates=None,
                        policy_est_s: "float | None" = None) -> int:
        """Audit one dispatch decision; returns the record's uid.  Pass
        the cluster request uid when there is one (``Cluster.collect``
        joins measured latencies by it); synchronous callers (the legacy
        router path) omit it and join immediately under an auto uid."""
        if uid is None:
            self._auto_uid -= 1  # negatives: disjoint from cluster uids
            uid = self._auto_uid
        self._audit[uid] = DispatchRecord(
            uid=uid, task=int(task), server=int(server),
            t_dispatch_s=float(t), predicted_s=float(predicted_s),
            terms={k: float(v) for k, v in (terms or {}).items()},
            candidates=(None if candidates is None
                        else [float(c) for c in candidates]),
            policy_est_s=(None if policy_est_s is None
                          else float(policy_est_s)))
        return uid

    def join_measured(self, uid: int, e2e_s: float, *,
                      completed: bool = True):
        """Patch the measured end-to-end latency into a dispatch record
        (no-op for uids this telemetry never audited)."""
        rec = self._audit.get(uid)
        if rec is not None:
            rec.measured_e2e_s = float(e2e_s)
            rec.completed = bool(completed)

    def audit_records(self) -> "list[DispatchRecord]":
        return [self._audit[uid] for uid in sorted(self._audit)]

    def prediction_error(self) -> dict:
        """Cost-model calibration over completed requests: percentiles of
        the absolute per-request e2e prediction error, in percent of the
        measured latency.  Timeout/never-finished requests are excluded
        (their sentinel latency would measure the timeout horizon, not
        the model)."""
        pairs = [(r.predicted_s, r.measured_e2e_s)
                 for r in self._audit.values()
                 if r.completed and r.measured_e2e_s]
        if not pairs:
            return {"n": 0, "mean_abs_pct_err": 0.0, "p50_abs_pct_err": 0.0,
                    "p95_abs_pct_err": 0.0, "mean_signed_pct_err": 0.0}
        pred, meas = np.array(pairs).T
        pct = 100.0 * (pred - meas) / np.maximum(meas, 1e-9)
        return {"n": len(pairs),
                "mean_abs_pct_err": float(np.mean(np.abs(pct))),
                "p50_abs_pct_err": float(np.percentile(np.abs(pct), 50)),
                "p95_abs_pct_err": float(np.percentile(np.abs(pct), 95)),
                "mean_signed_pct_err": float(np.mean(pct))}

    # ---------------------------------------------------------- lifecycle
    def reset(self):
        """Per-replay reset: drop trace events and audit records.  Engine
        registries are reset by their owners (``Cluster.reset``)."""
        self.tracer.clear()
        self._audit.clear()
        self._auto_uid = 0

    def to_json(self) -> dict:
        """Chrome-trace JSON with the audit + metrics riding along as
        extra top-level keys (Perfetto ignores them)."""
        return {"traceEvents": self.tracer.chrome_events(),
                "displayTimeUnit": "ms",
                "metrics": {n: r.snapshot()
                            for n, r in self.registries.items()},
                "audit": [dataclasses.asdict(r)
                          for r in self.audit_records()],
                "prediction_error": self.prediction_error()}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path
