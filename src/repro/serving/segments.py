"""Typed prompt segments: the modality-aware request representation.

A multimodal prompt is an ordered list of *segments*, each occupying a
contiguous span of KV-cache positions:

  * ``TextSegment``  — ordinary token ids; positions are embedded through
    the LM's token table inside the jitted prefill entry point.
  * ``EmbedSegment`` — precomputed embedding vectors (image patches from
    the conv-patchify encoder, audio frames, ...) injected *as-is* at
    their positions; the LM never sees token ids for them.

Everything downstream of the embedding boundary (attention, KV pages,
decode) is modality-agnostic, so the serving stack only needs two things
from a segment list:

  * ``key_ids``        — one int64 per position, used everywhere token ids
    were used for *bookkeeping*: prompt length, bucket shapes and — most
    importantly — the paged prefix-cache trie (repro/serving/kv_cache.py).
    Text positions keep their token id; embedding positions get a negative
    id derived from the segment's content ``digest`` and the offset within
    the segment, so two requests carrying the *same* image produce the
    same chain hashes and hit each other's prefix blocks, while a
    different image (or a different compression setting) can never collide
    with a real token id.
  * ``dense_features`` — the ``[T, d]`` feature rows + ``[T]`` bool mask
    handed to the model entry points (``lm.embed_inputs`` selects between
    the token-table lookup and the injected row per position).

Digests are content hashes of the *feature bytes* (`feature_digest`): two
media inputs share KV pages exactly when they would produce identical
embeddings, which is the only correct notion of "same image" for cache
reuse (it folds in the encoder weights and the keep-top-k setting).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

# embedding-position key ids live in [-2**62, -1]: disjoint from every
# valid vocab id, so a text block can never alias a media block in the
# prefix trie's chain hash
_KEY_SPACE = 1 << 62
_KEY_MIX = 0x9E3779B97F4A7C15  # Fibonacci hashing multiplier


def feature_digest(features: np.ndarray) -> int:
    """Stable content hash of an embedding span (any dtype/shape)."""
    arr = np.ascontiguousarray(np.asarray(features, np.float32))
    h = hashlib.blake2b(arr.tobytes(), digest_size=8)
    h.update(str(arr.shape).encode())
    return int.from_bytes(h.digest(), "little")


@dataclasses.dataclass(frozen=True)
class TextSegment:
    """A span of ordinary token ids."""

    tokens: np.ndarray

    def __len__(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass(frozen=True)
class EmbedSegment:
    """A span of precomputed embedding vectors (one per position).

    ``features`` is ``[n, d_model]``; ``modality`` tags the span for the
    cost model's per-modality payload accounting; ``raw_bytes`` /
    ``feature_bytes`` describe what shipping this media costs over the
    uplink in each form (raw media vs. encoded features) — the split-point
    decision (sim/cost_model.best_split) compares exactly these.
    ``digest`` defaults to a content hash of the features.
    """

    features: np.ndarray
    modality: str = "image"
    raw_bytes: float = 0.0
    feature_bytes: float = 0.0
    digest: int | None = None

    def __len__(self) -> int:
        return len(self.features)

    def content_digest(self) -> int:
        return self.digest if self.digest is not None \
            else feature_digest(self.features)


Segment = TextSegment | EmbedSegment


def total_len(segments: "list[Segment]") -> int:
    return sum(len(s) for s in segments)


def key_ids(segments: "list[Segment]") -> np.ndarray:
    """Per-position int64 bookkeeping ids (prefix-trie hash inputs).

    Text positions carry their token id; embedding positions carry
    ``-(1 + mix(digest, offset))`` — always negative, deterministic in the
    segment content, distinct across offsets within a span.
    """
    out = []
    for seg in segments:
        if isinstance(seg, TextSegment):
            out.append(np.asarray(seg.tokens, np.int64))
        else:
            g = seg.content_digest()
            vals = [-(1 + ((g + j * _KEY_MIX) % _KEY_SPACE))
                    for j in range(len(seg))]
            out.append(np.asarray(vals, np.int64))
    if not out:
        return np.zeros(0, np.int64)
    return np.concatenate(out)


def dense_features(segments: "list[Segment]", d_model: int
                   ) -> "tuple[np.ndarray, np.ndarray]":
    """(features [T, d_model] float32, embed_mask [T] bool) for the model
    entry points; text rows are zero and masked out."""
    T = total_len(segments)
    feats = np.zeros((T, d_model), np.float32)
    mask = np.zeros(T, bool)
    pos = 0
    for seg in segments:
        n = len(seg)
        if isinstance(seg, EmbedSegment):
            f = np.asarray(seg.features, np.float32)
            if f.ndim != 2 or f.shape[1] != d_model:
                raise ValueError(
                    f"EmbedSegment features {f.shape} do not match "
                    f"d_model={d_model}")
            feats[pos:pos + n] = f
            mask[pos:pos + n] = True
        pos += n
    return feats, mask


def media_segments(segments: "list[Segment]") -> "list[EmbedSegment]":
    return [s for s in segments if isinstance(s, EmbedSegment)]
