"""Cluster-level QLMIO router with fault tolerance (README.md, Design notes).

The paper's offloading policy doubles as the serving fault-tolerance
mechanism: a dead or straggling server's effective latency explodes, the
health tracker folds that into the latency estimates the router sees, and
traffic drains away.  On top of that:

  * health tracking      — per-server EWMA latency + consecutive-failure
                           count; a server past the failure threshold is
                           excluded until its cooldown expires.
  * hedged requests      — if a dispatched request exceeds
                           ``hedge_factor x`` its predicted latency, a backup
                           dispatch goes to the next-best healthy server and
                           the first finisher wins (straggler mitigation).
  * elastic scaling      — servers can be added/removed between decisions;
                           the router re-reads the table every decision, and
                           the QLMIO state encodes per-server features, so a
                           trained policy generalizes across table sizes.
  * prefix-cache affinity — servers running the paged KV engine
                           (repro/serving/kv_cache.py) keep prompt-prefix
                           blocks resident; an optional per-(task, server)
                           expected-hit-rate predictor shrinks the prefill
                           term of that server's latency estimate, so
                           re-routing a conversation to the server that
                           already holds its prefix scores cheaper.
  * media-aware scoring  — an optional per-(task, server) media predictor
                           (cost_model.best_split) adds each modality's
                           cheapest split-point cost — raw-media vs.
                           compressed-feature uplink bytes plus encode —
                           to that server's latency estimate.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import numpy as np

from repro.serving.request import ContinuumRequest

logger = logging.getLogger(__name__)


@dataclasses.dataclass
class ServerHandle:
    name: str
    model_id: int
    device_id: int
    is_cloud: bool
    # returns (latency_s, success) for a task dispatched now
    execute: Callable[[int], "tuple[float, bool]"]
    # optional live-load probe.  A handle backed by a real ServingEngine
    # (repro/serving/cluster.EngineHandle) returns
    #   {"queue_depth": int,              # queued + admitted + prefilling
    #    "inflight_prefill_tokens": int,  # prompt tokens not yet in cache
    #    "backlog_s": float}              # est. seconds to drain all that
    # so the router can score against the engine's *actual* congestion
    # instead of only its own dispatch bookkeeping (queue_s).
    load: "Callable[[], dict] | None" = None


class SimulatedServer(ServerHandle):
    """Trace-driven handle over MIOBench (used by tests/examples)."""

    def __init__(self, name, bench, class_idx, rng, fail: bool = False):
        self.bench = bench
        self.class_idx = class_idx
        self.rng = rng
        self.fail = fail
        super().__init__(
            name=name,
            model_id=int(bench.model_id[class_idx]),
            device_id=int(bench.device_id[class_idx]),
            is_cloud=class_idx == bench.latency_s.shape[1] - 1,
            execute=self._execute)

    def _execute(self, task: int):
        if self.fail:
            return 240.0, False
        return (float(self.bench.latency_s[task, self.class_idx]),
                bool(self.bench.score[task, self.class_idx] == 1))


class HealthTracker:
    def __init__(self, n: int, *, ewma: float = 0.3, fail_threshold: int = 3,
                 cooldown: float = 30.0):
        self.ewma_lat = np.zeros(n)
        self.fails = np.zeros(n, np.int64)
        self.dead_until = np.zeros(n)
        self.alpha = ewma
        self.fail_threshold = fail_threshold
        self.cooldown = cooldown

    def record(self, server: int, latency: float, ok: bool, now: float):
        self.ewma_lat[server] = ((1 - self.alpha) * self.ewma_lat[server]
                                 + self.alpha * latency)
        if ok:
            self.fails[server] = 0
        else:
            self.fails[server] += 1
            if self.fails[server] >= self.fail_threshold:
                self.dead_until[server] = now + self.cooldown

    def healthy(self, now: float) -> np.ndarray:
        return now >= self.dead_until

    def straggler_factor(self, server: int) -> float:
        """>1 when a server is consistently slower than the fleet median."""
        med = np.median(self.ewma_lat[self.ewma_lat > 0]) if \
            (self.ewma_lat > 0).any() else 0.0
        if med <= 0 or self.ewma_lat[server] <= 0:
            return 1.0
        return float(max(1.0, self.ewma_lat[server] / med))


class QLMIORouter:
    """Quality-latency tradeoff-aware dispatch over live server handles."""

    def __init__(self, servers: "list[ServerHandle]", milp_pred, mgqp_pred,
                 *, quality_weight: float = 1.0, hedge_factor: float = 3.0,
                 policy=None, prefix_hit_pred=None, prefill_pred=None,
                 media_pred=None, migrate_pred=None, spec_pred=None,
                 telemetry=None):
        """milp_pred(task, server) -> seconds; mgqp_pred(task, server) ->
        P(success).  ``policy`` optionally overrides the scoring rule with a
        trained QLMIO agent's argmax.

        ``prefix_hit_pred(task, server) -> [0, 1]`` optionally estimates the
        fraction of the task's prompt already resident in that server's
        paged KV prefix cache, and ``prefill_pred(task, server) -> seconds``
        the prefill share of the MILP estimate; together they discount the
        latency of servers that already hold the conversation's prefix
        (cost_model.latency_s's ``prefix_hit_rate`` term).  Build
        ``prefill_pred`` from ``cost_model.prefill_s(..., prefill_chunk=N)``
        when the target server runs the bucketed/chunked prefill engine, so
        the discount matches the step-function cost it actually pays.

        ``media_pred(task, server) -> seconds`` optionally adds the
        per-modality media cost of dispatching this task to that server —
        typically the *best split point* extra
        (``cost_model.best_split``: edge-encode + compressed-feature
        uplink vs. raw-media uplink + destination encode), so servers
        behind thin links are charged for the bytes the task's media
        actually puts on them.

        ``migrate_pred(task, prefill_server, decode_server) -> seconds``
        optionally prices the *disaggregated* dispatch shape — prefill on
        one server, KV migration over the link, decode on another
        (serving/cluster.Cluster.predict_disagg_e2e_s gives the live
        version) — returning the pair's total predicted latency, or None
        for a KV-incompatible pair.  With it, ``plan`` scores every
        (prefill, decode) pair alongside the pure single-server shapes.

        ``spec_pred(task, draft_server, verify_server) -> seconds``
        optionally prices the *speculative* dispatch shape — the verify
        server runs prefill plus acceptance-discounted multi-token
        verification while ``draft_server``'s device prices the per-tick
        draft steps (serving/cluster.Cluster.predict_spec_e2e_s gives
        the live version, fed by the verify engine's measured acceptance
        rate) — returning the pair's total predicted latency, or None
        when the pair cannot speculate (verify server not speculative,
        or speculation predicted slower than its own plain decode).
        ``draft_server == verify_server`` prices colocated speculation;
        a distinct edge draft server is the paper's edge-drafts/
        cloud-verifies offloading mode.

        ``telemetry`` (repro/serving/telemetry.Telemetry) optionally
        audits every ``dispatch``: the chosen server, its predicted
        latency, every candidate's effective latency, and — this path
        executes synchronously — the measured latency, joined
        immediately.
        """
        self.servers = servers
        self.milp = milp_pred
        self.mgqp = mgqp_pred
        self.w = quality_weight
        self.hedge_factor = hedge_factor
        self.policy = policy
        self.prefix_hit_pred = prefix_hit_pred
        self.prefill_pred = prefill_pred
        self.media_pred = media_pred
        self.migrate_pred = migrate_pred
        self.spec_pred = spec_pred
        self.telemetry = telemetry
        self.health = HealthTracker(len(servers))
        self.queue_s = np.zeros(len(servers))
        self.now = 0.0
        self._last_drain = 0.0
        self.log: list[dict] = []

    # --------------------------------------------------------------- scoring
    def observed_load(self) -> np.ndarray:
        """Per-server engine-reported backlog seconds (0 for handles
        without a ``load`` probe).  Live handles report queue depth and
        in-flight prefill tokens converted to drain time; simulated ones
        report nothing and the router falls back to ``queue_s``."""
        out = np.zeros(len(self.servers))
        for s, h in enumerate(self.servers):
            probe = getattr(h, "load", None)
            if callable(probe):
                obs = probe() or {}
                out[s] = float(obs.get("backlog_s", 0.0))
        return out

    def _effective_latency(self, task: int) -> np.ndarray:
        """Per-server predicted seconds, net of expected prefix-cache hits,
        plus any engine-observed congestion beyond the router's own
        ``queue_s`` bookkeeping.

        ``queue_s`` only tracks work *this* router dispatched; a live
        engine may also be loaded by chunked prefills still in flight or
        by other traffic sources.  For servers exposing a ``load`` probe,
        the excess ``max(backlog_s - queue_s, 0)`` is folded in here, so
        ``_score``'s ``t_hat + queue_s`` totals ``t_hat + max(queue_s,
        backlog_s)`` — observed congestion wins when it is larger, and
        nothing is double-counted when the bookkeeping already covers it.
        """
        n = len(self.servers)
        t_hat = np.array([self.milp(task, s) for s in range(n)])
        if self.media_pred is not None:
            t_hat = t_hat + np.maximum(
                [self.media_pred(task, s) for s in range(n)], 0.0)
        if self.prefix_hit_pred is not None and self.prefill_pred is not None:
            hit = np.clip([self.prefix_hit_pred(task, s) for s in range(n)],
                          0.0, 1.0)
            pre = np.array([self.prefill_pred(task, s) for s in range(n)])
            t_hat = np.maximum(t_hat - hit * pre, 1e-3)
        obs = self.observed_load()
        if obs.any():
            t_hat = t_hat + np.maximum(obs - self.queue_s, 0.0)
        return t_hat

    def _score(self, task: int, t_hat: np.ndarray | None = None) -> np.ndarray:
        n = len(self.servers)
        if t_hat is None:
            t_hat = self._effective_latency(task)
        b_hat = np.array([self.mgqp(task, s) for s in range(n)])
        total = (t_hat + self.queue_s) * np.array(
            [self.health.straggler_factor(s) for s in range(n)])
        # reward-shaped utility: latency ratio + completion bonus (Eq. 21)
        utility = -total / max(total.min(), 1e-6) + self.w * (
            3.0 * b_hat - 2.0)
        utility[~self.health.healthy(self.now)] = -np.inf
        return utility

    def route(self, task: int, t_hat: np.ndarray | None = None) -> int:
        if self.policy is not None:
            a = self.policy(task, self.queue_s, self.health)
            if self.health.healthy(self.now)[a]:
                return a
        u = self._score(task, t_hat)
        best = int(np.argmax(u))
        if not np.isfinite(u[best]):
            # every server is in cooldown: argmax over all -inf would
            # silently pick server 0 — dispatch to the soonest-recovering
            # server instead (min dead_until) and say so
            best = int(np.argmin(self.health.dead_until))
            logger.warning(
                "task %s: all %d servers unhealthy; falling back to "
                "soonest-recovering server %d (%s, recovers at t=%.1fs)",
                task, len(self.servers), best, self.servers[best].name,
                float(self.health.dead_until[best]))
        return best

    def plan(self, task: "int | ContinuumRequest"):
        """Price every dispatch *shape* and return the best: pure
        prefill-and-decode-here for each healthy server, plus — when
        ``migrate_pred`` is given — disaggregated prefill-on-A/
        decode-on-B for every healthy, KV-compatible ordered pair, plus
        — when ``spec_pred`` is given — speculative draft-on-A/
        verify-on-B for every healthy pair (including A == B, colocated
        speculation; a distinct edge A is edge-drafts/cloud-verifies).

        Given a task id, returns the legacy ``{"server": decode server,
        "prefill_server": prefill server or None (pure),
        "draft_server": draft server or None (non-speculative),
        "utility", "predicted_s"}`` dict; a disaggregated winner maps
        onto ``Cluster.submit(server=prefill_server,
        decode_server=server)``.

        Given a typed ``ContinuumRequest`` (its ``task`` field names the
        MIOBench task the predictors score), returns the request
        *annotated* with the decision — ``with_plan(server=...,
        decode_server=..., predicted_s=..., utility=...)`` — ready to
        hand to ``Cluster.submit`` unchanged.

        The completion bonus is judged at the decode server — in a
        KV-compatible fleet both phases run the same model, so quality
        rides with whoever finishes the answer."""
        creq = task if isinstance(task, ContinuumRequest) else None
        if creq is not None:
            task = int(creq.task)
        n = len(self.servers)
        t_eff = self._effective_latency(task)
        healthy = self.health.healthy(self.now)
        strag = np.array([self.health.straggler_factor(s)
                          for s in range(n)])
        b_hat = np.array([self.mgqp(task, s) for s in range(n)])
        # (total_s, decode_server, prefill_server-or-None,
        #  draft_server-or-None) per shape
        shapes = [((t_eff[s] + self.queue_s[s]) * strag[s], s, None, None)
                  for s in range(n) if healthy[s]]
        if self.migrate_pred is not None:
            for sp in range(n):
                for sd in range(n):
                    if sp == sd or not (healthy[sp] and healthy[sd]):
                        continue
                    t = self.migrate_pred(task, sp, sd)
                    if t is None:  # KV-incompatible pair
                        continue
                    # both servers are busy for (parts of) the request;
                    # charge the worse backlog and the worse straggler
                    total = ((t + max(self.queue_s[sp], self.queue_s[sd]))
                             * max(strag[sp], strag[sd]))
                    shapes.append((total, sd, sp, None))
        if self.spec_pred is not None:
            for sa in range(n):  # draft server (may equal the verifier)
                for sv in range(n):  # verify/decode server
                    if not (healthy[sa] and healthy[sv]):
                        continue
                    t = self.spec_pred(task, sa, sv)
                    if t is None:  # pair cannot (profitably) speculate
                        continue
                    total = ((t + max(self.queue_s[sa], self.queue_s[sv]))
                             * max(strag[sa], strag[sv]))
                    shapes.append((total, sv, None, sa))
        if not shapes:  # every server in cooldown: mirror route()
            best = int(np.argmin(self.health.dead_until))
            logger.warning(
                "task %s: all %d servers unhealthy; plan falls back to "
                "soonest-recovering server %d (%s)", task, n, best,
                self.servers[best].name)
            if creq is not None:
                return creq.with_plan(server=best, decode_server=None,
                                      predicted_s=float("inf"),
                                      utility=float("-inf"))
            return {"server": best, "prefill_server": None,
                    "draft_server": None,
                    "utility": -np.inf, "predicted_s": float("inf")}
        norm = max(min(t for t, _, _, _ in shapes), 1e-6)
        utility = lambda e: -e[0] / norm + self.w * (3.0 * b_hat[e[1]] - 2.0)
        best = max(shapes, key=utility)
        total, decode_s, prefill_s, draft_s = best
        if creq is not None:
            # disaggregated shape: Cluster.submit prefills on ``server``
            # and decodes on ``decode_server`` — map accordingly
            if prefill_s is None:
                return creq.with_plan(server=decode_s, decode_server=None,
                                      draft_server=draft_s,
                                      predicted_s=float(total),
                                      utility=float(utility(best)))
            return creq.with_plan(server=prefill_s, decode_server=decode_s,
                                  draft_server=draft_s,
                                  predicted_s=float(total),
                                  utility=float(utility(best)))
        return {"server": decode_s, "prefill_server": prefill_s,
                "draft_server": draft_s,
                "utility": float(utility(best)),
                "predicted_s": float(total)}

    # -------------------------------------------------------------- dispatch
    def _drain_queues(self):
        """Work completes as wall-clock advances: shrink every server's
        backlog by the time elapsed since the last dispatch.  Without this,
        ``queue_s`` only ever grows and long runs mispredict every server
        as saturated."""
        elapsed = self.now - self._last_drain
        if elapsed > 0:
            self.queue_s = np.maximum(0.0, self.queue_s - elapsed)
        self._last_drain = self.now

    def dispatch(self, task: int) -> dict:
        self._drain_queues()
        t_eff = self._effective_latency(task)  # evaluated once per dispatch
        s = self.route(task, t_eff)
        lat, ok = self.servers[s].execute(task)
        predicted = t_eff[s] + self.queue_s[s]
        hedged = False
        if lat > self.hedge_factor * max(predicted, 0.25):
            # straggler: hedge to the next-best healthy server.  Both
            # servers executed the task, so the loser's work is charged to
            # its queue_s too — only the winner's latency reaches the
            # caller, but backlog accounting must cover both dispatches.
            u = self._score(task, t_eff)
            u[s] = -np.inf
            s2 = int(np.argmax(u))
            if s2 != s and np.isfinite(u[s2]):  # a healthy backup exists
                lat2, ok2 = self.servers[s2].execute(task)
                if self.queue_s[s2] + lat2 < self.queue_s[s] + lat:
                    self.health.record(s, lat, False, self.now)
                    self.queue_s[s] += lat  # losing original did the work
                    s, lat, ok, hedged = s2, lat2, ok2, True
                else:
                    self.queue_s[s2] += lat2  # losing hedge did the work
        total = lat + self.queue_s[s]
        if self.telemetry is not None:
            uid = self.telemetry.record_dispatch(
                task=task, server=s, t=self.now,
                predicted_s=t_eff[s] + self.queue_s[s],
                terms={"queue": float(self.queue_s[s]),
                       "latency": float(t_eff[s])},
                candidates=list(t_eff + self.queue_s))
            self.telemetry.join_measured(uid, total, completed=ok)
        self.queue_s[s] += lat
        self.health.record(s, lat, ok, self.now)
        self.now += 0.1
        rec = {"task": task, "server": s, "latency": total, "ok": ok,
               "hedged": hedged}
        self.log.append(rec)
        return rec

    # --------------------------------------------------------------- elastic
    def add_server(self, handle: ServerHandle):
        self.servers.append(handle)
        self.queue_s = np.append(self.queue_s, 0.0)
        h = HealthTracker(len(self.servers))
        h.ewma_lat[:-1] = self.health.ewma_lat
        h.fails[:-1] = self.health.fails
        h.dead_until[:-1] = self.health.dead_until
        self.health = h

    def remove_server(self, idx: int):
        del self.servers[idx]
        self.queue_s = np.delete(self.queue_s, idx)
        for arr_name in ("ewma_lat", "fails", "dead_until"):
            setattr(self.health, arr_name,
                    np.delete(getattr(self.health, arr_name), idx))
