"""Public surface of the continuum serving stack.

Light names import eagerly; the cluster harness (``Cluster``,
``EngineHandle``, ``SimEngine``, ``EngineBackend``, ``build_continuum``)
pulls in model building, so those resolve lazily via ``__getattr__`` —
``from repro.serving import Cluster`` works, but router-only / cost-model
consumers never pay the import.
"""
from repro.serving.engine import KVSnapshot, Request, ServingEngine  # noqa: F401
from repro.serving.request import ContinuumRequest, StreamEvent  # noqa: F401
from repro.serving.router import (  # noqa: F401
    HealthTracker,
    QLMIORouter,
    ServerHandle,
    SimulatedServer,
)
from repro.serving.telemetry import (  # noqa: F401
    MetricsRegistry,
    Telemetry,
    Tracer,
)

_LAZY = ("Cluster", "EngineHandle", "EngineBackend", "SimEngine",
         "build_continuum")

__all__ = ["ServingEngine", "Request", "KVSnapshot",
           "ContinuumRequest", "StreamEvent",
           "HealthTracker", "QLMIORouter", "ServerHandle",
           "SimulatedServer", "Telemetry", "MetricsRegistry", "Tracer",
           *_LAZY]


def __getattr__(name):
    if name in _LAZY:
        from repro.serving import cluster
        return getattr(cluster, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
