from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.router import (  # noqa: F401
    HealthTracker,
    QLMIORouter,
    ServerHandle,
    SimulatedServer,
)
