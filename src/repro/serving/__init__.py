from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.router import (  # noqa: F401
    HealthTracker,
    QLMIORouter,
    ServerHandle,
    SimulatedServer,
)
from repro.serving.telemetry import (  # noqa: F401
    MetricsRegistry,
    Telemetry,
    Tracer,
)

__all__ = ["ServingEngine", "HealthTracker", "QLMIORouter", "ServerHandle",
           "SimulatedServer", "Telemetry", "MetricsRegistry", "Tracer"]

# repro.serving.cluster (the continuum replay harness) is imported lazily
# by its users: it pulls in model building, which this package's light
# consumers (router-only tests, cost-model sims) should not pay for.
