"""Discrete-event cloud-edge continuum replay harness.

The offloading half of this repo (QLMIO router, CEMLLM-Sim episodes) used
to execute tasks against closed-form cost-model stubs; the serving half
(paged-KV + chunked-prefill ``ServingEngine``) was never in the decision
loop.  This module joins them: each ``EngineHandle`` wraps a **live**
``ServingEngine`` (small/fast reduced config for edge nodes, larger config
for the cloud tier) behind the network link of a quarantined
``DeviceProfile``, and a ``Cluster`` replays MIOBench arrival traces
against the fleet under a shared **virtual clock**:

  * the policy (QLMIO scoring, MILP/MGQP/greedy/all-cloud baselines via
    ``run_policy``) picks a server per task;
  * the harness ``submit()``s the request to that server's engine with the
    uplink delay applied, then advances every engine tick-by-tick;
  * one engine tick costs ``decode_tick_s`` virtual seconds (the roofline
    per-token decode time of the profiled hardware) plus
    ``prefill_tok_s`` per prompt token (computed + padding) the tick's
    chunked prefill actually ran — the engine generates *real* tokens
    while the clock charges the *profiled* device;
  * TTFT / ITL / e2e come from ``ServingEngine.latency_stats()`` in
    virtual-clock seconds (the engine's ``clock`` hook), and quality comes
    from the MIOBench success predictors, replacing
    ``SimulatedServer._execute``'s closed-form latency.

Multimodal requests ride the same harness: ``Cluster.submit`` accepts
typed segments (repro/serving/segments.py) and a ``media_delay_s`` charge,
and ``EngineHandle.split_point`` answers the per-request *split-point*
question — ship raw media and encode at this server, or encode on the
source edge device and ship keep-top-k-compressed features — from the
cost model's per-modality uplink/encode rooflines
(``cost_model.best_split``).

``EngineBackend`` plugs the harness into ``sim.cemllm.Episode`` with the
same interface as ``CostModelBackend``: dispatch-time estimates are the
cost-model numbers (so a deterministic policy takes identical decisions
under either backend), and ``drain()`` patches the episode records with
measured latencies once every engine has drained.

Observability (repro/serving/telemetry.py): pass ``telemetry=`` to
``build_continuum``/``Cluster`` to record uplink/media-encode/downlink
transfer spans, per-engine tick spans with true virtual durations, and a
dispatch audit — each routed request's predicted e2e with per-term
breakdown (``EngineHandle.predict_e2e_s``), joined with the measured e2e
at ``collect()`` so ``Telemetry.prediction_error`` reports cost-model
calibration.  ``Cluster.reset`` also resets every engine's metrics
registry, so per-replay stats stay independent.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import warnings
from collections import deque

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine, _PrefillTask
from repro.serving.kv_cache import ceil_blocks
from repro.serving.request import ContinuumRequest, StreamEvent
from repro.serving.router import ServerHandle
from repro.serving.telemetry import MetricsRegistry, latency_summary
from repro.sim import cost_model as cm
from repro.sim.cemllm import CostModelBackend
from repro.sim.miobench import SERVER_CLASSES

# live-engine arch per MIOBench server class (SERVER_CLASSES order):
# edge tiers run the small/fast config, the cloud tier a larger one.
CLASS_ARCHS = ["qwen2-0.5b", "qwen2-0.5b", "llama3.2-3b"]


class SimEngine:
    """Analytic drop-in for ``ServingEngine`` at fleet scale.

    A 100+ engine replay cannot afford 100 model builds + XLA compiles,
    and does not need them: the continuum harness charges virtual time
    from *counters* (decode ticks, prefill tokens computed), not from
    the numerical content of the tokens.  This class implements exactly
    the surface ``EngineHandle``/``Cluster``/``QLMIORouter._load`` read —
    queue/slots/prefill_tasks/budget, ``submit``/``step``/``busy``, the
    same metrics-registry counter names, streaming emission, and a
    page-granular prefix cache — while generating deterministic
    hash-derived tokens in plain Python.  ``paged`` is False, so
    ``kv_compatible`` correctly reports sim engines as non-migratable.

    Fidelity scope: chunked prefill under a per-tick token budget, one
    decode token per slot per tick, continuous batching, prefix reuse at
    ``page_size`` granularity.  Not modeled: KV pool pressure (admission
    never blocks on pages), bucketed-shape padding, KV snapshots.
    """

    def __init__(self, vocab: int, *, max_batch: int = 4,
                 max_seq: int = 256, eos_id: "int | None" = None,
                 prefill_chunk: int = 64,
                 prefill_budget: "int | None" = None,
                 page_size: int = 16, prefix_caching: bool = True,
                 clock=None, telemetry=None, trace_name: str = "sim"):
        self.vocab = vocab
        self._now = clock if clock is not None else (lambda: float(self.ticks))
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.paged = False
        self.kv_dtype = "bf16"
        self.chunked = prefill_chunk > 0
        self.prefill_chunk = max(prefill_chunk, 1)
        self.prefill_budget = (prefill_budget if prefill_budget is not None
                               else 2 * self.prefill_chunk)
        self.bucketing = False
        self.min_bucket = 1
        self.page_size = page_size
        self.prefix_caching = prefix_caching
        self._prefixes: set = set()  # hashes of page-aligned prompt prefixes
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.prefill_tasks: list[_PrefillTask | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int64)
        self.budget = np.zeros(max_batch, np.int64)
        self.ticks = 0
        self.finished: list[Request] = []
        self.telemetry = telemetry
        self.metrics = m = MetricsRegistry()
        self._c_prefill_computed = m.counter("prefill_tokens_computed")
        self._c_prefill_padded = m.counter("prefill_tokens_padded")
        self._c_prefix_reused = m.counter("prefix_tokens_reused")
        self._c_submitted = m.counter("requests_submitted")
        self._c_finished = m.counter("requests_finished")
        self._c_decode_tokens = m.counter("decode_tokens")
        self._c_stream_tokens = m.counter("stream_tokens")
        self._h_ttft = m.histogram("ttft_s")
        self._h_itl = m.histogram("itl_s")
        self._h_e2e = m.histogram("e2e_s")
        self._h_queue = m.histogram("queue_s")
        self._g_queue_depth = m.gauge("queue_depth")
        m.view("ticks", lambda: self.ticks)
        tr = telemetry.tracer if telemetry is not None else None
        self._tr = tr if (tr is not None and tr.enabled) else None
        self._pid = self._tr.process(trace_name) if self._tr else 0
        if telemetry is not None:
            telemetry.register_metrics(trace_name, m)
        self._auto_uid = 1_000_000_000

    # -- back-compat attribute accessors (EngineHandle tick charging)
    @property
    def prefill_tokens_computed(self) -> int:
        return self._c_prefill_computed.value

    @property
    def prefill_tokens_padded(self) -> int:
        return self._c_prefill_padded.value

    # ------------------------------------------------------------ intake
    def make_request(self, creq: ContinuumRequest,
                     uid: "int | None" = None) -> Request:
        if uid is None:
            self._auto_uid += 1
            uid = self._auto_uid
        tokens = (None if creq.tokens is None
                  else np.asarray(creq.tokens, np.int32))
        return Request(uid, tokens, max_new_tokens=int(creq.max_new_tokens),
                       extra=creq.extra, segments=creq.segments,
                       stream=creq.stream if callable(creq.stream) else None)

    def submit(self, req: "Request | ContinuumRequest") -> Request:
        if isinstance(req, ContinuumRequest):
            req = self.make_request(req)
        if req.tokens is None or len(req.tokens) < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.tokens) > self.max_seq - 1:
            raise ValueError(
                f"request {req.uid}: prompt of {len(req.tokens)} tokens "
                f"exceeds max_seq={self.max_seq} - 1")
        if not req.token_times:
            req.t_submit = self._now()
        self._c_submitted.inc()
        if self._tr is not None:
            self._tr.instant("submit", "lifecycle", req.t_submit,
                             pid=self._pid, tid=req.uid)
        self.queue.append(req)
        return req

    def busy(self) -> bool:
        return bool(self.queue or any(s is not None for s in self.slots)
                    or any(t is not None for t in self.prefill_tasks))

    # ------------------------------------------------------------ serving
    def _token(self, req: Request) -> int:
        """Deterministic hash-derived next token (seeded by uid + index,
        independent of which engine decodes — so a replay is bit-identical
        across routing policies and fleet layouts)."""
        i = len(req.output)
        return int((req.uid * 7919 + i * 104729 + 12345) % self.vocab)

    def _prefix_reuse(self, toks: np.ndarray) -> int:
        """Longest cached page-aligned prefix (capped at T-1, like the
        paged engine: the last token is always recomputed)."""
        if not self.prefix_caching:
            return 0
        T = len(toks)
        k = ((T - 1) // self.page_size) * self.page_size
        while k > 0:
            if hash(toks[:k].tobytes()) in self._prefixes:
                return k
            k -= self.page_size
        return 0

    def _register_prefix(self, toks: np.ndarray, upto: int):
        if not self.prefix_caching:
            return
        for k in range(self.page_size, upto + 1, self.page_size):
            self._prefixes.add(hash(toks[:k].tobytes()))

    def _emit(self, req: Request, tok: int, t: float, final: bool):
        idx = len(req.output) - 1
        if idx == 0 and self._tr is not None:
            self._tr.instant("first_token", "lifecycle", t,
                             pid=self._pid, tid=req.uid)
        if req.stream is None:
            return
        self._c_stream_tokens.inc()
        req.stream(StreamEvent(uid=req.uid, index=idx, token=tok, t_emit=t,
                               first=idx == 0, final=final))

    def _finish(self, req: Request):
        req.done = True
        self.finished.append(req)
        self._c_finished.inc()
        tt = req.token_times
        ta = req.t_admit if req.t_admit >= req.t_submit else req.t_submit
        self._h_queue.observe(ta - req.t_submit)
        self._h_ttft.observe(tt[0] - req.t_submit)
        self._h_e2e.observe(tt[-1] - req.t_submit)
        if len(tt) > 1:
            self._h_itl.extend(b - a for a, b in zip(tt, tt[1:]))
        if self._tr is not None:
            pid, tid = self._pid, req.uid
            self._tr.span("queue", "lifecycle", req.t_submit, ta,
                          pid=pid, tid=tid)
            self._tr.span("prefill", "lifecycle", ta, tt[0], pid=pid,
                          tid=tid, args={"prompt_tokens": len(req.tokens)})
            self._tr.span("decode", "lifecycle", tt[0], tt[-1], pid=pid,
                          tid=tid, args={"new_tokens": len(req.output)})

    def _activate(self, slot: int, req: Request):
        tok = self._token(req)
        req.output.append(tok)
        req.token_times.append(self._now())
        ends = (req.max_new_tokens <= 1
                or (self.eos_id is not None and tok == self.eos_id))
        self._emit(req, tok, req.token_times[-1], ends)
        if ends:
            self._finish(req)
            return
        self.slots[slot] = req
        self.pos[slot] = len(req.tokens)
        self.budget[slot] = req.max_new_tokens - 1

    def step(self) -> int:
        """One engine tick, same contract as ``ServingEngine.step``: spend
        the prefill budget (admitting queued requests into free slots),
        then one decode token for every fully-prefilled slot."""
        budget = self.prefill_budget
        while budget > 0:
            progressed = False
            if self.queue:
                free = next((i for i in range(self.max_batch)
                             if self.slots[i] is None
                             and self.prefill_tasks[i] is None), None)
                if free is not None:
                    req = self.queue.popleft()
                    req.t_admit = self._now()
                    toks = np.asarray(req.tokens)
                    reuse = self._prefix_reuse(toks)
                    self._c_prefix_reused.inc(reuse)
                    self.prefill_tasks[free] = _PrefillTask(
                        req, done=reuse, reused=reuse)
                    progressed = True
            for slot in range(self.max_batch):
                if budget <= 0:
                    break
                task = self.prefill_tasks[slot]
                if task is None:
                    continue
                T = len(task.req.tokens)
                n = min(self.prefill_chunk, T - task.done, budget)
                task.done += n
                budget -= n
                self._c_prefill_computed.inc(n)
                progressed = True
                if task.done >= T:
                    toks = np.asarray(task.req.tokens)
                    self._register_prefix(
                        toks, ((T // self.page_size) * self.page_size))
                    self.prefill_tasks[slot] = None
                    self._activate(slot, task.req)
            if not progressed:
                break
        self._g_queue_depth.set(len(self.queue))
        active = [i for i, r in enumerate(self.slots) if r is not None]
        n_prefilling = sum(t is not None for t in self.prefill_tasks)
        if self._tr is not None:
            self._tr.counter("queue_depth", self._now(),
                             {"queued": len(self.queue),
                              "active": len(active) + n_prefilling},
                             pid=self._pid)
        if not active:
            if n_prefilling:
                self.ticks += 1
            return n_prefilling
        self.ticks += 1
        self._c_decode_tokens.inc(len(active))
        t_now = self._now()
        for i in active:
            req = self.slots[i]
            tok = self._token(req)
            req.output.append(tok)
            req.token_times.append(t_now)
            self.pos[i] += 1
            self.budget[i] -= 1
            ends = bool(self.budget[i] <= 0 or tok == self.eos_id
                        or self.pos[i] >= self.max_seq - 1)
            self._emit(req, tok, t_now, ends)
            if ends:
                self._finish(req)
                self.slots[i] = None
                self.pos[i] = 0
        return len(active) + n_prefilling

    def run_until_drained(self, max_ticks: int = 10_000,
                          keep_finished: bool = False):
        deadline = self.ticks + max_ticks
        while self.busy():
            self.step()
            if self.ticks > deadline:
                raise RuntimeError("engine did not drain")
        if keep_finished:
            return list(self.finished)
        out, self.finished = self.finished, []
        return out

    def reset_prefix_cache(self):
        if self.busy():
            raise RuntimeError("reset_prefix_cache needs an idle engine")
        self._prefixes.clear()

    # -------------------------------------------------------------- stats
    def latency_stats(self) -> dict:
        """Alias for ``stats()["latency"]`` (same contract as
        ``ServingEngine.latency_stats``)."""
        return latency_summary(self._h_ttft.values, self._h_itl.values,
                               self._h_e2e.values)

    def stats(self) -> dict:
        out = {"paged": False, "kv_dtype": self.kv_dtype,
               "bucketed": False, "chunked": self.chunked, "sim": True}
        out.update(self.metrics.snapshot())
        out["latency"] = self.latency_stats()
        return out


class EngineHandle(ServerHandle):
    """One continuum server: a live ``ServingEngine`` under a virtual clock.

    The engine's ``clock`` hook reads ``self.vtime``, so every request
    timestamp (``t_submit`` / ``token_times``) — and therefore
    ``latency_stats()`` — is in virtual seconds.  Doubles as a plain
    ``ServerHandle``: ``execute`` runs one task synchronously (legacy
    router path) and ``load`` reports live queue depth, in-flight prefill
    tokens and estimated drain time for the router's scoring.
    """

    def __init__(self, name: str, arch: str, device: cm.DeviceProfile,
                 profile: cm.ModelProfile, *, is_cloud: bool = False,
                 seed: int = 0, max_batch: int = 2, max_seq: int = 96,
                 time_scale: float = 1.0, payload_bytes: float | None = None,
                 kv_dtype: str | None = None, fail: bool = False,
                 draft_profile: "cm.ModelProfile | None" = None,
                 draft_device: "cm.DeviceProfile | None" = None,
                 spec_k: int = 3, tp: int = 1,
                 telemetry=None, backend: str = "live", **engine_kw):
        """``draft_profile`` turns on speculative decoding for this
        handle: the live engine drafts with a small same-arch model and
        verifies with the paged multi-token kernel, while the virtual
        clock charges ``cost_model.speculative_tick_s`` — ``spec_k``
        draft steps priced as ``draft_profile`` on ``draft_device``
        (None = colocated on this handle's device; an edge device here
        is the edge-drafts/cloud-verifies offloading shape, where only
        token ids ride the uplink) plus one multi-token verify pass of
        this handle's own profile.  Live backend only.

        ``tp`` is the handle's tensor-parallel mesh width — a continuum
        routing axis: the live engine shards over a ``tp``-wide host mesh
        (distributed/tp.py; bit-identical tokens), and the tick costs
        switch to the cost model's TP rooflines (bytes and FLOPs divided
        by ``tp`` plus the per-layer collective term on ``ici_bw``), so
        the router prices mesh width exactly like every other knob."""
        cfg = reduced(get_config(arch))
        self.cfg = cfg
        self.backend = backend
        self.tp = tp
        self.vtime = 0.0
        self.time_scale = time_scale
        self.draft_profile = draft_profile
        self.draft_device = draft_device if draft_device is not None \
            else device
        if draft_profile is not None:
            if backend != "live":
                raise ValueError(
                    "speculative decoding (draft_profile=...) needs the "
                    "live engine backend")
            engine_kw.setdefault("draft_config", cfg)
            engine_kw.setdefault("spec_k", spec_k)
        # KV precision is itself an offloading decision: edge tiers
        # default to the int8 page pool (half the decode KV stream, ~2x
        # the page budget per HBM byte — what makes the weak tiers worth
        # routing to), the cloud tier keeps bf16.  The profiled tick cost
        # below prices the choice, so the router sees it through every
        # backlog/latency estimate.  Quantized pages need the paged
        # backend, so recurrent/hybrid archs (dense cache) stay bf16.
        if backend == "sim":
            # fleet-scale analytic engine: no weights, no XLA — the tick
            # *costs* below still come from the profiled roofline, so the
            # router sees the same continuum either way
            if kv_dtype is None:
                kv_dtype = "bf16" if is_cloud else "int8"
            self.kv_dtype = kv_dtype
            self.engine = SimEngine(cfg.vocab, max_batch=max_batch,
                                    max_seq=max_seq,
                                    clock=lambda: self.vtime,
                                    telemetry=telemetry, trace_name=name,
                                    **engine_kw)
            self.engine.kv_dtype = kv_dtype
        elif backend == "live":
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(seed))
            if kv_dtype is None:
                kv_dtype = ("int8" if model.supports_paged and not is_cloud
                            else "bf16")
            self.kv_dtype = kv_dtype
            if draft_profile is not None:
                # default draft weights = the target's own (the reduced
                # live config is the "small" model already); acceptance
                # is whatever the two numerical paths agree on, and the
                # emitted stream is bit-identical regardless
                engine_kw.setdefault("draft_params", params)
            if tp > 1:
                from repro.distributed.tp import serving_mesh
                engine_kw.setdefault("mesh", serving_mesh(tp))
            self.engine = ServingEngine(model, params, max_batch=max_batch,
                                        max_seq=max_seq, kv_dtype=kv_dtype,
                                        clock=lambda: self.vtime,
                                        telemetry=telemetry, trace_name=name,
                                        **engine_kw)
        else:
            raise ValueError(f"unknown backend {backend!r} "
                             "(expected 'live' or 'sim')")
        self.telemetry = telemetry
        tr = telemetry.tracer if telemetry is not None else None
        self._tr = tr if (tr is not None and tr.enabled) else None
        self._pid = self._tr.process(name) if self._tr else 0
        self.device = device
        self.profile = profile
        eff = device.flops * cm._EFF
        bw = device.mem_bw * cm._EFF
        # per-tick decode roofline: active weights + the resident KV
        # context (nominal half-full sequences) at this tier's precision
        kv_stream = cm.kv_bytes_per_token(profile, kv_dtype) * (max_seq / 2)
        self.decode_tick_s = (time_scale * (profile.n_active
                                            * profile.bytes_per_param
                                            + kv_stream) / bw)
        self.prefill_tok_s = time_scale * 2.0 * profile.n_active / eff
        if tp > 1:
            # TP rooflines replace the single-device ticks (the tp=1
            # expressions above stay verbatim so every calibrated replay
            # is bitwise untouched when the knob is off)
            self.decode_tick_s = time_scale * float(cm.decode_s(
                device, profile, 1.0, context_tokens=max_seq / 2,
                kv_dtype=kv_dtype, tp=tp))
            self.prefill_tok_s = time_scale * float(cm.prefill_s(
                device, profile, 1.0, tp=tp))
        # speculative handles charge the spec tick (k drafts priced as
        # draft_profile on draft_device + one multi-token verify here)
        # instead of the plain decode tick; each tick then emits 1..k+1
        # tokens, which is where the effective-ITL win comes from
        self.spec_k = spec_k
        if draft_profile is not None:
            self.spec_tick_s = float(time_scale * cm.speculative_tick_s(
                device, profile, draft_profile, spec_k,
                context_tokens=max_seq / 2, kv_dtype=kv_dtype,
                draft_device=self.draft_device, tp=tp))
            self._tick_s = self.spec_tick_s
        else:
            self.spec_tick_s = None
            self._tick_s = self.decode_tick_s
        # payload (default: the cost model's text+image request) split
        # evenly between request and response; both halves priced by the
        # shared cost-model link helper
        if payload_bytes is None:
            payload_bytes = cm.payload_bytes()
        self.up_s = float(cm.uplink_s(payload_bytes / 2, device))
        self.down_s = float(cm.downlink_s(payload_bytes / 2, device))
        # one streamed token chunk's downlink time: what a streamed
        # request pays at the tail instead of the full-payload downlink
        self.stream_chunk_s = float(cm.stream_chunk_s(device))
        self.fail = fail
        self.pending: list = []  # min-heap of (t_ready, seq, Request)
        self._seq = 0
        # invoked after every charged engine tick (Cluster wires this to
        # its migration scheduler so planned evacuations fire between
        # ticks, at a consistent engine state)
        self.on_step = None
        # invoked on enqueue (Cluster wires this to its event heap so a
        # newly-arrived / migrated request wakes an otherwise-idle handle)
        self.on_enqueue = None
        # KV pages moved to / from other engines, in wire bytes (priced
        # at the *receiving* side's page precision)
        self._c_mig_in = self.engine.metrics.counter("kv_migrate_in_bytes")
        self._c_mig_out = self.engine.metrics.counter("kv_migrate_out_bytes")
        super().__init__(name=name,
                         model_id=cm.MODEL_IDS.index(profile.name),
                         device_id=cm.DEVICE_IDS.index(device.name),
                         is_cloud=is_cloud, execute=self._execute_sync,
                         load=self._load)

    # ------------------------------------------------------- network link
    def uplink_s(self) -> float:
        return self.up_s

    def downlink_s(self) -> float:
        return self.down_s

    # ------------------------------------------------------- migration
    def kv_compatible(self, other: "EngineHandle") -> bool:
        """Whether a KV snapshot exported here can be imported by
        ``other``: both paged, same vocabulary, same KV geometry
        (layers, kv heads, head dim) and page size.  Structural check
        only — bit-identical resumed tokens additionally require the two
        engines to share weights (``build_continuum(param_seed=...)``)."""
        e, o = self.engine, other.engine
        return (e.paged and o.paged
                and self.cfg.vocab == other.cfg.vocab
                and e.model.kv_geometry == o.model.kv_geometry
                and e.page_size == o.page_size)

    # ------------------------------------------------------- split point
    def split_point(self, spec: cm.MediaSpec,
                    src: cm.DeviceProfile) -> "tuple[str, float]":
        """Where to encode ``spec``'s media for a request bound to this
        server: ``("raw", s)`` — ship raw media, encode here — or
        ``("edge", s)`` — encode on the source device ``src``, ship
        compressed features.  ``s`` is the extra virtual seconds the
        chosen split adds on top of the request's base uplink; pass it to
        ``Cluster.submit(media_delay_s=...)``."""
        return cm.best_split(spec, src, self.device)

    def split_delay_s(self, spec: cm.MediaSpec, src: cm.DeviceProfile,
                      choice: str) -> float:
        """Extra virtual seconds of a *forced* split choice (the fixed
        all-raw-ship / all-edge-encode baseline policies)."""
        return cm.split_point_s(spec, src, self.device)[choice]

    # ---------------------------------------------------- virtual stepping
    def enqueue(self, req: Request, t_ready: float):
        """Queue a request to reach this server at virtual time t_ready."""
        heapq.heappush(self.pending, (t_ready, self._seq, req))
        self._seq += 1
        if self.on_enqueue is not None:
            self.on_enqueue(self)

    def busy(self) -> bool:
        return self.engine.busy()

    def _admit_ready(self):
        while self.pending and self.pending[0][0] <= self.vtime + 1e-12:
            _, _, req = heapq.heappop(self.pending)
            self.engine.submit(req)  # t_submit stamps self.vtime

    def next_wake_s(self) -> float:
        """Virtual time of this handle's next chargeable event: now if the
        engine holds admitted work, the head arrival if only pending, +inf
        if idle or failed.  The cluster's event heap keys on this, so an
        idle handle costs nothing to advance past — the O(active)
        property the 100-engine replay rests on."""
        if self.fail:
            return math.inf
        if self.busy():
            return self.vtime
        if self.pending:
            return max(self.pending[0][0], self.vtime)
        return math.inf

    def step_once(self, t: float) -> bool:
        """Run at most ONE charged engine tick without crossing ``t``.

        A tick is charged its dynamic cost (decode step + prefill tokens
        it computed), so it may overshoot ``t`` by less than one tick.
        An idle engine first fast-forwards to its next arrival; a failed
        server never steps (its requests time out at drain).  Returns
        True iff a tick was charged — the caller must then re-read
        ``next_wake_s()``."""
        if self.fail:
            return False
        self._admit_ready()
        if not self.busy():
            nxt = self.pending[0][0] if self.pending else math.inf
            if nxt >= t - 1e-12:  # nothing to do before t
                return False
            self.vtime = max(self.vtime, nxt)
            self._admit_ready()
        if not self.busy() or self.vtime >= t - 1e-12:
            return False
        e = self.engine
        p0 = e.prefill_tokens_computed + e.prefill_tokens_padded
        n_busy = e.step()
        dp = e.prefill_tokens_computed + e.prefill_tokens_padded - p0
        dt = self._tick_s + dp * self.prefill_tok_s
        if self._tr is not None:
            # engine-side spans within one tick are zero-width under
            # the virtual clock (vtime advances *after* the step);
            # this span carries the tick's true virtual duration
            self._tr.span("tick", "engine", self.vtime,
                          self.vtime + dt, pid=self._pid,
                          args={"prefill_tokens": dp, "busy": n_busy})
        self.vtime += dt
        if self.on_step is not None:
            self.on_step(self)
        return True

    def advance_to(self, t: float):
        """Run whole engine ticks until the virtual clock reaches ``t``
        (standalone-handle driver; the cluster drives ``step_once``
        through its event heap instead)."""
        while self.step_once(t):
            pass
        self.vtime = max(self.vtime, t)

    # ------------------------------------------------------------- probes
    def itl_s(self) -> float:
        """Effective virtual seconds per emitted token: the plain decode
        tick, or — for a speculative handle — the spec tick amortized
        over the expected accepted prefix at the engine's *live measured*
        acceptance rate (telemetry feeding back into prediction)."""
        if self.spec_tick_s is None:
            return self.decode_tick_s
        k = getattr(self.engine, "spec_k", self.spec_k)
        a = self.engine.acceptance_rate()
        return float(self.spec_tick_s / cm.expected_accepted(k, a))

    def _load(self) -> dict:
        """Live congestion for the router's ``_effective_latency``: queued
        + running request count, prompt tokens not yet in any KV cache,
        and the estimated virtual seconds to drain all of it."""
        e = self.engine
        waiting = list(e.queue) + [r for _, _, r in self.pending]
        active = [r for r in e.slots if r is not None]
        tasks = [t for t in e.prefill_tasks if t is not None]
        inflight = (sum(len(t.req.tokens) - t.done for t in tasks)
                    + sum(len(r.tokens) for r in waiting))
        decode_ticks = max((int(e.budget[i]) for i, r in enumerate(e.slots)
                            if r is not None), default=0)
        decode_ticks += -(-sum(r.max_new_tokens for r in waiting)
                          // max(e.max_batch, 1))
        backlog = (inflight * self.prefill_tok_s
                   + decode_ticks * self.itl_s())
        return {"queue_depth": len(waiting) + len(active) + len(tasks),
                "inflight_prefill_tokens": int(inflight),
                "backlog_s": float(backlog)}

    def predict_e2e_s(self, prompt_tokens: int, max_new_tokens: int, *,
                      media_delay_s: float = 0.0) -> "tuple[float, dict]":
        """Predicted end-to-end virtual seconds for a request dispatched
        to this server *now*, decomposed per term — the dispatch-audit
        record ``Telemetry.prediction_error`` calibrates against measured
        e2e.  Built from the same per-tick costs ``advance_to`` charges
        (harness scale), so the error measures congestion/interleaving
        mispredictions, not the replay's deliberate scale-down vs. the
        paper-scale cost model.  Call *before* ``Cluster.submit`` so the
        queue term excludes the request itself."""
        e = self.engine
        queue = self._load()["backlog_s"]
        n_pref = float(cm.chunked_prefill_tokens(
            prompt_tokens, e.prefill_chunk if e.chunked else 0,
            minimum=e.min_bucket if e.bucketing else 1))
        terms = {"queue": queue,
                 "prefill": n_pref * self.prefill_tok_s,
                 "decode": max_new_tokens * self.itl_s(),
                 "media": float(media_delay_s),
                 "link": self.up_s + self.down_s}
        return sum(terms.values()), terms

    def _execute_sync(self, task: int) -> "tuple[float, bool]":
        """Legacy ``ServerHandle.execute``: run one task to completion on
        this engine alone; returns virtual seconds including the link."""
        if self.fail:
            return 4 * cm.TIMEOUT_S, False
        rng = np.random.default_rng((task, self.model_id, 7))
        prompt = rng.integers(0, self.cfg.vocab, 16).astype(np.int32)
        req = Request(-1 - task, prompt, max_new_tokens=6)
        t0 = self.vtime
        self.enqueue(req, self.vtime + self.uplink_s())
        deadline = t0 + 4 * cm.TIMEOUT_S
        stride = self.uplink_s() + 8 * self.decode_tick_s
        while not req.done and self.vtime < deadline:
            self.advance_to(self.vtime + stride)
        return self.vtime - t0 + self.downlink_s(), req.done


class Cluster:
    """Shared-virtual-clock harness over a list of ``EngineHandle``s.

    ``submit`` routes a request (a typed ``ContinuumRequest``, or the
    deprecated positional kwargs) to a server; ``advance_to`` moves the
    fleet to a common virtual time by replaying engine ticks in global
    event order off a min-heap of per-handle wake times — O(events on
    *active* engines), so a 100-engine fleet with three busy servers
    costs the same to advance as a 3-engine one; ``stream`` does the
    same while yielding ``StreamEvent``s as tokens decode; ``drain``
    runs all engines until every submitted request finished or the
    timeout horizon passed; ``collect`` returns the measured
    per-request records.
    """

    def __init__(self, handles: "list[EngineHandle]",
                 timeout_s: float = cm.TIMEOUT_S, telemetry=None):
        self.handles = handles
        self.timeout_s = timeout_s
        self.t = 0.0
        self.records: dict[int, dict] = {}
        self._uid = 0
        # uid -> destination handle index of a planned disaggregated
        # dispatch (prefill where submitted, decode there); executed by
        # _on_engine_step as soon as the request reaches decode phase
        self._planned: dict[int, int] = {}
        # event heap of (wake_s, seq, handle_idx, entry_ver) — lazy
        # deletion: entries are cheap to push, and an entry whose version
        # no longer matches the handle's is stale and falls out on pop
        self._heap: "list[tuple[float, int, int, int]]" = []
        self._hseq = 0
        # charged engine ticks / heap pops across the fleet — the
        # O(active) scaling probe fig13 gates on
        self.handle_steps = 0
        self.heap_pops = 0
        # StreamEvents buffered for Cluster.stream() (requests submitted
        # with stream=True rather than a callback)
        self._stream_buf: "deque[StreamEvent]" = deque()
        for i, h in enumerate(handles):
            h._cluster_idx = i
            h._heap_ver = 0
            h.on_step = self._on_engine_step
            h.on_enqueue = self._wake
        # default to the handles' shared telemetry so callers building via
        # build_continuum(telemetry=...) need not pass it twice
        if telemetry is None:
            telemetry = next((h.telemetry for h in handles
                              if h.telemetry is not None), None)
        self.telemetry = telemetry
        tr = telemetry.tracer if telemetry is not None else None
        self._tr = tr if (tr is not None and tr.enabled) else None

    # ------------------------------------------------------------ intake
    def submit(self, server=None, task=None, tokens=None,
               max_new_tokens=None, t_arrival: float = 0.0,
               quality_ok: bool = True, segments=None,
               media_delay_s: float = 0.0,
               decode_server: "int | None" = None,
               stream=None) -> int:
        """Dispatch one request; returns its uid.

        The typed form — ``submit(ContinuumRequest(...))`` — is the API:
        the request carries prompt, arrival, media split, stream sink and
        the router's plan annotations (``server`` must be set; route it
        through ``QLMIORouter.plan`` or set it explicitly).  The request
        reaches the engine after the uplink delay (+ ``media_delay_s``,
        the chosen split point's edge-encode/serialization cost), so
        measured TTFT/e2e include where the media crossed the continuum.
        ``decode_server`` plans the disaggregated shape: prefill on
        ``server``, then — as soon as the request reaches decode phase —
        its KV snapshot migrates over the device link (charged on the
        virtual clock, ``kv_migrate`` span) and decode resumes there.
        ``quality_ok`` is the success-predictor verdict for (task,
        server) — generated tokens are real but random, so answer quality
        is judged by the predictor, as in the sim.

        ``stream`` (``ContinuumRequest.stream``): a callable receives a
        ``StreamEvent`` per decoded token as it decodes (``t_user``
        stamped with the streamed chunk's downlink); ``True`` buffers the
        events for ``Cluster.stream()``.  Streamed requests pay one
        chunk's downlink at the tail instead of the full payload —
        earlier chunks overlap decoding.

        The legacy positional/kwarg form (``submit(server, task, tokens,
        max_new_tokens, t_arrival, ...)``) still works through a shim
        that builds the ``ContinuumRequest`` and emits a
        ``DeprecationWarning``."""
        if isinstance(server, ContinuumRequest):
            return self._submit_typed(server)
        warnings.warn(
            "Cluster.submit(server, task, tokens, ...) kwargs are "
            "deprecated; pass a ContinuumRequest (repro.serving.request)",
            DeprecationWarning, stacklevel=2)
        return self._submit_typed(ContinuumRequest(
            tokens=tokens, segments=segments,
            max_new_tokens=int(max_new_tokens), arrival_s=float(t_arrival),
            task=int(task), quality_ok=bool(quality_ok),
            media_delay_s=float(media_delay_s), stream=stream,
            server=int(server), decode_server=decode_server))

    def _submit_typed(self, creq: ContinuumRequest) -> int:
        if creq.server is None:
            raise ValueError(
                "ContinuumRequest.server is unset — annotate the request "
                "with a routing decision (QLMIORouter.plan(creq)) or set "
                "server= explicitly")
        server = int(creq.server)
        decode_server = creq.decode_server
        h = self.handles[server]
        if decode_server is not None and decode_server != server:
            if not h.kv_compatible(self.handles[decode_server]):
                raise ValueError(
                    f"cannot plan prefill on {h.name} / decode on "
                    f"{self.handles[decode_server].name}: KV-incompatible "
                    "engines (geometry, page size, or cache backend)")
        if creq.draft_server is not None:
            hv = self.handles[decode_server if decode_server is not None
                              else server]
            if hv.spec_tick_s is None:
                raise ValueError(
                    f"cannot plan drafts on "
                    f"{self.handles[creq.draft_server].name} for "
                    f"{hv.name}: the verify handle is not speculative "
                    "(build it with draft_profile=...)")
        self._uid += 1
        uid = self._uid
        req = h.engine.make_request(creq, uid=uid)
        rec = {"uid": uid, "task": creq.task, "server": server,
               "t_arrival": creq.arrival_s, "req": req,
               "quality_ok": bool(creq.quality_ok),
               "draft_server": creq.draft_server,
               "predicted_s": creq.predicted_s, "utility": creq.utility}
        streamed = creq.stream is not None and creq.stream is not False
        if streamed:
            rec["streamed"] = True
            user_cb = creq.stream if callable(creq.stream) else None

            def deliver(ev: StreamEvent, _rec=rec, _user=user_cb):
                # the *current* holder prices the chunk — a mid-stream
                # migration moves the downlink to the resumed engine
                hh = self.handles[_rec["server"]]
                ev = dataclasses.replace(
                    ev, t_user=ev.t_emit + hh.stream_chunk_s)
                if _user is not None:
                    _user(ev)
                else:
                    self._stream_buf.append(ev)

            req.stream = deliver
        self.records[uid] = rec
        t_arrival, media_delay_s = creq.arrival_s, creq.media_delay_s
        h.enqueue(req, t_arrival + h.uplink_s() + media_delay_s)
        if self._tr is not None:
            tr, pid = self._tr, h._pid
            t1 = t_arrival + h.uplink_s()
            tr.span("uplink", "transfer", t_arrival, t1, pid=pid, tid=uid,
                    args={"task": int(creq.task)})
            if media_delay_s:
                tr.span("media_encode", "transfer", t1,
                        t1 + media_delay_s, pid=pid, tid=uid)
        if decode_server is not None and decode_server != server:
            self._planned[uid] = int(decode_server)
        return uid

    # --------------------------------------------------- event-heap clock
    def busy(self) -> bool:
        return any(h.busy() or h.pending for h in self.handles)

    def _wake(self, h: EngineHandle):
        """(EngineHandle.on_enqueue) arm the handle's next wake time on
        the event heap — an arrival or migration onto an idle handle
        becomes a heap event so the event loop revisits it.  Each push
        bumps the handle's entry version: at most one entry per handle is
        *canonical*; superseded ones drop on pop without re-arming, so
        heap traffic stays linear in (ticks + arrivals)."""
        w = h.next_wake_s()
        if w == math.inf:
            return
        h._heap_ver += 1
        heapq.heappush(self._heap, (w, self._hseq, h._cluster_idx,
                                    h._heap_ver))
        self._hseq += 1

    def _step_next(self, t: float) -> bool:
        """Charge the single earliest pending engine tick strictly before
        ``t``; returns False once no handle has an event before ``t``.
        A migration fired inside the tick enqueues onto the peer handle,
        which arms a fresh heap entry — so cross-engine causality holds
        without a lockstep quantum."""
        while self._heap:
            w, _, idx, ver = self._heap[0]
            if w >= t - 1e-9:
                return False
            heapq.heappop(self._heap)
            self.heap_pops += 1
            h = self.handles[idx]
            if ver != h._heap_ver:
                continue  # superseded by a newer arm for this handle
            w2 = h.next_wake_s()
            if w2 >= t - 1e-9 or w2 > w + 1e-9:
                self._wake(h)  # re-arm at the corrected time (noop if inf)
                continue
            if h.step_once(t):
                self.handle_steps += 1
            self._wake(h)
            return True
        return False

    def advance_to(self, t: float, step_s: float | None = None):
        """Advance the whole fleet to virtual time ``t`` in global event
        order.  ``step_s`` is accepted for back-compat and ignored — the
        event heap makes a sync quantum unnecessary."""
        del step_s
        if t <= self.t:
            return
        while self._step_next(t):
            pass
        self.t = t

    def stream(self, until: float):
        """Advance the fleet to virtual time ``until``, yielding buffered
        ``StreamEvent``s (requests submitted with ``stream=True``) in
        emission order as engines decode them.  Events carry ``t_user``
        — arrival at the user after the streamed chunk's downlink.
        Requests with a ``stream`` *callback* are delivered inline
        instead and do not appear here."""
        if until > self.t:
            while True:
                progressed = self._step_next(until)
                while self._stream_buf:
                    yield self._stream_buf.popleft()
                if not progressed:
                    break
            self.t = until
        while self._stream_buf:
            yield self._stream_buf.popleft()

    # ------------------------------------------------------- migration
    def _on_engine_step(self, h: EngineHandle):
        """Per-tick hook (EngineHandle.on_step): execute planned
        prefill-here/decode-there handoffs whose request just reached
        decode phase on ``h``.  A request may decode a token or two here
        before the hook sees it — the snapshot resumes at exactly
        ``output[-1]`` either way, so no work is lost or repeated."""
        if not self._planned:
            return
        for uid in list(self._planned):
            rec = self.records.get(uid)
            if rec is None or self.handles[rec["server"]] is not h:
                continue
            req = rec["req"]
            if req.done:
                del self._planned[uid]  # finished before the handoff fired
                continue
            if req.output and h.engine.slot_of_request(uid) is not None:
                dst = self._planned.pop(uid)
                self.migrate(uid, dst)

    def migrate(self, uid: int, dst: int) -> dict:
        """Evacuate request ``uid`` from the engine currently holding it
        and resume it on handle ``dst``, charging the KV transfer on the
        virtual clock: wire bytes are the non-cached snapshot pages at the
        **destination's** page precision (int8 tiers pay ~half), link time
        is the cost model's server-to-server roofline, and the transfer is
        visible as a ``kv_migrate`` span.  Returns the move record."""
        rec = self.records[uid]
        src = rec["server"]
        src_h, dst_h = self.handles[src], self.handles[dst]
        if not src_h.kv_compatible(dst_h):
            raise ValueError(
                f"cannot migrate request {uid}: {src_h.name} and "
                f"{dst_h.name} are KV-incompatible")
        req, snap = src_h.engine.evacuate(uid)
        n_cached = (len(dst_h.engine.pool.peek_hashes(snap.prefix_hashes))
                    if dst_h.engine.prefix_caching else 0)
        n_wire = max(snap.num_pages - n_cached, 0)
        nbytes = n_wire * dst_h.engine.page_bytes()
        mig_s = float(cm.migrate_link_s(nbytes, src_h.device, dst_h.device))
        t0 = src_h.vtime
        dst_h.enqueue(req, t0 + mig_s)
        rec["server"] = dst
        src_h._c_mig_out.inc(nbytes)
        dst_h._c_mig_in.inc(nbytes)
        if self._tr is not None:
            self._tr.span("kv_migrate", "transfer", t0, t0 + mig_s,
                          pid=dst_h._pid, tid=uid,
                          args={"bytes": int(nbytes), "pages": int(n_wire),
                                "tokens": int(snap.num_tokens),
                                "src": src_h.name, "dst": dst_h.name})
        return {"uid": uid, "src": src, "dst": dst, "bytes": int(nbytes),
                "pages": int(n_wire), "migrate_s": mig_s, "t": t0}

    def rebalance(self, threshold_s: float, *,
                  min_gain_s: float = 0.0) -> "list[dict]":
        """Mid-stream evacuation policy: for every engine whose backlog
        exceeds ``threshold_s``, consider moving its decoding request with
        the most generation budget left to the KV-compatible handle where
        (migration + remaining decode + queueing) beats staying local by
        more than ``min_gain_s``.  Returns the executed move records."""
        loads = [h._load()["backlog_s"] for h in self.handles]
        moves = []
        for i, src_h in enumerate(self.handles):
            if src_h.fail or loads[i] <= threshold_s:
                continue
            e = src_h.engine
            cands = [(int(e.budget[s]), s, r.uid)
                     for s, r in enumerate(e.slots)
                     if r is not None and r.output and int(e.budget[s]) > 0]
            if not cands:
                continue
            remaining, slot, uid = max(cands)
            n_ctx = int(e.pos[slot])
            best = None
            for j, dst_h in enumerate(self.handles):
                if j == i or dst_h.fail or not src_h.kv_compatible(dst_h):
                    continue
                pages = ceil_blocks(n_ctx, dst_h.engine.page_size)
                mig = float(cm.migrate_link_s(
                    pages * dst_h.engine.page_bytes(),
                    src_h.device, dst_h.device))
                t_move = (mig + remaining * dst_h.itl_s()
                          + 0.5 * loads[j])
                if best is None or t_move < best[0]:
                    best = (t_move, j)
            if best is None:
                continue
            t_stay = remaining * src_h.itl_s() + 0.5 * loads[i]
            if t_stay - best[0] > min_gain_s:
                self._planned.pop(uid, None)  # superseded by this move
                moves.append(self.migrate(uid, best[1]))
                loads[i] = src_h._load()["backlog_s"]
        return moves

    def predict_disagg_e2e_s(self, prefill: int, decode: int,
                             prompt_tokens: int, max_new_tokens: int, *,
                             media_delay_s: float = 0.0
                             ) -> "tuple[float, dict]":
        """Predicted e2e of the disaggregated dispatch shape — prefill on
        handle ``prefill``, KV migration, decode on handle ``decode`` —
        decomposed per term; the third shape ``QLMIORouter.plan`` prices
        against pure-edge and pure-cloud.  Mirrors
        ``EngineHandle.predict_e2e_s`` (same tick-cost scale)."""
        hp, hd = self.handles[prefill], self.handles[decode]
        ep, ed = hp.engine, hd.engine
        n_pref = float(cm.chunked_prefill_tokens(
            prompt_tokens, ep.prefill_chunk if ep.chunked else 0,
            minimum=ep.min_bucket if ep.bucketing else 1))
        pages = ceil_blocks(prompt_tokens + 1, ed.page_size)
        mig = float(cm.migrate_link_s(pages * ed.page_bytes(),
                                      hp.device, hd.device))
        terms = {"queue": hp._load()["backlog_s"],
                 "prefill": n_pref * hp.prefill_tok_s,
                 "migrate": mig,
                 "queue_decode": hd._load()["backlog_s"],
                 "decode": max_new_tokens * hd.decode_tick_s,
                 "media": float(media_delay_s),
                 "link": hp.up_s + hd.down_s}
        return sum(terms.values()), terms

    def predict_spec_e2e_s(self, draft: int, verify: int,
                           prompt_tokens: int, max_new_tokens: int, *,
                           media_delay_s: float = 0.0
                           ) -> "tuple[float, dict] | None":
        """Predicted e2e of the *speculative* dispatch shape — handle
        ``draft``'s device prices the per-tick draft steps while handle
        ``verify`` runs prefill + multi-token verification — decomposed
        per term; the fourth shape ``QLMIORouter.plan`` prices (via
        ``spec_pred``) against pure and disaggregated dispatch.  None
        when ``verify`` is not a speculative handle.

        ``draft == verify`` is colocated speculation; a distinct edge
        ``draft`` is the edge-drafts/cloud-verifies mode, whose only
        cross-device traffic is ``spec_k`` token ids per tick
        (``draft_link``) — the verify tick is re-priced with the draft
        steps on the *draft handle's* device, and the expected emitted
        tokens per tick come from the verify engine's live measured
        acceptance rate (telemetry feedback)."""
        hd, hv = self.handles[draft], self.handles[verify]
        ev = hv.engine
        if hv.spec_tick_s is None or hv.draft_profile is None:
            return None
        k = getattr(ev, "spec_k", hv.spec_k)
        tick = float(hv.time_scale * cm.speculative_tick_s(
            hv.device, hv.profile, hv.draft_profile, k,
            context_tokens=ev.max_seq / 2, kv_dtype=hv.kv_dtype,
            draft_device=hd.device))
        # k drafted token ids uplink per tick (ids pipeline on the
        # persistent stream: bytes only, no per-tick RTT)
        link_bw = min(hd.device.net_bw, hv.device.net_bw)
        draft_link = 0.0 if draft == verify else k * 4.0 / link_bw
        e_acc = float(cm.expected_accepted(k, ev.acceptance_rate()))
        n_pref = float(cm.chunked_prefill_tokens(
            prompt_tokens, ev.prefill_chunk if ev.chunked else 0,
            minimum=ev.min_bucket if ev.bucketing else 1))
        terms = {"queue": hv._load()["backlog_s"],
                 "prefill": n_pref * hv.prefill_tok_s,
                 "decode": max_new_tokens * tick / e_acc,
                 "draft_link": max_new_tokens * draft_link / e_acc,
                 "media": float(media_delay_s),
                 "link": hv.up_s + hv.down_s}
        return sum(terms.values()), terms

    def drain(self, max_virtual_s: float | None = None,
              step_s: float | None = None):
        """Advance every engine until idle (or the deadline, for failed /
        wedged servers) by replaying the event heap to the deadline — one
        pass, no per-handle full-horizon sweep.  A migration fired
        mid-drain enqueues onto a peer handle *as a heap event*, so the
        peer serves it in the same pass at the right virtual time.  Work
        still queued at the deadline — a failed server's requests, or
        backlog beyond the timeout horizon — can never complete inside
        it, so it is dropped here: ``collect()`` reports those requests
        as timeouts and the cluster stays reusable (``reset()``-able).
        ``step_s`` is accepted for back-compat and ignored."""
        del step_s
        deadline = self.t + (2 * self.timeout_s if max_virtual_s is None
                             else max_virtual_s)
        self.advance_to(deadline)
        for h in self.handles:
            # timestamp the horizon on every handle (failed servers burn
            # the time without serving) and drop unservable leftovers
            h.vtime = max(h.vtime, deadline)
            h.pending.clear()
            h.engine.queue.clear()
        self.t = deadline

    def collect(self) -> "list[dict]":
        """Measured per-request records (virtual seconds, links included).
        A request that never completed (failed server, drain deadline)
        counts as a timeout, like the sim's failure injection."""
        out = []
        for uid in sorted(self.records):
            rec = self.records[uid]
            req, h = rec["req"], self.handles[rec["server"]]
            streamed = bool(rec.get("streamed"))
            if req.done and req.token_times:
                # a streamed request pays one token chunk's downlink at
                # the tail (earlier chunks overlapped decoding); a drained
                # one ships the full response payload at the end
                down = h.stream_chunk_s if streamed else h.downlink_s()
                e2e = req.token_times[-1] + down - rec["t_arrival"]
                ttft = req.token_times[0] + down - rec["t_arrival"]
                timeout = e2e > self.timeout_s
                success = rec["quality_ok"] and not timeout
                service = req.e2e_s()
                if self._tr is not None and not rec.get("spanned"):
                    rec["spanned"] = True  # collect() may run twice
                    self._tr.span("stream" if streamed else "downlink",
                                  "transfer", req.token_times[-1],
                                  req.token_times[-1] + down,
                                  pid=h._pid, tid=uid)
                if self.telemetry is not None:
                    self.telemetry.join_measured(uid, e2e)
            else:
                e2e = ttft = 4 * self.timeout_s
                timeout, success, service = True, False, 0.0
                if self.telemetry is not None:
                    self.telemetry.join_measured(uid, e2e, completed=False)
            out.append({"uid": uid, "task": rec["task"],
                        "server": rec["server"], "ttft_s": float(ttft),
                        "e2e_s": float(e2e), "service_s": float(service),
                        "timeout": bool(timeout), "success": bool(success),
                        "n_tokens": len(req.output),
                        "streamed": streamed,
                        "predicted_s": rec.get("predicted_s")})
        return out

    def reset(self):
        """Rewind the virtual clock for a fresh replay on warm engines
        (keeps params and XLA caches — the expensive part).  Engine
        metrics registries (and any attached telemetry's trace + audit)
        reset too, so per-replay stats stay independent; the engines'
        ``_traced`` sets are *not* cleared — XLA's compile caches persist
        across replays, and the ``xla_trace_events`` counters restart at 0
        against that warm state (the steady-state recompile guard)."""
        for h in self.handles:
            if h.busy() or h.pending:
                raise RuntimeError("reset() needs a drained cluster")
            h.vtime = 0.0
            h.engine.finished.clear()
            h.engine.metrics.reset()
            h.engine.reset_prefix_cache()  # replays must be independent
        if self.telemetry is not None:
            self.telemetry.reset()
        self.t = 0.0
        self.records = {}
        self._planned = {}
        self._heap.clear()  # any surviving entries are stale by now
        self._stream_buf.clear()
        self.handle_steps = 0
        self.heap_pops = 0
        self._uid = 0  # uids restart so replays compare bit-identically

    def latency_stats(self) -> dict:
        """Per-handle engine stats (virtual-clock seconds), plus per-tier
        rollups under ``"tiers"``: edge/cloud summaries over the *merged*
        raw latency samples of each tier's engines (exact percentiles, not
        averages of per-engine percentiles)."""
        out = {h.name: h.engine.latency_stats() for h in self.handles}
        tiers = {}
        for tier, cloud in (("edge", False), ("cloud", True)):
            hs = [h for h in self.handles if h.is_cloud == cloud]
            if not hs:
                continue
            tiers[tier] = latency_summary(
                [v for h in hs for v in h.engine.metrics
                 .histogram("ttft_s").values],
                [v for h in hs for v in h.engine.metrics
                 .histogram("itl_s").values],
                [v for h in hs for v in h.engine.metrics
                 .histogram("e2e_s").values])
        out["tiers"] = tiers
        return out


class EngineBackend:
    """``Episode`` execution backend over a live ``Cluster`` (same
    interface as ``sim.cemllm.CostModelBackend``).

    ``execute`` returns the cost-model estimate — backend parity: a
    deterministic policy sees exactly the observations it would under the
    default backend — while the real request is submitted to the chosen
    engine at the task's virtual arrival time; the cluster then advances
    to the next arrival, so execution pipelines across decisions.
    ``drain()`` finishes every engine and patches the registered episode
    records with measured TTFT/e2e latency, timeout, and success.
    """

    def __init__(self, cluster: Cluster, bench, servers, *,
                 failed=None, arrival_dt: float = 0.02,
                 prompt_cap: int = 48, decode_cap: int = 10,
                 out_token_scale: float = 40.0):
        self.cluster = cluster
        self.bench = bench
        self.servers = servers
        self.failed = (np.zeros(servers.n, bool) if failed is None
                       else np.asarray(failed, bool))
        self.est = CostModelBackend(bench, servers, self.failed)
        self.arrival_dt = arrival_dt
        self.prompt_cap = prompt_cap
        self.decode_cap = decode_cap
        self.out_token_scale = out_token_scale
        self.t = cluster.t
        self._last_uid: int | None = None
        self._open: "list[tuple[int, dict]]" = []

    # ------------------------------------------------------- task shaping
    def prompt_tokens(self, task: int, vocab: int) -> np.ndarray:
        """Deterministic per-task prompt, MIOBench prompt-length matched."""
        L = int(np.clip(self.bench.tasks.text_len[task], 1, self.prompt_cap))
        rng = np.random.default_rng(1_000_003 * (task + 1))
        return rng.integers(0, vocab, L).astype(np.int32)

    def gen_budget(self, task: int, server: int) -> int:
        """Scaled-down CoT inflation: weaker models / harder tasks decode
        more tokens (cost_model.expected_out_tokens / out_token_scale)."""
        prof = self.cluster.handles[server].profile
        out = cm.expected_out_tokens(
            prof, float(self.bench.tasks.difficulty[task]))
        return int(np.clip(round(out / self.out_token_scale), 2,
                           self.decode_cap))

    # --------------------------------------------------- backend interface
    def execute(self, task: int, server: int):
        lat_e, ok_e, _ = self.est.execute(task, server)
        h = self.cluster.handles[server]
        c = int(self.servers.cls[server])
        quality_ok = (not self.failed[server]
                      and int(self.bench.score[task, c]) == 1)
        prompt = self.prompt_tokens(task, h.cfg.vocab)
        budget = self.gen_budget(task, server)
        creq = ContinuumRequest(tokens=prompt, max_new_tokens=budget,
                                arrival_s=self.t, task=task,
                                quality_ok=quality_ok, server=server)
        tm = self.cluster.telemetry
        if tm is not None:
            # predict before submit: the queue term must not include the
            # request itself.  candidates = what every server would have
            # predicted, for the audit's why-this-server story.
            predicted, terms = h.predict_e2e_s(len(prompt), budget)
            cand = [self.cluster.handles[s].predict_e2e_s(
                        len(prompt), self.gen_budget(task, s))[0]
                    for s in range(len(self.cluster.handles))]
            uid = self.cluster.submit(creq.with_plan(predicted_s=predicted))
            tm.record_dispatch(task=task, server=server, t=self.t,
                               predicted_s=predicted, uid=uid, terms=terms,
                               candidates=cand, policy_est_s=float(lat_e))
            self._last_uid = uid
        else:
            self._last_uid = self.cluster.submit(creq)
        self.t += self.arrival_dt
        self.cluster.advance_to(self.t)
        return lat_e, ok_e, False

    def register(self, rec: dict):
        self._open.append((self._last_uid, rec))

    def drain(self):
        self.cluster.drain()
        measured = {r["uid"]: r for r in self.cluster.collect()}
        for uid, rec in self._open:
            m = measured[uid]
            rec.update(latency_r=m["service_s"], latency_total=m["e2e_s"],
                       ttft_s=m["ttft_s"], timeout=m["timeout"],
                       success=m["success"], pending=False)
        self._open.clear()


def build_continuum(spec, *, seed: int = 0, time_scale: float = 1.0,
                    fail=(), telemetry=None, arch: str | None = None,
                    param_seed: int | None = None, backend: str = "live",
                    tp: "int | dict | None" = None,
                    **engine_kw) -> "list[EngineHandle]":
    """Live handles for a ``[(class_idx, count), ...]`` spec (the
    ``SYSTEM_CONFIGS`` layout) — pair with
    ``cemllm.make_servers_from_spec`` so the sim table and the engine
    fleet index the same servers.  Class 0/1 are edge tiers on the small
    config; the last class is the cloud tier on the larger config.
    ``telemetry`` (shared across the fleet) turns on lifecycle tracing +
    the dispatch audit; ``Cluster`` picks it up from the handles.

    ``arch`` forces every handle onto one live config and ``param_seed``
    onto one shared weight init — together they make the whole fleet
    KV-compatible with identical weights, the precondition for
    bit-identical cross-engine migration (disaggregated prefill/decode;
    the per-class archs and per-handle seeds stay the default because
    heterogeneous fleets exercise more of the replay harness).

    ``backend="sim"`` swaps every handle's live engine for the analytic
    ``SimEngine`` — no weights, no XLA, same profiled tick costs — which
    is what makes 100+ handle fleets (benchmarks/fig13_scaleout.py)
    constructible in milliseconds.

    ``tp`` makes mesh width a tier knob: an int shards only the cloud
    class (the tier with interconnect worth spending), a
    ``{class_idx: tp}`` dict shards per class.  Live handles get a real
    ``tp``-wide host mesh; both backends price the width through the
    cost model's TP tick terms, which is how the router sees it."""
    if isinstance(tp, int):
        tp = {len(SERVER_CLASSES) - 1: tp}
    tp = tp or {}
    handles = []
    i = 0
    for class_idx, count in spec:
        dev_name, prof_name = SERVER_CLASSES[class_idx]
        for _ in range(count):
            cloud = class_idx == len(SERVER_CLASSES) - 1
            arch_i = arch if arch is not None else CLASS_ARCHS[class_idx]
            seed_i = param_seed if param_seed is not None else seed + i
            handles.append(EngineHandle(
                f"{'cloud' if cloud else 'edge'}-{i} ({dev_name}/{arch_i})",
                arch_i, cm.DEVICES[dev_name], cm.MODELS[prof_name],
                is_cloud=cloud, seed=seed_i, fail=i in fail,
                time_scale=time_scale, telemetry=telemetry,
                backend=backend, tp=int(tp.get(class_idx, 1)), **engine_kw))
            i += 1
    return handles
