"""Discrete-event cloud-edge continuum replay harness.

The offloading half of this repo (QLMIO router, CEMLLM-Sim episodes) used
to execute tasks against closed-form cost-model stubs; the serving half
(paged-KV + chunked-prefill ``ServingEngine``) was never in the decision
loop.  This module joins them: each ``EngineHandle`` wraps a **live**
``ServingEngine`` (small/fast reduced config for edge nodes, larger config
for the cloud tier) behind the network link of a quarantined
``DeviceProfile``, and a ``Cluster`` replays MIOBench arrival traces
against the fleet under a shared **virtual clock**:

  * the policy (QLMIO scoring, MILP/MGQP/greedy/all-cloud baselines via
    ``run_policy``) picks a server per task;
  * the harness ``submit()``s the request to that server's engine with the
    uplink delay applied, then advances every engine tick-by-tick;
  * one engine tick costs ``decode_tick_s`` virtual seconds (the roofline
    per-token decode time of the profiled hardware) plus
    ``prefill_tok_s`` per prompt token (computed + padding) the tick's
    chunked prefill actually ran — the engine generates *real* tokens
    while the clock charges the *profiled* device;
  * TTFT / ITL / e2e come from ``ServingEngine.latency_stats()`` in
    virtual-clock seconds (the engine's ``clock`` hook), and quality comes
    from the MIOBench success predictors, replacing
    ``SimulatedServer._execute``'s closed-form latency.

Multimodal requests ride the same harness: ``Cluster.submit`` accepts
typed segments (repro/serving/segments.py) and a ``media_delay_s`` charge,
and ``EngineHandle.split_point`` answers the per-request *split-point*
question — ship raw media and encode at this server, or encode on the
source edge device and ship keep-top-k-compressed features — from the
cost model's per-modality uplink/encode rooflines
(``cost_model.best_split``).

``EngineBackend`` plugs the harness into ``sim.cemllm.Episode`` with the
same interface as ``CostModelBackend``: dispatch-time estimates are the
cost-model numbers (so a deterministic policy takes identical decisions
under either backend), and ``drain()`` patches the episode records with
measured latencies once every engine has drained.

Observability (repro/serving/telemetry.py): pass ``telemetry=`` to
``build_continuum``/``Cluster`` to record uplink/media-encode/downlink
transfer spans, per-engine tick spans with true virtual durations, and a
dispatch audit — each routed request's predicted e2e with per-term
breakdown (``EngineHandle.predict_e2e_s``), joined with the measured e2e
at ``collect()`` so ``Telemetry.prediction_error`` reports cost-model
calibration.  ``Cluster.reset`` also resets every engine's metrics
registry, so per-replay stats stay independent.
"""
from __future__ import annotations

import heapq

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import ceil_blocks
from repro.serving.router import ServerHandle
from repro.serving.telemetry import latency_summary
from repro.sim import cost_model as cm
from repro.sim.cemllm import CostModelBackend
from repro.sim.miobench import SERVER_CLASSES

# live-engine arch per MIOBench server class (SERVER_CLASSES order):
# edge tiers run the small/fast config, the cloud tier a larger one.
CLASS_ARCHS = ["qwen2-0.5b", "qwen2-0.5b", "llama3.2-3b"]


class EngineHandle(ServerHandle):
    """One continuum server: a live ``ServingEngine`` under a virtual clock.

    The engine's ``clock`` hook reads ``self.vtime``, so every request
    timestamp (``t_submit`` / ``token_times``) — and therefore
    ``latency_stats()`` — is in virtual seconds.  Doubles as a plain
    ``ServerHandle``: ``execute`` runs one task synchronously (legacy
    router path) and ``load`` reports live queue depth, in-flight prefill
    tokens and estimated drain time for the router's scoring.
    """

    def __init__(self, name: str, arch: str, device: cm.DeviceProfile,
                 profile: cm.ModelProfile, *, is_cloud: bool = False,
                 seed: int = 0, max_batch: int = 2, max_seq: int = 96,
                 time_scale: float = 1.0, payload_bytes: float | None = None,
                 kv_dtype: str | None = None, fail: bool = False,
                 telemetry=None, **engine_kw):
        cfg = reduced(get_config(arch))
        self.cfg = cfg
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        self.vtime = 0.0
        # KV precision is itself an offloading decision: edge tiers
        # default to the int8 page pool (half the decode KV stream, ~2x
        # the page budget per HBM byte — what makes the weak tiers worth
        # routing to), the cloud tier keeps bf16.  The profiled tick cost
        # below prices the choice, so the router sees it through every
        # backlog/latency estimate.  Quantized pages need the paged
        # backend, so recurrent/hybrid archs (dense cache) stay bf16.
        if kv_dtype is None:
            kv_dtype = ("int8" if model.supports_paged and not is_cloud
                        else "bf16")
        self.kv_dtype = kv_dtype
        self.engine = ServingEngine(model, params, max_batch=max_batch,
                                    max_seq=max_seq, kv_dtype=kv_dtype,
                                    clock=lambda: self.vtime,
                                    telemetry=telemetry, trace_name=name,
                                    **engine_kw)
        self.telemetry = telemetry
        tr = telemetry.tracer if telemetry is not None else None
        self._tr = tr if (tr is not None and tr.enabled) else None
        self._pid = self._tr.process(name) if self._tr else 0
        self.device = device
        self.profile = profile
        eff = device.flops * cm._EFF
        bw = device.mem_bw * cm._EFF
        # per-tick decode roofline: active weights + the resident KV
        # context (nominal half-full sequences) at this tier's precision
        kv_stream = cm.kv_bytes_per_token(profile, kv_dtype) * (max_seq / 2)
        self.decode_tick_s = (time_scale * (profile.n_active
                                            * profile.bytes_per_param
                                            + kv_stream) / bw)
        self.prefill_tok_s = time_scale * 2.0 * profile.n_active / eff
        # payload (default: the cost model's text+image request) split
        # evenly between request and response; both halves priced by the
        # shared cost-model link helper
        if payload_bytes is None:
            payload_bytes = cm.payload_bytes()
        self.up_s = float(cm.uplink_s(payload_bytes / 2, device))
        self.down_s = float(cm.downlink_s(payload_bytes / 2, device))
        self.fail = fail
        self.pending: list = []  # min-heap of (t_ready, seq, Request)
        self._seq = 0
        # invoked after every charged engine tick (Cluster wires this to
        # its migration scheduler so planned evacuations fire between
        # ticks, at a consistent engine state)
        self.on_step = None
        # KV pages moved to / from other engines, in wire bytes (priced
        # at the *receiving* side's page precision)
        self._c_mig_in = self.engine.metrics.counter("kv_migrate_in_bytes")
        self._c_mig_out = self.engine.metrics.counter("kv_migrate_out_bytes")
        super().__init__(name=name,
                         model_id=cm.MODEL_IDS.index(profile.name),
                         device_id=cm.DEVICE_IDS.index(device.name),
                         is_cloud=is_cloud, execute=self._execute_sync,
                         load=self._load)

    # ------------------------------------------------------- network link
    def uplink_s(self) -> float:
        return self.up_s

    def downlink_s(self) -> float:
        return self.down_s

    # ------------------------------------------------------- migration
    def kv_compatible(self, other: "EngineHandle") -> bool:
        """Whether a KV snapshot exported here can be imported by
        ``other``: both paged, same vocabulary, same KV geometry
        (layers, kv heads, head dim) and page size.  Structural check
        only — bit-identical resumed tokens additionally require the two
        engines to share weights (``build_continuum(param_seed=...)``)."""
        e, o = self.engine, other.engine
        return (e.paged and o.paged
                and self.cfg.vocab == other.cfg.vocab
                and e.model.kv_geometry == o.model.kv_geometry
                and e.page_size == o.page_size)

    # ------------------------------------------------------- split point
    def split_point(self, spec: cm.MediaSpec,
                    src: cm.DeviceProfile) -> "tuple[str, float]":
        """Where to encode ``spec``'s media for a request bound to this
        server: ``("raw", s)`` — ship raw media, encode here — or
        ``("edge", s)`` — encode on the source device ``src``, ship
        compressed features.  ``s`` is the extra virtual seconds the
        chosen split adds on top of the request's base uplink; pass it to
        ``Cluster.submit(media_delay_s=...)``."""
        return cm.best_split(spec, src, self.device)

    def split_delay_s(self, spec: cm.MediaSpec, src: cm.DeviceProfile,
                      choice: str) -> float:
        """Extra virtual seconds of a *forced* split choice (the fixed
        all-raw-ship / all-edge-encode baseline policies)."""
        return cm.split_point_s(spec, src, self.device)[choice]

    # ---------------------------------------------------- virtual stepping
    def enqueue(self, req: Request, t_ready: float):
        """Queue a request to reach this server at virtual time t_ready."""
        heapq.heappush(self.pending, (t_ready, self._seq, req))
        self._seq += 1

    def busy(self) -> bool:
        return self.engine.busy()

    def _admit_ready(self):
        while self.pending and self.pending[0][0] <= self.vtime + 1e-12:
            _, _, req = heapq.heappop(self.pending)
            self.engine.submit(req)  # t_submit stamps self.vtime

    def advance_to(self, t: float):
        """Run whole engine ticks until the virtual clock reaches ``t``.

        A tick is charged its dynamic cost (decode step + prefill tokens
        it computed), so the final tick may overshoot ``t`` by less than
        one tick.  An idle engine fast-forwards to its next arrival (or to
        ``t``) without burning host CPU; a failed server burns the time
        without serving anything (its requests time out).
        """
        while True:
            self._admit_ready()
            if self.vtime >= t - 1e-12:
                return
            if self.fail:
                self.vtime = t
                return
            if not self.busy():
                nxt = self.pending[0][0] if self.pending else t
                if nxt >= t - 1e-12:  # nothing to do before t
                    self.vtime = t
                    return
                self.vtime = max(self.vtime, nxt)
                continue
            e = self.engine
            p0 = e.prefill_tokens_computed + e.prefill_tokens_padded
            n_busy = e.step()
            dp = e.prefill_tokens_computed + e.prefill_tokens_padded - p0
            dt = self.decode_tick_s + dp * self.prefill_tok_s
            if self._tr is not None:
                # engine-side spans within one tick are zero-width under
                # the virtual clock (vtime advances *after* the step);
                # this span carries the tick's true virtual duration
                self._tr.span("tick", "engine", self.vtime,
                              self.vtime + dt, pid=self._pid,
                              args={"prefill_tokens": dp, "busy": n_busy})
            self.vtime += dt
            if self.on_step is not None:
                self.on_step(self)

    # ------------------------------------------------------------- probes
    def _load(self) -> dict:
        """Live congestion for the router's ``_effective_latency``: queued
        + running request count, prompt tokens not yet in any KV cache,
        and the estimated virtual seconds to drain all of it."""
        e = self.engine
        waiting = list(e.queue) + [r for _, _, r in self.pending]
        active = [r for r in e.slots if r is not None]
        tasks = [t for t in e.prefill_tasks if t is not None]
        inflight = (sum(len(t.req.tokens) - t.done for t in tasks)
                    + sum(len(r.tokens) for r in waiting))
        decode_ticks = max((int(e.budget[i]) for i, r in enumerate(e.slots)
                            if r is not None), default=0)
        decode_ticks += -(-sum(r.max_new_tokens for r in waiting)
                          // max(e.max_batch, 1))
        backlog = (inflight * self.prefill_tok_s
                   + decode_ticks * self.decode_tick_s)
        return {"queue_depth": len(waiting) + len(active) + len(tasks),
                "inflight_prefill_tokens": int(inflight),
                "backlog_s": float(backlog)}

    def predict_e2e_s(self, prompt_tokens: int, max_new_tokens: int, *,
                      media_delay_s: float = 0.0) -> "tuple[float, dict]":
        """Predicted end-to-end virtual seconds for a request dispatched
        to this server *now*, decomposed per term — the dispatch-audit
        record ``Telemetry.prediction_error`` calibrates against measured
        e2e.  Built from the same per-tick costs ``advance_to`` charges
        (harness scale), so the error measures congestion/interleaving
        mispredictions, not the replay's deliberate scale-down vs. the
        paper-scale cost model.  Call *before* ``Cluster.submit`` so the
        queue term excludes the request itself."""
        e = self.engine
        queue = self._load()["backlog_s"]
        n_pref = float(cm.chunked_prefill_tokens(
            prompt_tokens, e.prefill_chunk if e.chunked else 0,
            minimum=e.min_bucket if e.bucketing else 1))
        terms = {"queue": queue,
                 "prefill": n_pref * self.prefill_tok_s,
                 "decode": max_new_tokens * self.decode_tick_s,
                 "media": float(media_delay_s),
                 "link": self.up_s + self.down_s}
        return sum(terms.values()), terms

    def _execute_sync(self, task: int) -> "tuple[float, bool]":
        """Legacy ``ServerHandle.execute``: run one task to completion on
        this engine alone; returns virtual seconds including the link."""
        if self.fail:
            return 4 * cm.TIMEOUT_S, False
        rng = np.random.default_rng((task, self.model_id, 7))
        prompt = rng.integers(0, self.cfg.vocab, 16).astype(np.int32)
        req = Request(-1 - task, prompt, max_new_tokens=6)
        t0 = self.vtime
        self.enqueue(req, self.vtime + self.uplink_s())
        deadline = t0 + 4 * cm.TIMEOUT_S
        stride = self.uplink_s() + 8 * self.decode_tick_s
        while not req.done and self.vtime < deadline:
            self.advance_to(self.vtime + stride)
        return self.vtime - t0 + self.downlink_s(), req.done


class Cluster:
    """Shared-virtual-clock harness over a list of ``EngineHandle``s.

    ``submit`` routes a request to a server; ``advance_to`` moves every
    engine to a common virtual time (arrival ordering is respected via the
    per-handle pending heaps); ``drain`` runs all engines until every
    submitted request finished or the timeout horizon passed; ``collect``
    returns the measured per-request records.
    """

    def __init__(self, handles: "list[EngineHandle]",
                 timeout_s: float = cm.TIMEOUT_S, telemetry=None):
        self.handles = handles
        self.timeout_s = timeout_s
        self.t = 0.0
        self.records: dict[int, dict] = {}
        self._uid = 0
        # uid -> destination handle index of a planned disaggregated
        # dispatch (prefill where submitted, decode there); executed by
        # _on_engine_step as soon as the request reaches decode phase
        self._planned: dict[int, int] = {}
        for h in handles:
            h.on_step = self._on_engine_step
        # default to the handles' shared telemetry so callers building via
        # build_continuum(telemetry=...) need not pass it twice
        if telemetry is None:
            telemetry = next((h.telemetry for h in handles
                              if h.telemetry is not None), None)
        self.telemetry = telemetry
        tr = telemetry.tracer if telemetry is not None else None
        self._tr = tr if (tr is not None and tr.enabled) else None

    def submit(self, server: int, task: int, tokens, max_new_tokens: int,
               t_arrival: float, quality_ok: bool = True, segments=None,
               media_delay_s: float = 0.0,
               decode_server: int | None = None) -> int:
        """Dispatch one task to ``server`` at virtual ``t_arrival``; the
        request reaches the engine after the uplink delay.  ``quality_ok``
        is the success-predictor verdict for (task, server) — generated
        tokens are real but random, so answer quality is judged by the
        predictor, as in the sim.

        ``segments`` makes the request multimodal (typed spans,
        repro/serving/segments.py; ``tokens`` is then ignored) and
        ``media_delay_s`` charges the chosen split point's extra cost —
        edge-side encode + media serialization from
        ``EngineHandle.split_point`` — before the request reaches the
        engine, so measured TTFT/e2e include where the media crossed the
        continuum.

        ``decode_server`` (None = run both phases on ``server``) plans the
        disaggregated dispatch shape: prefill on ``server``, then — as
        soon as the request reaches decode phase — its KV snapshot
        migrates over the device link (charged on the virtual clock,
        ``kv_migrate`` span) and decode resumes on ``decode_server``."""
        h = self.handles[server]
        if decode_server is not None and decode_server != server:
            if not h.kv_compatible(self.handles[decode_server]):
                raise ValueError(
                    f"cannot plan prefill on {h.name} / decode on "
                    f"{self.handles[decode_server].name}: KV-incompatible "
                    "engines (geometry, page size, or cache backend)")
        self._uid += 1
        if segments is not None:
            req = Request(self._uid, segments=segments,
                          max_new_tokens=int(max_new_tokens))
        else:
            req = Request(self._uid, np.asarray(tokens, np.int32),
                          max_new_tokens=int(max_new_tokens))
        h.enqueue(req, t_arrival + h.uplink_s() + media_delay_s)
        if self._tr is not None:
            tr, pid, uid = self._tr, h._pid, self._uid
            t1 = t_arrival + h.uplink_s()
            tr.span("uplink", "transfer", t_arrival, t1, pid=pid, tid=uid,
                    args={"task": int(task)})
            if media_delay_s:
                tr.span("media_encode", "transfer", t1,
                        t1 + media_delay_s, pid=pid, tid=uid)
        self.records[self._uid] = {"uid": self._uid, "task": task,
                                   "server": server, "t_arrival": t_arrival,
                                   "req": req, "quality_ok": bool(quality_ok)}
        if decode_server is not None and decode_server != server:
            self._planned[self._uid] = int(decode_server)
        return self._uid

    # lockstep quantum: a migration fired while advancing one handle
    # enqueues work onto a *peer* whose clock may already sit at the
    # current barrier, so the admission lands late by at most one
    # quantum.  Idle handles fast-forward, so finer sync is cheap.
    SYNC_STEP_S = 0.1

    def busy(self) -> bool:
        return any(h.busy() or h.pending for h in self.handles)

    def advance_to(self, t: float, step_s: float | None = None):
        if t <= self.t:
            return
        step = step_s if step_s is not None else self.SYNC_STEP_S
        while self.t < t - 1e-9:
            tt = min(self.t + step, t)
            for h in self.handles:
                h.advance_to(tt)
            self.t = tt

    # ------------------------------------------------------- migration
    def _on_engine_step(self, h: EngineHandle):
        """Per-tick hook (EngineHandle.on_step): execute planned
        prefill-here/decode-there handoffs whose request just reached
        decode phase on ``h``.  A request may decode a token or two here
        before the hook sees it — the snapshot resumes at exactly
        ``output[-1]`` either way, so no work is lost or repeated."""
        if not self._planned:
            return
        for uid in list(self._planned):
            rec = self.records.get(uid)
            if rec is None or self.handles[rec["server"]] is not h:
                continue
            req = rec["req"]
            if req.done:
                del self._planned[uid]  # finished before the handoff fired
                continue
            if req.output and h.engine.slot_of_request(uid) is not None:
                dst = self._planned.pop(uid)
                self.migrate(uid, dst)

    def migrate(self, uid: int, dst: int) -> dict:
        """Evacuate request ``uid`` from the engine currently holding it
        and resume it on handle ``dst``, charging the KV transfer on the
        virtual clock: wire bytes are the non-cached snapshot pages at the
        **destination's** page precision (int8 tiers pay ~half), link time
        is the cost model's server-to-server roofline, and the transfer is
        visible as a ``kv_migrate`` span.  Returns the move record."""
        rec = self.records[uid]
        src = rec["server"]
        src_h, dst_h = self.handles[src], self.handles[dst]
        if not src_h.kv_compatible(dst_h):
            raise ValueError(
                f"cannot migrate request {uid}: {src_h.name} and "
                f"{dst_h.name} are KV-incompatible")
        req, snap = src_h.engine.evacuate(uid)
        n_cached = (len(dst_h.engine.pool.peek_hashes(snap.prefix_hashes))
                    if dst_h.engine.prefix_caching else 0)
        n_wire = max(snap.num_pages - n_cached, 0)
        nbytes = n_wire * dst_h.engine.page_bytes()
        mig_s = float(cm.migrate_link_s(nbytes, src_h.device, dst_h.device))
        t0 = src_h.vtime
        dst_h.enqueue(req, t0 + mig_s)
        rec["server"] = dst
        src_h._c_mig_out.inc(nbytes)
        dst_h._c_mig_in.inc(nbytes)
        if self._tr is not None:
            self._tr.span("kv_migrate", "transfer", t0, t0 + mig_s,
                          pid=dst_h._pid, tid=uid,
                          args={"bytes": int(nbytes), "pages": int(n_wire),
                                "tokens": int(snap.num_tokens),
                                "src": src_h.name, "dst": dst_h.name})
        return {"uid": uid, "src": src, "dst": dst, "bytes": int(nbytes),
                "pages": int(n_wire), "migrate_s": mig_s, "t": t0}

    def rebalance(self, threshold_s: float, *,
                  min_gain_s: float = 0.0) -> "list[dict]":
        """Mid-stream evacuation policy: for every engine whose backlog
        exceeds ``threshold_s``, consider moving its decoding request with
        the most generation budget left to the KV-compatible handle where
        (migration + remaining decode + queueing) beats staying local by
        more than ``min_gain_s``.  Returns the executed move records."""
        loads = [h._load()["backlog_s"] for h in self.handles]
        moves = []
        for i, src_h in enumerate(self.handles):
            if src_h.fail or loads[i] <= threshold_s:
                continue
            e = src_h.engine
            cands = [(int(e.budget[s]), s, r.uid)
                     for s, r in enumerate(e.slots)
                     if r is not None and r.output and int(e.budget[s]) > 0]
            if not cands:
                continue
            remaining, slot, uid = max(cands)
            n_ctx = int(e.pos[slot])
            best = None
            for j, dst_h in enumerate(self.handles):
                if j == i or dst_h.fail or not src_h.kv_compatible(dst_h):
                    continue
                pages = ceil_blocks(n_ctx, dst_h.engine.page_size)
                mig = float(cm.migrate_link_s(
                    pages * dst_h.engine.page_bytes(),
                    src_h.device, dst_h.device))
                t_move = (mig + remaining * dst_h.decode_tick_s
                          + 0.5 * loads[j])
                if best is None or t_move < best[0]:
                    best = (t_move, j)
            if best is None:
                continue
            t_stay = remaining * src_h.decode_tick_s + 0.5 * loads[i]
            if t_stay - best[0] > min_gain_s:
                self._planned.pop(uid, None)  # superseded by this move
                moves.append(self.migrate(uid, best[1]))
                loads[i] = src_h._load()["backlog_s"]
        return moves

    def predict_disagg_e2e_s(self, prefill: int, decode: int,
                             prompt_tokens: int, max_new_tokens: int, *,
                             media_delay_s: float = 0.0
                             ) -> "tuple[float, dict]":
        """Predicted e2e of the disaggregated dispatch shape — prefill on
        handle ``prefill``, KV migration, decode on handle ``decode`` —
        decomposed per term; the third shape ``QLMIORouter.plan`` prices
        against pure-edge and pure-cloud.  Mirrors
        ``EngineHandle.predict_e2e_s`` (same tick-cost scale)."""
        hp, hd = self.handles[prefill], self.handles[decode]
        ep, ed = hp.engine, hd.engine
        n_pref = float(cm.chunked_prefill_tokens(
            prompt_tokens, ep.prefill_chunk if ep.chunked else 0,
            minimum=ep.min_bucket if ep.bucketing else 1))
        pages = ceil_blocks(prompt_tokens + 1, ed.page_size)
        mig = float(cm.migrate_link_s(pages * ed.page_bytes(),
                                      hp.device, hd.device))
        terms = {"queue": hp._load()["backlog_s"],
                 "prefill": n_pref * hp.prefill_tok_s,
                 "migrate": mig,
                 "queue_decode": hd._load()["backlog_s"],
                 "decode": max_new_tokens * hd.decode_tick_s,
                 "media": float(media_delay_s),
                 "link": hp.up_s + hd.down_s}
        return sum(terms.values()), terms

    def drain(self, max_virtual_s: float | None = None,
              step_s: float | None = None):
        """Advance every engine until idle (or the deadline, for failed /
        wedged servers).  Idle engines fast-forward, so this is cheap.
        Work still queued at the deadline — a failed server's requests, or
        backlog beyond the timeout horizon — can never complete inside it,
        so it is dropped here: ``collect()`` reports those requests as
        timeouts and the cluster stays reusable (``reset()``-able).

        Draining steps the fleet in ``step_s`` increments (default
        ``SYNC_STEP_S``) rather than one full-horizon pass per handle: a
        migration fired mid-drain enqueues work onto a *peer* handle at
        the source's current vtime, and a handle already advanced to the
        deadline would clear that work as a timeout without serving it."""
        deadline = self.t + (2 * self.timeout_s if max_virtual_s is None
                             else max_virtual_s)
        step = step_s if step_s is not None else self.SYNC_STEP_S
        while self.t < deadline - 1e-9 and self.busy():
            self.advance_to(min(self.t + step, deadline), step_s=step)
        for h in self.handles:
            h.advance_to(deadline)
            h.pending.clear()
            h.engine.queue.clear()
        self.t = deadline

    def collect(self) -> "list[dict]":
        """Measured per-request records (virtual seconds, links included).
        A request that never completed (failed server, drain deadline)
        counts as a timeout, like the sim's failure injection."""
        out = []
        for uid in sorted(self.records):
            rec = self.records[uid]
            req, h = rec["req"], self.handles[rec["server"]]
            if req.done and req.token_times:
                down = h.downlink_s()
                e2e = req.token_times[-1] + down - rec["t_arrival"]
                ttft = req.token_times[0] + down - rec["t_arrival"]
                timeout = e2e > self.timeout_s
                success = rec["quality_ok"] and not timeout
                service = req.e2e_s()
                if self._tr is not None and not rec.get("spanned"):
                    rec["spanned"] = True  # collect() may run twice
                    self._tr.span("downlink", "transfer",
                                  req.token_times[-1],
                                  req.token_times[-1] + down,
                                  pid=h._pid, tid=uid)
                if self.telemetry is not None:
                    self.telemetry.join_measured(uid, e2e)
            else:
                e2e = ttft = 4 * self.timeout_s
                timeout, success, service = True, False, 0.0
                if self.telemetry is not None:
                    self.telemetry.join_measured(uid, e2e, completed=False)
            out.append({"uid": uid, "task": rec["task"],
                        "server": rec["server"], "ttft_s": float(ttft),
                        "e2e_s": float(e2e), "service_s": float(service),
                        "timeout": bool(timeout), "success": bool(success),
                        "n_tokens": len(req.output)})
        return out

    def reset(self):
        """Rewind the virtual clock for a fresh replay on warm engines
        (keeps params and XLA caches — the expensive part).  Engine
        metrics registries (and any attached telemetry's trace + audit)
        reset too, so per-replay stats stay independent; the engines'
        ``_traced`` sets are *not* cleared — XLA's compile caches persist
        across replays, and the ``xla_trace_events`` counters restart at 0
        against that warm state (the steady-state recompile guard)."""
        for h in self.handles:
            if h.busy() or h.pending:
                raise RuntimeError("reset() needs a drained cluster")
            h.vtime = 0.0
            h.engine.finished.clear()
            h.engine.metrics.reset()
            h.engine.reset_prefix_cache()  # replays must be independent
        if self.telemetry is not None:
            self.telemetry.reset()
        self.t = 0.0
        self.records = {}
        self._planned = {}
        self._uid = 0  # uids restart so replays compare bit-identically

    def latency_stats(self) -> dict:
        """Per-handle engine stats (virtual-clock seconds), plus per-tier
        rollups under ``"tiers"``: edge/cloud summaries over the *merged*
        raw latency samples of each tier's engines (exact percentiles, not
        averages of per-engine percentiles)."""
        out = {h.name: h.engine.latency_stats() for h in self.handles}
        tiers = {}
        for tier, cloud in (("edge", False), ("cloud", True)):
            hs = [h for h in self.handles if h.is_cloud == cloud]
            if not hs:
                continue
            tiers[tier] = latency_summary(
                [v for h in hs for v in h.engine.metrics
                 .histogram("ttft_s").values],
                [v for h in hs for v in h.engine.metrics
                 .histogram("itl_s").values],
                [v for h in hs for v in h.engine.metrics
                 .histogram("e2e_s").values])
        out["tiers"] = tiers
        return out


class EngineBackend:
    """``Episode`` execution backend over a live ``Cluster`` (same
    interface as ``sim.cemllm.CostModelBackend``).

    ``execute`` returns the cost-model estimate — backend parity: a
    deterministic policy sees exactly the observations it would under the
    default backend — while the real request is submitted to the chosen
    engine at the task's virtual arrival time; the cluster then advances
    to the next arrival, so execution pipelines across decisions.
    ``drain()`` finishes every engine and patches the registered episode
    records with measured TTFT/e2e latency, timeout, and success.
    """

    def __init__(self, cluster: Cluster, bench, servers, *,
                 failed=None, arrival_dt: float = 0.02,
                 prompt_cap: int = 48, decode_cap: int = 10,
                 out_token_scale: float = 40.0):
        self.cluster = cluster
        self.bench = bench
        self.servers = servers
        self.failed = (np.zeros(servers.n, bool) if failed is None
                       else np.asarray(failed, bool))
        self.est = CostModelBackend(bench, servers, self.failed)
        self.arrival_dt = arrival_dt
        self.prompt_cap = prompt_cap
        self.decode_cap = decode_cap
        self.out_token_scale = out_token_scale
        self.t = cluster.t
        self._last_uid: int | None = None
        self._open: "list[tuple[int, dict]]" = []

    # ------------------------------------------------------- task shaping
    def prompt_tokens(self, task: int, vocab: int) -> np.ndarray:
        """Deterministic per-task prompt, MIOBench prompt-length matched."""
        L = int(np.clip(self.bench.tasks.text_len[task], 1, self.prompt_cap))
        rng = np.random.default_rng(1_000_003 * (task + 1))
        return rng.integers(0, vocab, L).astype(np.int32)

    def gen_budget(self, task: int, server: int) -> int:
        """Scaled-down CoT inflation: weaker models / harder tasks decode
        more tokens (cost_model.expected_out_tokens / out_token_scale)."""
        prof = self.cluster.handles[server].profile
        out = cm.expected_out_tokens(
            prof, float(self.bench.tasks.difficulty[task]))
        return int(np.clip(round(out / self.out_token_scale), 2,
                           self.decode_cap))

    # --------------------------------------------------- backend interface
    def execute(self, task: int, server: int):
        lat_e, ok_e, _ = self.est.execute(task, server)
        h = self.cluster.handles[server]
        c = int(self.servers.cls[server])
        quality_ok = (not self.failed[server]
                      and int(self.bench.score[task, c]) == 1)
        prompt = self.prompt_tokens(task, h.cfg.vocab)
        budget = self.gen_budget(task, server)
        tm = self.cluster.telemetry
        if tm is not None:
            # predict before submit: the queue term must not include the
            # request itself.  candidates = what every server would have
            # predicted, for the audit's why-this-server story.
            predicted, terms = h.predict_e2e_s(len(prompt), budget)
            cand = [self.cluster.handles[s].predict_e2e_s(
                        len(prompt), self.gen_budget(task, s))[0]
                    for s in range(len(self.cluster.handles))]
            uid = self.cluster.submit(
                server, task, prompt, budget, t_arrival=self.t,
                quality_ok=quality_ok)
            tm.record_dispatch(task=task, server=server, t=self.t,
                               predicted_s=predicted, uid=uid, terms=terms,
                               candidates=cand, policy_est_s=float(lat_e))
            self._last_uid = uid
        else:
            self._last_uid = self.cluster.submit(
                server, task, prompt, budget, t_arrival=self.t,
                quality_ok=quality_ok)
        self.t += self.arrival_dt
        self.cluster.advance_to(self.t)
        return lat_e, ok_e, False

    def register(self, rec: dict):
        self._open.append((self._last_uid, rec))

    def drain(self):
        self.cluster.drain()
        measured = {r["uid"]: r for r in self.cluster.collect()}
        for uid, rec in self._open:
            m = measured[uid]
            rec.update(latency_r=m["service_s"], latency_total=m["e2e_s"],
                       ttft_s=m["ttft_s"], timeout=m["timeout"],
                       success=m["success"], pending=False)
        self._open.clear()


def build_continuum(spec, *, seed: int = 0, time_scale: float = 1.0,
                    fail=(), telemetry=None, arch: str | None = None,
                    param_seed: int | None = None,
                    **engine_kw) -> "list[EngineHandle]":
    """Live handles for a ``[(class_idx, count), ...]`` spec (the
    ``SYSTEM_CONFIGS`` layout) — pair with
    ``cemllm.make_servers_from_spec`` so the sim table and the engine
    fleet index the same servers.  Class 0/1 are edge tiers on the small
    config; the last class is the cloud tier on the larger config.
    ``telemetry`` (shared across the fleet) turns on lifecycle tracing +
    the dispatch audit; ``Cluster`` picks it up from the handles.

    ``arch`` forces every handle onto one live config and ``param_seed``
    onto one shared weight init — together they make the whole fleet
    KV-compatible with identical weights, the precondition for
    bit-identical cross-engine migration (disaggregated prefill/decode;
    the per-class archs and per-handle seeds stay the default because
    heterogeneous fleets exercise more of the replay harness)."""
    handles = []
    i = 0
    for class_idx, count in spec:
        dev_name, prof_name = SERVER_CLASSES[class_idx]
        for _ in range(count):
            cloud = class_idx == len(SERVER_CLASSES) - 1
            arch_i = arch if arch is not None else CLASS_ARCHS[class_idx]
            seed_i = param_seed if param_seed is not None else seed + i
            handles.append(EngineHandle(
                f"{'cloud' if cloud else 'edge'}-{i} ({dev_name}/{arch_i})",
                arch_i, cm.DEVICES[dev_name], cm.MODELS[prof_name],
                is_cloud=cloud, seed=seed_i, fail=i in fail,
                time_scale=time_scale, telemetry=telemetry, **engine_kw))
            i += 1
    return handles
