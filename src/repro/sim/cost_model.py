"""Analytic latency + quality response model (QUARANTINED SIMULATION GATE).

The real MIOBench records wall-clock latencies and answer correctness
measured on RTX5090 / RTX3090Ti / Jetson-Orin hardware running Qwen3-VL
{30B, 8B, 2B} under Ollama.  None of that hardware (or weights) exists in
this container, so this module replaces measurement with a roofline latency
model + a calibrated capability-difficulty response model:

  latency  = prefill(prompt_tok)      2*N_active*T / FLOPS_eff
           + decode(out_tok)          out_tok * bytes_active / MEM_BW_eff
           + transmission             payload / bandwidth + RTT
  out_tok  ~ CoT inflation: smaller capability & harder tasks => longer
             chains of thought (the paper's Sec. I observation)
  success  ~ Bernoulli(sigmoid(a * (capability - difficulty + affinity)))
  timeout  : latency > 60 s  =>  score -1 (counts as failure)

Constants are calibrated so Fig. 1 aggregates match the paper:
Jetson ~66.7% acc / ~26.3% timeouts; RTX5090 ~90% acc, 0 timeouts, <10 s.
"""
from __future__ import annotations

import dataclasses

import numpy as np

TIMEOUT_S = 60.0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops: float  # effective FLOP/s for prefill
    mem_bw: float  # effective B/s for decode
    net_bw: float  # B/s to the user (LAN for edge, WAN for cloud)
    rtt: float  # s


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    n_active: float  # active params
    bytes_per_param: float  # quantization
    capability: float  # cognitive capability score


DEVICES = {
    "jetson_orin_nano": DeviceProfile("jetson_orin_nano", 20e12, 48e9,
                                      12.5e6, 0.004),
    "rtx3090ti": DeviceProfile("rtx3090ti", 120e12, 800e9, 12.5e6, 0.004),
    "rtx5090": DeviceProfile("rtx5090", 300e12, 1.5e12, 3e6, 0.030),
    # TPU-native serving classes (hardware adaptation; README.md, Design notes)
    "tpu_v5e_1": DeviceProfile("tpu_v5e_1", 197e12, 819e9, 12.5e6, 0.004),
    "tpu_v5e_4": DeviceProfile("tpu_v5e_4", 4 * 197e12, 4 * 819e9,
                               12.5e6, 0.004),
    "tpu_v5e_pod": DeviceProfile("tpu_v5e_pod", 256 * 197e12, 256 * 819e9,
                                 3e6, 0.030),
}

MODELS = {
    "qwen3vl-2b": ModelProfile("qwen3vl-2b", 2e9, 1.0, 0.94),
    "qwen3vl-8b": ModelProfile("qwen3vl-8b", 8e9, 1.0, 0.88),
    "qwen3vl-30b": ModelProfile("qwen3vl-30b", 3e9, 2.0, 1.02),  # MoE A3B
}

MODEL_IDS = list(MODELS)
DEVICE_IDS = list(DEVICES)

# calibration knobs
_QUALITY_SLOPE = 5.5
_COT_BASE = 90.0  # base answer tokens
_COT_SCALE = 2800.0  # extra CoT tokens at (difficulty - capability) = 1
_PAYLOAD = 300e3  # image + prompt bytes
_EFF = 0.35  # achieved fraction of peak


_PREFILL_MIN_BUCKET = 16  # mirrors ServingEngine's min_bucket default


def expected_out_tokens(model: ModelProfile, difficulty) -> np.ndarray:
    gap = np.maximum(0.15, 0.75 + difficulty - model.capability)
    return _COT_BASE + _COT_SCALE * gap ** 2


def bucketed_tokens(n, minimum: int = _PREFILL_MIN_BUCKET) -> np.ndarray:
    """Power-of-two shape bucket a prompt of ``n`` tokens is padded to by
    the serving engine's anti-recompile-storm prefill path."""
    n = np.maximum(np.asarray(n, float), 1.0)
    return np.maximum(2.0 ** np.ceil(np.log2(n)), float(minimum))


def chunked_prefill_tokens(prompt_tokens, prefill_chunk: int,
                           minimum: int = _PREFILL_MIN_BUCKET) -> np.ndarray:
    """Token positions the engine's bucketed + chunked prefill actually
    computes for a prompt: full ``prefill_chunk``-sized chunks plus the
    remainder padded up to its power-of-two bucket.  With chunking off
    (``prefill_chunk == 0``) the whole prompt is one bucket.  This is the
    term the router's latency estimates use so they track the real engine
    (ServingEngine ``prefill_chunk`` / ``bucket_prompts`` knobs).
    """
    t = np.asarray(prompt_tokens, float)
    if not prefill_chunk:
        return bucketed_tokens(t, minimum)
    full = np.floor(t / prefill_chunk) * prefill_chunk
    rem = t - full
    return full + np.where(rem > 0,
                           bucketed_tokens(np.maximum(rem, 1.0), minimum),
                           0.0)


def prefill_s(device: DeviceProfile, model: ModelProfile, prompt_tokens,
              prefill_chunk: int | None = None):
    """Prefill-only roofline term (the part a prefix-cache hit elides).

    ``prefill_chunk`` (None = legacy smooth model) switches to the serving
    engine's bucketed/chunked token count, whose padding makes prefill a
    step function of prompt length rather than a straight line.
    """
    tokens = (np.asarray(prompt_tokens)
              if prefill_chunk is None
              else chunked_prefill_tokens(prompt_tokens, prefill_chunk))
    return 2.0 * model.n_active * tokens / (device.flops * _EFF)


def latency_s(device: DeviceProfile, model: ModelProfile, prompt_tokens,
              difficulty, rng: np.random.Generator | None = None,
              prefix_hit_rate=0.0, prefill_chunk: int | None = None):
    """Roofline latency; lognormal noise if rng given.

    ``prefix_hit_rate`` is the expected fraction of prompt tokens already
    resident in the server's paged KV prefix cache (repro/serving/kv_cache):
    hit tokens skip prefill compute entirely, so the prefill term scales by
    ``1 - hit_rate``.  Decode and transmission are unaffected.

    ``prefill_chunk`` (None = legacy smooth model) models the serving
    engine's bucketed + chunked prefill instead: compute covers the padded
    bucket shapes, so the estimate tracks what the engine actually runs.
    """
    hit = np.clip(np.asarray(prefix_hit_rate, float), 0.0, 1.0)
    prefill = prefill_s(device, model, prompt_tokens,
                        prefill_chunk=prefill_chunk) * (1.0 - hit)
    out_tok = expected_out_tokens(model, np.asarray(difficulty))
    if rng is not None:
        out_tok = out_tok * rng.lognormal(0.0, 0.35, np.shape(out_tok))
    decode = out_tok * model.n_active * model.bytes_per_param / (
        device.mem_bw * _EFF)
    trans = _PAYLOAD / device.net_bw + device.rtt
    return prefill + decode + trans


def success_prob(model: ModelProfile, difficulty, affinity=0.0) -> np.ndarray:
    z = _QUALITY_SLOPE * (model.capability - np.asarray(difficulty)
                          + affinity) - 0.5
    return 1.0 / (1.0 + np.exp(-z))


def category_affinity(n_categories: int, n_models: int, seed: int = 7):
    """Per-(category, model) quality offsets — some models are better at
    some task families."""
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, 0.08, (n_categories, n_models))
