"""Analytic latency + quality response model (QUARANTINED SIMULATION GATE).

The real MIOBench records wall-clock latencies and answer correctness
measured on RTX5090 / RTX3090Ti / Jetson-Orin hardware running Qwen3-VL
{30B, 8B, 2B} under Ollama.  None of that hardware (or weights) exists in
this container, so this module replaces measurement with a roofline latency
model + a calibrated capability-difficulty response model:

  latency  = prefill(prompt_tok)      2*N_active*T / FLOPS_eff
           + decode(out_tok)          out_tok * bytes_active / MEM_BW_eff
           + transmission             payload / bandwidth + RTT
  out_tok  ~ CoT inflation: smaller capability & harder tasks => longer
             chains of thought (the paper's Sec. I observation)
  success  ~ Bernoulli(sigmoid(a * (capability - difficulty + affinity)))
  timeout  : latency > 60 s  =>  score -1 (counts as failure)

Constants are calibrated so Fig. 1 aggregates match the paper:
Jetson ~66.7% acc / ~26.3% timeouts; RTX5090 ~90% acc, 0 timeouts, <10 s.
"""
from __future__ import annotations

import dataclasses

import numpy as np

TIMEOUT_S = 60.0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops: float  # effective FLOP/s for prefill
    mem_bw: float  # effective B/s for decode
    net_bw: float  # B/s to the user (LAN for edge, WAN for cloud)
    rtt: float  # s
    hbm_bytes: float = 16e9  # accelerator memory (caps resident KV)
    # device-to-device interconnect B/s *within* a tensor-parallel group
    # (NVLink / ICI / PCIe) — what the per-layer all-gathers of sharded
    # serving ride on; irrelevant at tp=1
    ici_bw: float = 1e11


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    n_active: float  # active params
    bytes_per_param: float  # quantization
    capability: float  # cognitive capability score
    # KV-cache geometry (n_layers, kv_heads, head_dim): rough dims of the
    # profiled checkpoints, enough for per-token KV byte rooflines
    kv_layout: "tuple[int, int, int]" = (28, 4, 128)
    # residual width — sizes the per-layer activation all-gathers of
    # tensor-parallel serving (tp_collective_s)
    d_model: float = 2048.0


DEVICES = {
    "jetson_orin_nano": DeviceProfile("jetson_orin_nano", 20e12, 48e9,
                                      12.5e6, 0.004, hbm_bytes=8e9,
                                      ici_bw=8e9),  # no NVLink: PCIe-class
    "rtx3090ti": DeviceProfile("rtx3090ti", 120e12, 800e9, 12.5e6, 0.004,
                               hbm_bytes=24e9, ici_bw=16e9),
    "rtx5090": DeviceProfile("rtx5090", 300e12, 1.5e12, 3e6, 0.030,
                             hbm_bytes=32e9, ici_bw=32e9),
    # TPU-native serving classes (hardware adaptation; README.md, Design notes)
    "tpu_v5e_1": DeviceProfile("tpu_v5e_1", 197e12, 819e9, 12.5e6, 0.004,
                               hbm_bytes=16e9, ici_bw=180e9),
    "tpu_v5e_4": DeviceProfile("tpu_v5e_4", 4 * 197e12, 4 * 819e9,
                               12.5e6, 0.004, hbm_bytes=4 * 16e9,
                               ici_bw=180e9),
    "tpu_v5e_pod": DeviceProfile("tpu_v5e_pod", 256 * 197e12, 256 * 819e9,
                                 3e6, 0.030, hbm_bytes=256 * 16e9,
                                 ici_bw=180e9),
}

MODELS = {
    "qwen3vl-2b": ModelProfile("qwen3vl-2b", 2e9, 1.0, 0.94,
                               kv_layout=(28, 2, 128), d_model=2048.0),
    "qwen3vl-8b": ModelProfile("qwen3vl-8b", 8e9, 1.0, 0.88,
                               kv_layout=(36, 4, 128), d_model=4096.0),
    "qwen3vl-30b": ModelProfile("qwen3vl-30b", 3e9, 2.0, 1.02,  # MoE A3B
                                kv_layout=(48, 4, 128), d_model=2048.0),
}

MODEL_IDS = list(MODELS)
DEVICE_IDS = list(DEVICES)

# calibration knobs
_QUALITY_SLOPE = 5.5
_COT_BASE = 90.0  # base answer tokens
_COT_SCALE = 2800.0  # extra CoT tokens at (difficulty - capability) = 1
_EFF = 0.35  # achieved fraction of peak

# per-modality raw uplink payloads (bytes).  text + image reproduce the
# historical single 300 KB constant, so every calibrated Fig. 1 aggregate
# is unchanged; audio ~ 15 s of 16 kHz 16-bit PCM.
PAYLOAD_BYTES = {"text": 2e3, "image": 298e3, "audio": 480e3}


def payload_bytes(modalities=("text", "image")) -> float:
    """Total raw uplink bytes for a request carrying ``modalities``."""
    return float(sum(PAYLOAD_BYTES[m] for m in modalities))


_PAYLOAD = payload_bytes()  # legacy default: text prompt + one image


def uplink_s(nbytes, device: DeviceProfile):
    """One-way user->server link delay for ``nbytes`` of payload.  The
    single link-delay formula shared by the analytic latency model and the
    live continuum harness (serving/cluster.EngineHandle) — previously
    each computed its own."""
    return np.asarray(nbytes, float) / device.net_bw + device.rtt / 2


def downlink_s(nbytes, device: DeviceProfile):
    """One-way server->user link delay (same roofline, response bytes)."""
    return uplink_s(nbytes, device)


# one streamed token chunk on the wire: a few bytes of token id plus the
# SSE/frame framing overhead every streaming protocol pays per event
STREAM_CHUNK_BYTES = 256.0


def stream_chunk_s(device: DeviceProfile,
                   nbytes: float = STREAM_CHUNK_BYTES):
    """Server->user link delay of ONE streamed token chunk.

    Streaming replaces the single end-of-request response transfer with a
    per-token trickle: each decoded token reaches the user
    ``stream_chunk_s`` after it was sampled, so TTFT is measured at the
    first *emitted* token + one chunk, not at drain + the full payload.
    Chunks pipeline (the link is not serialized per chunk at these
    sizes), so e2e pays this once — the last chunk's latency — rather
    than ``n_tokens`` times."""
    return downlink_s(nbytes, device)


_PREFILL_MIN_BUCKET = 16  # mirrors ServingEngine's min_bucket default

# ------------------------------------------------------- KV-cache roofline
#
# The bytes/token -> decode_s -> router-score chain: decode is memory-
# bandwidth-bound, and what streams through HBM every generated token is
# (a) the active weights and (b) the resident KV context.  Quantizing KV
# to int8 (ServingEngine kv_dtype="int8") halves (b) — kv_bytes_per_token
# drops ~2x — which lowers decode_s and, through EngineHandle's tick cost
# and backlog probe, the router's effective-latency score for that server;
# the same bytes/token figure divides the device's HBM budget, so it also
# sets how many sequences can be resident at once (kv_concurrency).  The
# per-element byte costs mirror repro/serving/kv_cache.KV_DTYPE_BYTES.

KV_DTYPE_BYTES = {"bf16": 2.0, "int8": 1.0}
_KV_SCALE_BYTES = 4.0  # fp32 scale per (token, kv head) row, int8 only


def kv_bytes_per_token(model: ModelProfile, kv_dtype: str = "bf16") -> float:
    """HBM bytes one token's K+V occupy across all layers of ``model``."""
    L, hkv, dh = model.kv_layout
    per_head = dh * KV_DTYPE_BYTES[kv_dtype]
    if kv_dtype == "int8":
        per_head += _KV_SCALE_BYTES
    return 2.0 * L * hkv * per_head


def kv_migrate_bytes(model: ModelProfile, n_tokens,
                     kv_dtype: str = "bf16") -> float:
    """Bytes a KV snapshot of ``n_tokens`` context costs on the wire.

    Priced at the **destination** engine's ``kv_dtype``: the importer
    converts pages to its own pool precision on adoption
    (serving/engine._admit_imported), so an int8 edge tier receives ~half
    the bytes a bf16 tier would for the same context — the PR 5 byte
    saving extended to migration traffic."""
    return float(np.asarray(n_tokens, float)
                 * kv_bytes_per_token(model, kv_dtype))


def migrate_link_s(nbytes, src: DeviceProfile, dst: DeviceProfile):
    """Server->server transfer seconds for a KV snapshot: serialization
    on the narrower of the two links plus one half-RTT on each side."""
    bw = min(src.net_bw, dst.net_bw)
    return np.asarray(nbytes, float) / bw + (src.rtt + dst.rtt) / 2


def migrate_s(model: ModelProfile, n_tokens, src: DeviceProfile,
              dst: DeviceProfile, kv_dtype: str = "bf16"):
    """Seconds to move ``n_tokens`` of KV context from ``src`` to ``dst``
    at the destination's ``kv_dtype`` — the cost-model view of the live
    migration the continuum harness charges (serving/cluster.migrate)."""
    return migrate_link_s(kv_migrate_bytes(model, n_tokens, kv_dtype),
                          src, dst)


# ------------------------------------------------- tensor-parallel terms
#
# A tp-wide mesh (distributed/tp.py) divides the weight + KV bytes each
# token streams and the prefill FLOPs across the ``model`` axis, but pays
# ring all-gathers of the residual activations every layer.  The tp terms
# are guarded with an exact early return at tp<=1 so every calibrated
# single-device aggregate (Fig. 1/10/12/13/14) stays bitwise unchanged.

_TP_GATHERS_PER_LAYER = 2.0  # attention-out + mlp-down gather pairs


def tp_collective_s(device: DeviceProfile, model: ModelProfile, tokens,
                    tp: int) -> np.ndarray:
    """Seconds the per-layer activation all-gathers cost for ``tokens``
    token-positions at mesh width ``tp``: each gather pair moves
    ``2 * (tp-1)/tp`` of a bf16 ``d_model`` row per token over the
    device's ``ici_bw`` ring.  0 at ``tp <= 1`` (no collectives)."""
    if tp <= 1:
        return np.asarray(tokens, float) * 0.0
    L = model.kv_layout[0]
    bytes_per_tok = (_TP_GATHERS_PER_LAYER * L
                     * 2.0 * (tp - 1) / tp * model.d_model * 2.0)
    return np.asarray(tokens, float) * bytes_per_tok / device.ici_bw


def decode_s(device: DeviceProfile, model: ModelProfile, out_tokens,
             context_tokens=0.0, kv_dtype: str = "bf16",
             tp: int = 1) -> np.ndarray:
    """Decode roofline: every generated token streams the active weights
    plus the resident KV context (``context_tokens`` positions) through
    HBM.  ``context_tokens=0`` recovers the legacy weights-only decode
    term used by ``latency_s``'s calibrated aggregates.  ``tp > 1``
    divides the streamed bytes across the mesh and adds the per-layer
    collective term."""
    bytes_per_tok = (model.n_active * model.bytes_per_param
                     + kv_bytes_per_token(model, kv_dtype)
                     * np.asarray(context_tokens, float))
    base = np.asarray(out_tokens, float) * bytes_per_tok / (
        device.mem_bw * _EFF)
    if tp <= 1:
        return base
    return base / tp + tp_collective_s(device, model, out_tokens, tp)


def kv_concurrency(device: DeviceProfile, model: ModelProfile,
                   seq_len: int, kv_dtype: str = "bf16",
                   hbm_frac: float = 0.3) -> int:
    """Sequences of ``seq_len`` whose KV fits the device's cache budget
    (``hbm_frac`` of the HBM left after the resident weights) — the
    per-device concurrency cap int8 roughly doubles, which is what lets
    edge tiers admit more requests at the same memory.  0 when the
    weights alone do not fit the device."""
    free = device.hbm_bytes - model.n_active * model.bytes_per_param
    if free <= 0:
        return 0
    per_seq = seq_len * kv_bytes_per_token(model, kv_dtype)
    return int(hbm_frac * free / per_seq)


# ---------------------------------------------------- speculative decoding
#
# Speculative serving replaces one target decode step per token with
# ``k`` draft-model decode steps plus ONE multi-token verify pass of the
# target (kernels/paged_verify): the verify streams the target weights and
# the KV context once — like a single decode step — while scoring k+1
# positions, so its extra cost over plain decode is almost pure FLOPs.
# At acceptance rate ``a`` each tick emits 1..k+1 tokens (expected
# ``(1 - a^(k+1)) / (1 - a)``), which is what discounts the effective ITL.


def draft_s(device: DeviceProfile, draft_model: ModelProfile,
            tokens=1.0, context_tokens=0.0) -> np.ndarray:
    """Seconds the draft model spends proposing ``tokens`` tokens — plain
    decode roofline of the (small) draft profile; the draft cache is
    dense bf16 regardless of the target pool's precision."""
    return decode_s(device, draft_model, tokens,
                    context_tokens=context_tokens, kv_dtype="bf16")


def verify_s(device: DeviceProfile, model: ModelProfile, k,
             context_tokens=0.0, kv_dtype: str = "bf16",
             tp: int = 1) -> np.ndarray:
    """One multi-token verify pass scoring ``k`` positions: the active
    weights and the resident KV context stream through HBM **once**
    (the paged-verify kernel reads each page a single time for all query
    rows), plus ``2 * n_active * k`` FLOPs of batched scoring.  ``tp > 1``
    divides both across the mesh, plus one collective term for the pass
    (all k rows share each layer's gathers)."""
    weights = model.n_active * model.bytes_per_param
    kv = kv_bytes_per_token(model, kv_dtype) * np.asarray(
        context_tokens, float)
    mem = (weights + kv) / (device.mem_bw * _EFF)
    flop = 2.0 * model.n_active * np.asarray(k, float) / (
        device.flops * _EFF)
    if tp <= 1:
        return mem + flop
    return (mem + flop) / tp + tp_collective_s(device, model, k, tp)


def expected_accepted(k, acceptance) -> np.ndarray:
    """Expected tokens emitted per speculative tick with ``k`` drafts at
    per-token acceptance rate ``a``: the accepted prefix plus the
    target's correction/bonus token, ``1 + a + ... + a^k``."""
    a = np.clip(np.asarray(acceptance, float), 0.0, 0.9999)
    return (1.0 - a ** (np.asarray(k, float) + 1.0)) / (1.0 - a)


def speculative_tick_s(device: DeviceProfile, model: ModelProfile,
                       draft_model: ModelProfile, k, context_tokens=0.0,
                       kv_dtype: str = "bf16",
                       draft_device: DeviceProfile | None = None,
                       tp: int = 1):
    """Seconds one speculative tick costs: ``k`` draft decode steps (on
    ``draft_device`` — None = colocated with the target; the edge-drafts/
    cloud-verifies shape prices drafting on the edge device) plus one
    ``k+1``-position verify pass of the target.  ``tp`` shards only the
    target's verify — the draft model stays unsharded (distributed/tp.py
    leaves it replicated)."""
    dd = draft_device if draft_device is not None else device
    return (np.asarray(k, float)
            * draft_s(dd, draft_model, 1.0, context_tokens)
            + verify_s(device, model, np.asarray(k, float) + 1.0,
                       context_tokens, kv_dtype, tp=tp))


def speculative_itl_s(device: DeviceProfile, model: ModelProfile,
                      draft_model: ModelProfile, k, acceptance,
                      context_tokens=0.0, kv_dtype: str = "bf16",
                      draft_device: DeviceProfile | None = None):
    """Acceptance-discounted effective inter-token latency of speculative
    decoding: one tick's cost amortized over the expected emitted tokens.
    Below-breakeven acceptance makes this *worse* than plain decode —
    exactly the signal the router needs to fall back."""
    tick = speculative_tick_s(device, model, draft_model, k,
                              context_tokens, kv_dtype,
                              draft_device=draft_device)
    return tick / expected_accepted(k, acceptance)


def expected_out_tokens(model: ModelProfile, difficulty) -> np.ndarray:
    gap = np.maximum(0.15, 0.75 + difficulty - model.capability)
    return _COT_BASE + _COT_SCALE * gap ** 2


def bucketed_tokens(n, minimum: int = _PREFILL_MIN_BUCKET) -> np.ndarray:
    """Power-of-two shape bucket a prompt of ``n`` tokens is padded to by
    the serving engine's anti-recompile-storm prefill path."""
    n = np.maximum(np.asarray(n, float), 1.0)
    return np.maximum(2.0 ** np.ceil(np.log2(n)), float(minimum))


def chunked_prefill_tokens(prompt_tokens, prefill_chunk: int,
                           minimum: int = _PREFILL_MIN_BUCKET) -> np.ndarray:
    """Token positions the engine's bucketed + chunked prefill actually
    computes for a prompt: full ``prefill_chunk``-sized chunks plus the
    remainder padded up to its power-of-two bucket.  With chunking off
    (``prefill_chunk == 0``) the whole prompt is one bucket.  This is the
    term the router's latency estimates use so they track the real engine
    (ServingEngine ``prefill_chunk`` / ``bucket_prompts`` knobs).
    """
    t = np.asarray(prompt_tokens, float)
    if not prefill_chunk:
        return bucketed_tokens(t, minimum)
    full = np.floor(t / prefill_chunk) * prefill_chunk
    rem = t - full
    return full + np.where(rem > 0,
                           bucketed_tokens(np.maximum(rem, 1.0), minimum),
                           0.0)


def prefill_s(device: DeviceProfile, model: ModelProfile, prompt_tokens,
              prefill_chunk: int | None = None, tp: int = 1):
    """Prefill-only roofline term (the part a prefix-cache hit elides).

    ``prefill_chunk`` (None = legacy smooth model) switches to the serving
    engine's bucketed/chunked token count, whose padding makes prefill a
    step function of prompt length rather than a straight line.  ``tp > 1``
    divides the FLOPs across the mesh plus the per-position collectives.
    """
    tokens = (np.asarray(prompt_tokens)
              if prefill_chunk is None
              else chunked_prefill_tokens(prompt_tokens, prefill_chunk))
    base = 2.0 * model.n_active * tokens / (device.flops * _EFF)
    if tp <= 1:
        return base
    return base / tp + tp_collective_s(device, model, tokens, tp)


def latency_terms(device: DeviceProfile, model: ModelProfile, prompt_tokens,
                  difficulty, rng: np.random.Generator | None = None,
                  prefix_hit_rate=0.0, prefill_chunk: int | None = None,
                  kv_dtype: str | None = None,
                  prefill_device: DeviceProfile | None = None,
                  migrate_kv_dtype: str | None = None) -> dict:
    """Per-term decomposition of the roofline latency — the breakdown the
    telemetry dispatch audit records per routed request
    (repro/serving/telemetry.DispatchRecord).  ``latency_s`` is the summed
    view; the op order here is identical, so ``total_s`` matches it
    bit-for-bit under every knob combination.

    ``prefill_device`` (None = same device) prices the disaggregated
    dispatch shape: prefill runs there, the prompt's KV migrates to
    ``device`` for decode, and a ``migrate_s`` term (priced at the
    *decode* side's KV precision — ``migrate_kv_dtype`` overrides, else
    ``kv_dtype``, else bf16) charges the transfer.  ``migrate_s`` is 0.0
    whenever both phases share a device.
    """
    hit = np.clip(np.asarray(prefix_hit_rate, float), 0.0, 1.0)
    pf_dev = prefill_device if prefill_device is not None else device
    prefill = prefill_s(pf_dev, model, prompt_tokens,
                        prefill_chunk=prefill_chunk) * (1.0 - hit)
    out_tok = expected_out_tokens(model, np.asarray(difficulty))
    if rng is not None:
        out_tok = out_tok * rng.lognormal(0.0, 0.35, np.shape(out_tok))
    if kv_dtype is None:
        decode = decode_s(device, model, out_tok)
    else:
        ctx = np.asarray(prompt_tokens, float) + out_tok / 2.0
        decode = decode_s(device, model, out_tok, context_tokens=ctx,
                          kv_dtype=kv_dtype)
    migrate = 0.0
    if prefill_device is not None and prefill_device.name != device.name:
        migrate = migrate_s(model, prompt_tokens, prefill_device, device,
                            kv_dtype=migrate_kv_dtype or kv_dtype or "bf16")
    # request up + (byte-free) response down == payload/bw + rtt, the
    # historical transmission term
    trans = uplink_s(_PAYLOAD, device) + downlink_s(0.0, device)
    return {"prefill_s": prefill, "decode_s": decode, "link_s": trans,
            "migrate_s": migrate,
            "total_s": prefill + decode + trans + migrate}


def latency_s(device: DeviceProfile, model: ModelProfile, prompt_tokens,
              difficulty, rng: np.random.Generator | None = None,
              prefix_hit_rate=0.0, prefill_chunk: int | None = None,
              kv_dtype: str | None = None,
              prefill_device: DeviceProfile | None = None,
              migrate_kv_dtype: str | None = None):
    """Roofline latency; lognormal noise if rng given.

    ``prefix_hit_rate`` is the expected fraction of prompt tokens already
    resident in the server's paged KV prefix cache (repro/serving/kv_cache):
    hit tokens skip prefill compute entirely, so the prefill term scales by
    ``1 - hit_rate``.  Decode and transmission are unaffected.

    ``prefill_chunk`` (None = legacy smooth model) models the serving
    engine's bucketed + chunked prefill instead: compute covers the padded
    bucket shapes, so the estimate tracks what the engine actually runs.

    ``kv_dtype`` (None = legacy weights-only decode, keeping the
    calibrated Fig. 1 aggregates untouched) adds the KV-streaming term to
    decode: each generated token also reads the resident context
    (prompt + the mean half of the answer so far) at
    ``kv_bytes_per_token(model, kv_dtype)`` — the bytes/token → decode_s
    → router-score chain int8 KV compresses.

    See ``latency_terms`` for the per-term decomposition the telemetry
    dispatch audit records.
    """
    return latency_terms(device, model, prompt_tokens, difficulty, rng=rng,
                         prefix_hit_rate=prefix_hit_rate,
                         prefill_chunk=prefill_chunk,
                         kv_dtype=kv_dtype,
                         prefill_device=prefill_device,
                         migrate_kv_dtype=migrate_kv_dtype)["total_s"]


def success_prob(model: ModelProfile, difficulty, affinity=0.0) -> np.ndarray:
    z = _QUALITY_SLOPE * (model.capability - np.asarray(difficulty)
                          + affinity) - 0.5
    return 1.0 / (1.0 + np.exp(-z))


# --------------------------------------------------- split-point offloading
#
# A multimodal request can cross the cloud-edge boundary at two points
# (MoA-Off / CE-CoLLM): ship the *raw* media over the uplink and encode at
# the destination server, or run the modality encoder on the source edge
# device and ship the (keep-top-k compressed) *features*.  Everything the
# decision needs is a roofline: encoder FLOPs on either device plus the
# per-modality uplink bytes of whichever representation travels.


@dataclasses.dataclass(frozen=True)
class MediaSpec:
    """Cost-model view of one media input (paper-scale encoder dims, so
    the decision operates at profiled-hardware magnitudes regardless of
    the reduced live encoder actually producing the features)."""

    modality: str  # key into PAYLOAD_BYTES
    raw_bytes: float  # raw media over the uplink
    feature_bytes: float  # encoded (compressed) features over the uplink
    encode_tokens: int  # patches / frames through the encoder
    encode_dim: int = 768  # ViT-B-ish trunk
    encode_layers: int = 12
    encode_ff: int = 3072


def media_spec(modality: str, keep_ratio: float = 1.0) -> MediaSpec:
    """Paper-scale spec per modality; ``keep_ratio`` is the keep-top-k
    pooling knob (models/mm_encoder.py) scaling the kept span and with it
    the feature-uplink bytes (bf16 features)."""
    tokens = {"image": 197, "audio": 1500}[modality]  # ViT-B/16, whisper
    kept = max(1, int(np.ceil(keep_ratio * tokens)))
    return MediaSpec(modality, raw_bytes=PAYLOAD_BYTES[modality],
                     feature_bytes=kept * 768 * 2, encode_tokens=tokens)


def mm_encode_s(device: DeviceProfile, spec: MediaSpec):
    """Roofline seconds to run the modality encoder on ``device``."""
    d, ff = spec.encode_dim, spec.encode_ff
    flops = spec.encode_tokens * spec.encode_layers * (8 * d * d
                                                       + 4 * d * ff)
    return flops / (device.flops * _EFF)


def split_point_s(spec: MediaSpec, src: DeviceProfile,
                  dst: DeviceProfile) -> dict:
    """Extra seconds (beyond the text payload) each split choice costs:
    ``raw`` ships the media and encodes at the destination, ``edge``
    encodes at the source and ships compressed features over the
    destination's link.  Pure serialization + encode: the link RTT is
    already paid once by the request itself, whichever form the media
    rides along in."""
    return {
        "raw": float(spec.raw_bytes / dst.net_bw + mm_encode_s(dst, spec)),
        "edge": float(mm_encode_s(src, spec)
                      + spec.feature_bytes / dst.net_bw),
    }


def best_split(spec: MediaSpec, src: DeviceProfile,
               dst: DeviceProfile) -> "tuple[str, float]":
    """(choice, extra_s): the cheaper of raw-ship vs edge-encode.  Slow
    uplinks favor edge encoding (features are smaller than media); fast
    uplinks with a weak source device favor shipping raw."""
    costs = split_point_s(spec, src, dst)
    choice = min(costs, key=costs.get)
    return choice, costs[choice]


def category_affinity(n_categories: int, n_models: int, seed: int = 7):
    """Per-(category, model) quality offsets — some models are better at
    some task families."""
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, 0.08, (n_categories, n_models))
