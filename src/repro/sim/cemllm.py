"""CEMLLM-Sim: trace-driven cloud-edge collaborative MLLM system simulator
(paper Sec. V-B).

Replays MIOBench: any offloading decision's ground-truth latency/quality is a
table lookup, so policies train/evaluate without real hardware.  Supports the
paper's 5/10/15-server configurations (Table III), per-server queues (Eq. 3),
timeouts, episodes, and health/failure injection (serving-layer fault
tolerance hooks).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.cost_model import TIMEOUT_S
from repro.sim.miobench import MIOBench, SERVER_CLASSES


# paper Table III: (class_index, count) per configuration; class 2 = cloud
SYSTEM_CONFIGS = {
    5: [(2, 1), (1, 1), (0, 3)],
    10: [(2, 1), (1, 2), (0, 7)],
    15: [(2, 1), (1, 4), (0, 10)],
}


@dataclasses.dataclass
class Servers:
    """Static server table for one configuration."""
    cls: np.ndarray  # [E+1] server-class index into SERVER_CLASSES
    model_id: np.ndarray  # [E+1]
    device_id: np.ndarray  # [E+1]
    is_cloud: np.ndarray  # [E+1] bool

    @property
    def n(self) -> int:
        return len(self.cls)


def make_servers(n_servers: int, bench: MIOBench) -> Servers:
    spec = SYSTEM_CONFIGS[n_servers]
    cls = []
    for class_idx, count in spec:
        cls += [class_idx] * count
    cls = np.array(cls)
    return Servers(cls=cls,
                   model_id=bench.model_id[cls],
                   device_id=bench.device_id[cls],
                   is_cloud=(cls == len(SERVER_CLASSES) - 1))


class Episode:
    """One decision episode: U users each propose a task; a policy assigns
    each task to a server; queues accumulate (Eqs. 2-3)."""

    def __init__(self, bench: MIOBench, servers: Servers, task_ids,
                 rng: np.random.Generator, failed: np.ndarray | None = None):
        self.bench = bench
        self.servers = servers
        self.task_ids = np.asarray(task_ids)
        self.rng = rng
        self.queue_s = np.zeros(servers.n)  # actual queued latency (Eq. 3)
        self.queue_len = np.zeros(servers.n, np.int64)
        self.t = 0
        # failure injection: a failed server never completes tasks and its
        # queue grows unboundedly (fault-tolerance experiments)
        self.failed = (np.zeros(servers.n, bool) if failed is None else failed)

    @property
    def done(self) -> bool:
        return self.t >= len(self.task_ids)

    @property
    def current_task(self) -> int:
        return int(self.task_ids[self.t])

    def ground_truth(self, task: int, server: int):
        """(response_latency_s, success_bool) for this offloading decision."""
        c = int(self.servers.cls[server])
        lat = float(self.bench.latency_s[task, c])
        sc = int(self.bench.score[task, c])
        if self.failed[server]:
            return TIMEOUT_S * 4, False
        return lat, sc == 1

    def step(self, server: int):
        """Offload the current task; returns a record dict."""
        task = self.current_task
        lat_r, ok = self.ground_truth(task, server)
        total = lat_r + self.queue_s[server]  # Eq. 2
        timeout = total > TIMEOUT_S
        success = ok and not timeout
        self.queue_s[server] += lat_r
        self.queue_len[server] += 1
        self.t += 1
        return {"task": task, "server": server, "latency_r": lat_r,
                "latency_total": total, "success": success,
                "timeout": timeout}


def greedy_latencies(bench: MIOBench, servers: Servers, task_ids):
    """The paper's Greedy comparator (Eq. 21): offload each task to the
    server with the shortest queue; returns per-task total latency."""
    q = np.zeros(servers.n)
    out = np.zeros(len(task_ids))
    for i, t in enumerate(task_ids):
        s = int(np.argmin(q))
        lat = bench.latency_s[int(t), servers.cls[s]]
        out[i] = lat + q[s]
        q[s] += lat
    return out


def run_policy(policy, bench: MIOBench, servers: Servers, task_ids,
               rng: np.random.Generator, failed=None) -> dict:
    """Roll a full episode with ``policy(episode) -> server``; aggregate the
    paper's metrics."""
    ep = Episode(bench, servers, task_ids, rng, failed=failed)
    lat, succ = [], []
    while not ep.done:
        rec = ep.step(policy(ep))
        lat.append(rec["latency_total"])
        succ.append(rec["success"])
    return {"avg_latency_s": float(np.mean(lat)),
            "completion_rate": float(np.mean(succ)),
            "p95_latency_s": float(np.percentile(lat, 95))}
