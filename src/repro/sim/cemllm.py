"""CEMLLM-Sim: trace-driven cloud-edge collaborative MLLM system simulator
(paper Sec. V-B).

Replays MIOBench: any offloading decision's ground-truth latency/quality is a
table lookup, so policies train/evaluate without real hardware.  Supports the
paper's 5/10/15-server configurations (Table III), per-server queues (Eq. 3),
timeouts, episodes, and health/failure injection (serving-layer fault
tolerance hooks).

The *execution backend* of an episode is pluggable:

  * ``CostModelBackend`` (default) — the closed-form table lookup above;
    every record resolves at dispatch time.  This is what policy training
    uses (immediate rewards).
  * ``EngineBackend`` (repro/serving/cluster.py) — each decision submits a
    real request to a live ``ServingEngine`` behind the chosen server and
    the continuum harness advances all engines under a shared virtual
    clock.  Records are *pending* until ``Episode.finalize()`` drains the
    cluster, which patches in measured TTFT / e2e latency; the provisional
    latency/success at dispatch time is the same cost-model estimate the
    default backend returns, so a deterministic policy takes identical
    decisions under either backend (backend parity).

Both backends expose ``execute(task, server) -> (latency_r, ok, resolved)``
and ``drain() -> None``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.cost_model import TIMEOUT_S
from repro.sim.miobench import MIOBench, SERVER_CLASSES


# paper Table III: (class_index, count) per configuration; class 2 = cloud
SYSTEM_CONFIGS = {
    5: [(2, 1), (1, 1), (0, 3)],
    10: [(2, 1), (1, 2), (0, 7)],
    15: [(2, 1), (1, 4), (0, 10)],
}


@dataclasses.dataclass
class Servers:
    """Static server table for one configuration."""
    cls: np.ndarray  # [E+1] server-class index into SERVER_CLASSES
    model_id: np.ndarray  # [E+1]
    device_id: np.ndarray  # [E+1]
    is_cloud: np.ndarray  # [E+1] bool

    @property
    def n(self) -> int:
        return len(self.cls)


def make_servers_from_spec(spec, bench: MIOBench) -> Servers:
    """Server table from an explicit ``[(class_idx, count), ...]`` spec —
    the same layout the continuum harness's ``build_continuum`` uses, so a
    sim ``Servers`` table and a list of live ``EngineHandle``s built from
    one spec index the same fleet."""
    cls = []
    for class_idx, count in spec:
        cls += [class_idx] * count
    cls = np.array(cls)
    return Servers(cls=cls,
                   model_id=bench.model_id[cls],
                   device_id=bench.device_id[cls],
                   is_cloud=(cls == len(SERVER_CLASSES) - 1))


def make_servers(n_servers: int, bench: MIOBench) -> Servers:
    return make_servers_from_spec(SYSTEM_CONFIGS[n_servers], bench)


class CostModelBackend:
    """Closed-form execution: ground-truth latency/quality table lookup.

    Every decision resolves immediately; ``drain`` is a no-op."""

    def __init__(self, bench: MIOBench, servers: Servers,
                 failed: np.ndarray):
        self.bench = bench
        self.servers = servers
        self.failed = failed

    def execute(self, task: int, server: int):
        """(response_latency_s, success_bool, resolved=True)."""
        c = int(self.servers.cls[server])
        lat = float(self.bench.latency_s[task, c])
        sc = int(self.bench.score[task, c])
        if self.failed[server]:
            return TIMEOUT_S * 4, False, True
        return lat, sc == 1, True

    def drain(self):
        pass


class Episode:
    """One decision episode: U users each propose a task; a policy assigns
    each task to a server; queues accumulate (Eqs. 2-3).

    ``backend`` (default ``CostModelBackend``) performs the actual
    execution; pass ``repro.serving.cluster.EngineBackend`` to replay the
    episode against live ``ServingEngine`` instances.  With a pending
    backend, call ``finalize()`` after the last ``step`` so measured
    latencies replace the dispatch-time estimates in the returned records
    (the record dicts are patched in place)."""

    def __init__(self, bench: MIOBench, servers: Servers, task_ids,
                 rng: np.random.Generator, failed: np.ndarray | None = None,
                 backend=None):
        self.bench = bench
        self.servers = servers
        self.task_ids = np.asarray(task_ids)
        self.rng = rng
        self.queue_s = np.zeros(servers.n)  # actual queued latency (Eq. 3)
        self.queue_len = np.zeros(servers.n, np.int64)
        self.t = 0
        # failure injection: a failed server never completes tasks and its
        # queue grows unboundedly (fault-tolerance experiments)
        self.failed = (np.zeros(servers.n, bool) if failed is None else failed)
        self._cost = CostModelBackend(bench, servers, self.failed)
        self.backend = self._cost if backend is None else backend

    @property
    def done(self) -> bool:
        return self.t >= len(self.task_ids)

    @property
    def current_task(self) -> int:
        return int(self.task_ids[self.t])

    def ground_truth(self, task: int, server: int):
        """(response_latency_s, success_bool) for this offloading decision
        under the closed-form cost model (backend-independent estimate)."""
        lat, ok, _ = self._cost.execute(task, server)
        return lat, ok

    def step(self, server: int):
        """Offload the current task; returns a record dict.  When the
        backend is asynchronous the latency/success fields hold the
        cost-model estimate until ``finalize()`` patches them."""
        task = self.current_task
        lat_r, ok, resolved = self.backend.execute(task, server)
        total = lat_r + self.queue_s[server]  # Eq. 2
        timeout = total > TIMEOUT_S
        success = ok and not timeout
        self.queue_s[server] += lat_r
        self.queue_len[server] += 1
        self.t += 1
        rec = {"task": task, "server": server, "latency_r": lat_r,
               "latency_total": total, "success": success,
               "timeout": timeout, "pending": not resolved}
        if not resolved:
            self.backend.register(rec)
        return rec

    def finalize(self):
        """Resolve pending records (no-op for the cost-model backend)."""
        self.backend.drain()


def greedy_latencies(bench: MIOBench, servers: Servers, task_ids):
    """The paper's Greedy comparator (Eq. 21): offload each task to the
    server with the shortest queue; returns per-task total latency."""
    q = np.zeros(servers.n)
    out = np.zeros(len(task_ids))
    for i, t in enumerate(task_ids):
        s = int(np.argmin(q))
        lat = bench.latency_s[int(t), servers.cls[s]]
        out[i] = lat + q[s]
        q[s] += lat
    return out


def run_policy(policy, bench: MIOBench, servers: Servers, task_ids,
               rng: np.random.Generator, failed=None, backend=None) -> dict:
    """Roll a full episode with ``policy(episode) -> server``; aggregate the
    paper's metrics.  With an asynchronous ``backend`` (EngineBackend) the
    records are aggregated only after ``finalize()`` fills in the measured
    latencies, and the mean TTFT over finished requests is reported too."""
    ep = Episode(bench, servers, task_ids, rng, failed=failed,
                 backend=backend)
    recs = []
    while not ep.done:
        recs.append(ep.step(policy(ep)))
    ep.finalize()
    lat = [r["latency_total"] for r in recs]
    succ = [r["success"] for r in recs]
    out = {"avg_latency_s": float(np.mean(lat)),
           "completion_rate": float(np.mean(succ)),
           "p95_latency_s": float(np.percentile(lat, 95))}
    ttft = [r["ttft_s"] for r in recs if "ttft_s" in r]
    if ttft:
        out["avg_ttft_s"] = float(np.mean(ttft))
    return out
