"""MIOBench: MLLM Inference Offloading Benchmark (paper Sec. V-A).

3,377 tasks x 3 server classes = 10,131 offloading records with the fields of
Table II.  Records are synthesized from the quarantined cost model
(repro/sim/cost_model.py) — see the "Design notes" section of the top-level
README.md for the fidelity discussion.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.data.taskgen import CATEGORIES, TaskSet, make_taskset
from repro.sim import cost_model as cm

SERVER_CLASSES = [  # (device, model) — paper Table I
    ("jetson_orin_nano", "qwen3vl-2b"),
    ("rtx3090ti", "qwen3vl-8b"),
    ("rtx5090", "qwen3vl-30b"),
]


@dataclasses.dataclass
class MIOBench:
    tasks: TaskSet
    # [n_tasks, n_classes]
    latency_s: np.ndarray
    score: np.ndarray  # 1 success, 0 incorrect, -1 timeout
    model_id: np.ndarray  # [n_classes] index into cm.MODEL_IDS
    device_id: np.ndarray

    @property
    def n_records(self) -> int:
        return self.tasks.n * len(SERVER_CLASSES)

    def records(self):
        """Iterate Table-II-style dicts."""
        for t in range(self.tasks.n):
            for c, (dev, mdl) in enumerate(SERVER_CLASSES):
                yield {
                    "dataset": "MMBench-synthetic",
                    "prompt": f"task-{t}",
                    "device_type": dev,
                    "model_name": mdl,
                    "score": int(self.score[t, c]),
                    "latency_ms": float(self.latency_s[t, c] * 1e3),
                    "sample_id": t,
                    "index": t * len(SERVER_CLASSES) + c,
                    "source": CATEGORIES[int(self.tasks.category[t])],
                }


def generate(seed: int = 0, n_tasks: int | None = None,
             prefill_chunk: int | None = None) -> MIOBench:
    """``prefill_chunk`` (None = legacy smooth latency model) synthesizes
    latencies with the serving engine's bucketed/chunked prefill term, so
    predictors trained on the bench match the real engine's step-function
    prefill cost (see cost_model.chunked_prefill_tokens)."""
    tasks = make_taskset(n_tasks or 3377, seed)
    rng = np.random.default_rng(seed + 1)
    aff = cm.category_affinity(len(CATEGORIES), len(SERVER_CLASSES))
    n = tasks.n
    lat = np.zeros((n, len(SERVER_CLASSES)))
    score = np.zeros((n, len(SERVER_CLASSES)), np.int64)
    model_id = np.array([cm.MODEL_IDS.index(m) for _, m in SERVER_CLASSES])
    device_id = np.array([cm.DEVICE_IDS.index(d) for d, _ in SERVER_CLASSES])
    for c, (dev, mdl) in enumerate(SERVER_CLASSES):
        device, model = cm.DEVICES[dev], cm.MODELS[mdl]
        lat[:, c] = cm.latency_s(device, model, tasks.text_len,
                                 tasks.difficulty, rng,
                                 prefill_chunk=prefill_chunk)
        p = cm.success_prob(model, tasks.difficulty,
                            aff[tasks.category, c])
        ok = rng.random(n) < p
        timeout = lat[:, c] > cm.TIMEOUT_S
        score[:, c] = np.where(timeout, -1, ok.astype(np.int64))
    return MIOBench(tasks, lat, score, model_id, device_id)


def save_jsonl(bench: MIOBench, path: str):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        for rec in bench.records():
            f.write(json.dumps(rec) + "\n")


def summary(bench: MIOBench) -> dict:
    out = {"n_tasks": bench.tasks.n, "n_records": bench.n_records}
    for c, (dev, mdl) in enumerate(SERVER_CLASSES):
        s = bench.score[:, c]
        out[f"{dev}"] = {
            "model": mdl,
            "accuracy": float((s == 1).mean()),
            "timeout_rate": float((s == -1).mean()),
            "latency_p50_s": float(np.median(bench.latency_s[:, c])),
            "latency_p95_s": float(np.percentile(bench.latency_s[:, c], 95)),
        }
    return out
