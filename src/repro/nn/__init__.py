from repro.nn.spec import (
    TensorSpec,
    abstract_params,
    init_params,
    param_bytes,
    param_count,
    pspec_tree,
    tree_map_specs,
)
from repro.nn import layers  # noqa: F401
