"""Parameter-spec substrate.

Models are declared as nested dicts of :class:`TensorSpec`.  From one spec
tree we derive, without ever materializing full-size weights:

* ``init_params``     — seeded concrete arrays (smoke tests / real training)
* ``abstract_params`` — ``jax.ShapeDtypeStruct`` stand-ins (multi-pod dry-run)
* ``pspec_tree``      — ``PartitionSpec`` per leaf via logical-axis rules

Logical axis names used across the repo:
  embed, mlp, heads, kv_heads, qk, head_dim, vocab, layers, experts,
  state, conv, seq, batch, None
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Tree = Any


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Declarative description of one parameter tensor."""

    shape: tuple
    axes: tuple  # logical axis name (or None) per dim; len == len(shape)
    init: str = "normal"  # normal | zeros | ones | embed | scaled
    scale: float | None = None  # stddev override (normal/scaled)
    dtype: Any = None  # None -> use the policy's param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n


def _is_spec(x) -> bool:
    return isinstance(x, TensorSpec)


def tree_map_specs(fn: Callable[[str, TensorSpec], Any], tree: Tree, path: str = "") -> Tree:
    """Map ``fn(path, spec)`` over every TensorSpec leaf, preserving structure."""
    if _is_spec(tree):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: tree_map_specs(fn, v, f"{path}/{k}") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [tree_map_specs(fn, v, f"{path}/{i}") for i, v in enumerate(tree)]
        return type(tree)(out)
    raise TypeError(f"unexpected node in spec tree at {path!r}: {type(tree)}")


def _path_key(key: jax.Array, path: str) -> jax.Array:
    digest = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, digest)


def _materialize(spec: TensorSpec, key: jax.Array, dtype) -> jax.Array:
    dtype = spec.dtype or dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init in ("normal", "embed", "scaled"):
        if spec.scale is not None:
            std = spec.scale
        elif spec.init == "embed":
            std = 1.0
        else:  # fan-in scaling on the first axis by convention
            fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
            std = fan_in ** -0.5
        x = jax.random.normal(key, spec.shape, jnp.float32) * std
        return x.astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(spec_tree: Tree, key: jax.Array, param_dtype=jnp.float32) -> Tree:
    """Materialize real arrays; each leaf seeded deterministically by its path."""
    return tree_map_specs(
        lambda path, s: _materialize(s, _path_key(key, path), param_dtype), spec_tree
    )


def abstract_params(spec_tree: Tree, param_dtype=jnp.float32) -> Tree:
    """ShapeDtypeStruct stand-ins — zero allocation, for .lower()/dry-run."""
    return tree_map_specs(
        lambda _p, s: jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype), spec_tree
    )


def pspec_tree(spec_tree: Tree, rules: dict) -> Tree:
    """Map logical axes -> PartitionSpec using ``rules`` (logical -> mesh axis).

    rules values may be: a mesh-axis name, a tuple of mesh-axis names, or None.
    A mesh axis is used at most once per leaf (first logical dim wins).
    """

    def one(_path, spec: TensorSpec):
        used: set = set()
        out = []
        for name in spec.axes:
            mesh_axis = rules.get(name)
            flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
            if mesh_axis is None or any(a in used for a in flat):
                out.append(None)
            else:
                used.update(flat)
                out.append(mesh_axis)
        return P(*out)

    return tree_map_specs(one, spec_tree)


def param_count(spec_tree: Tree) -> int:
    total = 0

    def add(_p, s):
        nonlocal total
        total += s.size
        return None

    tree_map_specs(add, spec_tree)
    return total


def param_bytes(spec_tree: Tree, dtype=jnp.bfloat16) -> int:
    return param_count(spec_tree) * jnp.dtype(dtype).itemsize
