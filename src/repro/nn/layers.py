"""Layer building blocks: spec constructors + pure apply functions.

Conventions: activations flow in ``compute_dtype`` (bf16 on TPU), params are
cast at use sites; norms accumulate in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.spec import TensorSpec

# ---------------------------------------------------------------- specs


def linear(d_in: int, d_out: int, axes=("embed", "mlp"), bias: bool = False,
           scale: float | None = None):
    p = {"w": TensorSpec((d_in, d_out), axes, "normal", scale)}
    if bias:
        p["b"] = TensorSpec((d_out,), (axes[1],), "zeros")
    return p


def stacked_linear(n: int, d_in: int, d_out: int, axes=("embed", "mlp"),
                   bias: bool = False, scale: float | None = None):
    """Leading ``layers`` dim for scan-over-layers stacks."""
    p = {"w": TensorSpec((n, d_in, d_out), ("layers",) + tuple(axes), "normal", scale)}
    if bias:
        p["b"] = TensorSpec((n, d_out), ("layers", axes[1]), "zeros")
    return p


def rmsnorm(dim: int, axes=("embed",)):
    return {"scale": TensorSpec((dim,), axes, "ones")}


def stacked_rmsnorm(n: int, dim: int, axes=("embed",)):
    return {"scale": TensorSpec((n, dim), ("layers",) + tuple(axes), "ones")}


def layernorm(dim: int, axes=("embed",)):
    return {
        "scale": TensorSpec((dim,), axes, "ones"),
        "bias": TensorSpec((dim,), axes, "zeros"),
    }


def embedding(vocab: int, dim: int, axes=("vocab", "embed"), scale: float | None = None):
    return {"table": TensorSpec((vocab, dim), axes, "embed", scale)}


# ---------------------------------------------------------------- applies


def apply_linear(p, x, compute_dtype=None):
    dt = compute_dtype or x.dtype
    y = x.astype(dt) @ p["w"].astype(dt)
    if "b" in p:
        y = y + p["b"].astype(dt)
    return y


def apply_rmsnorm(p, x, eps: float = 1e-6, zero_centered: bool = False):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if zero_centered:  # gemma convention: weight stored as (scale - 1)
        scale = scale + 1.0
    return (xf * scale).astype(dt)


def apply_layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def apply_embedding(p, ids, compute_dtype=jnp.bfloat16):
    return p["table"].astype(compute_dtype)[ids]


def dropout(key, x, rate: float, deterministic: bool):
    if deterministic or rate == 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0).astype(x.dtype)


# ---------------------------------------------------------------- RoPE


def rope_frequencies(head_dim: int, max_len: int, theta: float = 10000.0,
                     dtype=jnp.float32):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)  # [T, head_dim//2]
    return jnp.cos(freqs).astype(dtype), jnp.sin(freqs).astype(dtype)


def apply_rope(x, cos, sin, positions):
    """x: [..., T, H, D]; positions: [..., T] int32."""
    c = cos[positions][..., None, :]  # [..., T, 1, D/2]
    s = sin[positions][..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)
