"""AdamW + schedules + clipping, pure-pytree (no optax on this box).

Mixed precision: if params are stored in a low-precision dtype, the optimizer
keeps an fp32 master copy in its state (ZeRO-1 shards it over the data axis
via the pspec helpers in ``repro.distributed.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 copy when params are low precision, else None


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - frac)
    else:
        decay = jnp.array(1.0)
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_init(params, keep_master: bool | None = None) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    m = jax.tree.map(f32, params)
    v = jax.tree.map(f32, params)
    low_precision = any(
        l.dtype != jnp.float32 for l in jax.tree_util.tree_leaves(params)
    )
    keep_master = low_precision if keep_master is None else keep_master
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) if keep_master else None
    return AdamWState(jnp.zeros((), jnp.int32), m, v, master)


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    ref = state.master if state.master is not None else params

    def upd(p32, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        new = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32)
        return new, m, v

    flat_ref, treedef = jax.tree_util.tree_flatten(ref)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p.astype(jnp.float32), g, m, v)
           for p, g, m, v in zip(flat_ref, flat_g, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])

    if state.master is not None:
        new_params = jax.tree.map(
            lambda n, p: n.astype(p.dtype), new_master, params
        )
        new_state = AdamWState(step, new_m, new_v, new_master)
    else:
        new_params = new_master
        new_state = AdamWState(step, new_m, new_v, None)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ------------------------------------------------------------------ SGD (for
# the tiny DRL nets the paper trains with Adam defaults; kept for ablations)


def sgd_update(params, grads, lr: float):
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
