"""Fault-tolerant checkpointing: atomic write (tmp + rename), keep-N, resume.

Format: zstd-compressed msgpack of ``{path: {dtype, shape, data-bytes}}`` plus
a small JSON metadata sidecar.  No orbax on this box; this is self-contained
and safe against preemption mid-write (the rename is the commit point).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
import zlib
from typing import Any

import jax
import msgpack
import numpy as np

try:  # optional dep: fall back to stdlib zlib when absent
    import zstandard
except ImportError:
    zstandard = None

_SEP = "/"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not "
                "installed; pip install zstandard to read it")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree, prefix="") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{_SEP}{k}"))
    elif isinstance(tree, (list, tuple)):
        out[f"{prefix}{_SEP}__type__"] = type(tree).__name__
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}"))
    elif tree is None:
        out[prefix] = None
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    # rebuild nested dicts first, then convert list-like nodes
    root: dict = {}
    for path, val in flat.items():
        parts = [p for p in path.split(_SEP) if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def convert(node):
        if not isinstance(node, dict):
            return node
        if "__type__" in node:
            typ = node.pop("__type__")
            items = [convert(node[str(i)]) for i in range(len(node))]
            return items if typ == "list" else tuple(items)
        return {k: convert(v) for k, v in node.items()}

    return convert(root)


def save_checkpoint(directory: str, step: int, tree: Any, *, keep: int = 3,
                    metadata: dict | None = None) -> str:
    """Atomically write checkpoint for ``step``; prune to the newest ``keep``."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    payload = {}
    for path, arr in flat.items():
        if arr is None or isinstance(arr, str):
            payload[path] = arr
        else:
            payload[path] = {
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "data": arr.tobytes(),
            }
    blob = _compress(msgpack.packb(payload, use_bin_type=True))
    final = os.path.join(directory, f"ckpt_{step:010d}")
    tmp = final + f".tmp.{os.getpid()}.{int(time.time() * 1e6)}"
    os.makedirs(tmp)
    with open(os.path.join(tmp, "tree.msgpack.zst"), "wb") as f:
        f.write(blob)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "time": time.time(), **(metadata or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit point
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    steps = sorted(list_checkpoints(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"ckpt_{s:010d}"), ignore_errors=True)
    # clean stale tmp dirs from preempted writers
    for name in os.listdir(directory):
        if ".tmp." in name:
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def list_checkpoints(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d{10})", name)
        if m and os.path.exists(os.path.join(directory, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


def load_checkpoint(directory: str, step: int | None = None):
    """Returns (step, tree) — host numpy arrays; caller device_puts/shards."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:010d}")
    with open(os.path.join(path, "tree.msgpack.zst"), "rb") as f:
        blob = f.read()
    payload = msgpack.unpackb(_decompress(blob), raw=False)
    flat = {}
    for p, rec in payload.items():
        if rec is None:
            flat[p] = None
        elif p.endswith("__type__"):
            flat[p] = rec
        else:
            flat[p] = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
    return step, _unflatten(flat)
