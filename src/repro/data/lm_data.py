"""Deterministic synthetic LM data pipeline.

Host-sharded: each process materializes only its shard of the global batch
(``host_id``/``host_count``), the pattern used on multi-host pods.  Streams
zipf-distributed token sequences with markov-ish structure so the loss has
signal to minimize; fully seeded.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    host_count: int = 1


class SyntheticLM:
    def __init__(self, cfg: LMDataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count
        rng = np.random.default_rng(cfg.seed)
        # a sparse "bigram table" gives the stream learnable structure
        self._next = rng.integers(0, cfg.vocab, size=cfg.vocab)
        self._noise_p = 0.15

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id, 0xD15EA5E))
        B, S = self.local_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.zipf(1.4, B) % cfg.vocab
        for t in range(S):
            follow = self._next[toks[:, t]]
            noise = rng.integers(0, cfg.vocab, B)
            use_noise = rng.random(B) < self._noise_p
            toks[:, t + 1] = np.where(use_noise, noise, follow)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
