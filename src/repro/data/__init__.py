from repro.data.taskgen import CATEGORIES, TaskSet, make_taskset  # noqa: F401
