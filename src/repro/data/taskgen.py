"""Synthetic MMBench-like multimodal task set.

MMBench itself (3,377 image+text choice questions, 20 task categories) is not
available offline; we generate a statistically matched stand-in: per-category
difficulty distributions, prompt-length distributions, and procedural images
whose statistics (edges, texture, entropy) vary with category and difficulty.
Each task additionally carries a media ``modality`` (image / audio / text-
only, category-biased) and a matching procedural media generator
(``image`` / ``audio``), so the multimodal serving benchmarks can replay
traces where real media segments travel through the request path.
Seeded and fully deterministic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

CATEGORIES = [
    "action_recognition", "attribute_comparison", "attribute_recognition",
    "celebrity_recognition", "function_reasoning", "future_prediction",
    "identity_reasoning", "image_emotion", "image_quality", "image_scene",
    "image_style", "image_topic", "nature_relation", "object_localization",
    "ocr", "physical_property", "physical_relation", "social_relation",
    "spatial_relationship", "structuralized_image_text",
]

N_TASKS = 3377  # match MMBench

# per-task media modality: what travels with the text prompt.  MMBench is
# image+text; the multimodal serving traces add an audio share so the
# split-point benchmarks exercise more than one payload class.
MODALITIES = ["text", "image", "audio"]


@dataclasses.dataclass
class TaskSet:
    n: int
    category: np.ndarray  # [n] int
    difficulty: np.ndarray  # [n] float in (0,1)
    text_len: np.ndarray  # [n] int (prompt tokens)
    image_entropy: np.ndarray  # [n] float
    seed: int
    modality: np.ndarray | None = None  # [n] int into MODALITIES

    def text_tokens(self, idx: int, max_len: int, vocab: int) -> np.ndarray:
        """Deterministic per-task DistilBERT-style token ids + mask."""
        rng = np.random.default_rng(self.seed * 1_000_003 + idx)
        L = min(int(self.text_len[idx]), max_len)
        # category-biased token distribution (zipf-ish)
        base = 1000 + int(self.category[idx]) * 700
        ids = base + rng.zipf(1.6, size=L) % (vocab - base - 1)
        out = np.zeros(max_len, np.int32)
        out[:L] = np.minimum(ids, vocab - 1)
        mask = np.zeros(max_len, np.int32)
        mask[:L] = 1
        return out, mask

    def image(self, idx: int, size: int) -> np.ndarray:
        """Procedural [size,size,3] image in [0,1]: gradient + blobs + noise,
        with edge density tied to category and noise to difficulty."""
        rng = np.random.default_rng(self.seed * 2_000_003 + idx)
        cat = int(self.category[idx])
        dif = float(self.difficulty[idx])
        yy, xx = np.mgrid[0:size, 0:size] / size
        img = np.stack([
            0.5 + 0.5 * np.sin(2 * np.pi * (xx * (1 + cat % 5))),
            0.5 + 0.5 * np.cos(2 * np.pi * (yy * (1 + cat % 3))),
            np.full_like(xx, 0.3 + 0.02 * cat),
        ], -1)
        for _ in range(2 + cat % 4):  # blobs = objects
            cx, cy, r = rng.random(), rng.random(), 0.08 + 0.2 * rng.random()
            m = ((xx - cx) ** 2 + (yy - cy) ** 2) < r * r
            img[m] = rng.random(3)
        img += rng.normal(0, 0.05 + 0.25 * dif, img.shape)  # difficulty noise
        return np.clip(img, 0, 1).astype(np.float32)

    def audio(self, idx: int, n_frames: int, n_mel: int = 16) -> np.ndarray:
        """Procedural [n_frames, n_mel] log-mel-like frames: a category-
        pitched harmonic ramp + difficulty-scaled noise (the audio analog
        of ``image``).  Seeded and fully deterministic."""
        rng = np.random.default_rng(self.seed * 3_000_017 + idx)
        cat = int(self.category[idx])
        dif = float(self.difficulty[idx])
        t = np.arange(n_frames)[:, None] / max(n_frames, 1)
        m = np.arange(n_mel)[None, :] / max(n_mel, 1)
        frames = (0.5 + 0.5 * np.sin(2 * np.pi * ((1 + cat % 5) * t
                                                  + (1 + cat % 3) * m))
                  ) * np.exp(-2.0 * m)
        frames += rng.normal(0, 0.05 + 0.25 * dif, frames.shape)
        return frames.astype(np.float32)

    def modality_name(self, idx: int) -> str:
        if self.modality is None:
            return "image"  # MMBench default: every task carries an image
        return MODALITIES[int(self.modality[idx])]

    def images(self, idxs, size: int) -> np.ndarray:
        return np.stack([self.image(int(i), size) for i in idxs])

    def texts(self, idxs, max_len: int, vocab: int):
        toks, masks = zip(*[self.text_tokens(int(i), max_len, vocab)
                            for i in idxs])
        return np.stack(toks), np.stack(masks)


def make_taskset(n: int = N_TASKS, seed: int = 0) -> TaskSet:
    rng = np.random.default_rng(seed)
    category = rng.integers(0, len(CATEGORIES), n)
    # per-category base difficulty + per-task Beta spread
    cat_base = rng.uniform(0.25, 0.75, len(CATEGORIES))
    difficulty = np.clip(
        cat_base[category] + 0.35 * (rng.beta(2, 2, n) - 0.5), 0.02, 0.98)
    text_len = np.clip(rng.lognormal(3.6, 0.5, n), 8, 256).astype(np.int64)
    image_entropy = 0.3 + 0.6 * difficulty + rng.normal(0, 0.05, n)
    # media modality, category-biased: harder (visual-heavy) categories
    # are mostly image-bound, the rest less so; both keep a 15% audio
    # share and the remainder is text-only
    p_img = np.where(cat_base[category] > 0.5, 0.8, 0.6)
    u = rng.random(n)
    modality = np.where(u < p_img, MODALITIES.index("image"),
                        np.where(u < p_img + 0.15,
                                 MODALITIES.index("audio"),
                                 MODALITIES.index("text")))
    return TaskSet(n, category, difficulty, text_len, image_entropy, seed,
                   modality=modality.astype(np.int64))


def splits(n: int, seed: int = 0, ratios=(0.8, 0.1, 0.1)):
    """train/val/test index split (paper: 8:1:1)."""
    rng = np.random.default_rng(seed + 99)
    order = rng.permutation(n)
    n_tr = int(ratios[0] * n)
    n_va = int(ratios[1] * n)
    return order[:n_tr], order[n_tr:n_tr + n_va], order[n_tr + n_va:]


# --------------------------------------------------------- arrival traces
# Request *timing* for the scale-out replay (benchmarks/fig13_scaleout.py):
# the taskset says what the requests are, these say when they arrive.


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0) -> np.ndarray:
    """``n`` arrival times (seconds, sorted, starting after 0) of a
    homogeneous Poisson process with mean ``rate_per_s`` requests/s —
    i.i.d. exponential inter-arrival gaps."""
    if rate_per_s <= 0:
        raise ValueError(f"rate_per_s must be positive, got {rate_per_s}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, n))


def diurnal_arrivals(n: int, rate_per_s: float, period_s: float,
                     seed: int = 0, depth: float = 0.8) -> np.ndarray:
    """``n`` arrivals of an inhomogeneous Poisson process whose rate
    swings sinusoidally around ``rate_per_s`` — the classic diurnal
    serving load, compressed to ``period_s`` so a replay sees whole
    peak/trough cycles.  ``depth`` in [0, 1) scales the swing:
    ``rate(t) = rate_per_s * (1 + depth * sin(2 pi t / period_s))``.
    Generated by thinning (Lewis & Shedler): candidates at the peak rate,
    kept with probability rate(t)/peak."""
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"depth must be in [0, 1), got {depth}")
    if rate_per_s <= 0 or period_s <= 0:
        raise ValueError("rate_per_s and period_s must be positive")
    rng = np.random.default_rng(seed)
    peak = rate_per_s * (1.0 + depth)
    out = np.empty(n)
    t, i = 0.0, 0
    while i < n:
        t += rng.exponential(1.0 / peak)
        rate = rate_per_s * (1.0 + depth * np.sin(2.0 * np.pi * t / period_s))
        if rng.random() * peak < rate:
            out[i] = t
            i += 1
    return out


def session_ids(n: int, n_sessions: int, seed: int = 0,
                concentration: float = 1.2) -> np.ndarray:
    """Assign each of ``n`` requests to one of ``n_sessions``
    conversations (Zipf-ish popularity via a Dirichlet draw): requests in
    a session share a prompt prefix, which is what prefix-affinity
    routing and the engines' prefix caches exploit."""
    if n_sessions < 1:
        raise ValueError(f"n_sessions must be >= 1, got {n_sessions}")
    rng = np.random.default_rng(seed + 7)
    weights = rng.dirichlet(np.full(n_sessions, concentration))
    return rng.choice(n_sessions, size=n, p=weights).astype(np.int64)
