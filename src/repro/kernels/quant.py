"""Symmetric int8 quantization for the paged/dense KV caches.

K/V rows are quantized **per token position, per kv head** over the head
dim: ``scale = absmax(row) / 127`` (fp32), ``q = round(row / scale)`` in
``[-127, 127]``.  The scales ride alongside the page pool / cache as an
extra tensor whose layout mirrors the K/V layout minus the head dim
(``[..., Hkv, Dh] int8`` + ``[..., Hkv] float32``), so every piece of
bookkeeping that moves pages (copy-on-write, eviction, prefix-trie reuse,
block-table gathers) moves the scale rows with the same indices.

Row-wise symmetric absmax is the standard serving-time KV recipe (vLLM
fp8/int8 KV, saxml int8 caches): zero-point-free dequant is a single
multiply that fuses into the attention kernel's K/V load, and quantizing
at write time (one row per decode tick, one chunk per prefill call) never
needs to rescale data already resident in the pool — unlike a true
per-page scale, which would have to re-quantize the whole page whenever a
newly appended token raised its absmax.

Dequantization happens in-registers inside the Pallas decode kernels
(``paged_decode.paged_decode_quant_tpu`` / ``flash_decode.
flash_decode_quant_tpu``): pages stay int8 in HBM — the ~2x HBM-traffic
reduction is the point — and the fp32 flash-softmax accumulation is
unchanged, so quantization error is bounded by the int8 rounding of K and
V alone (<= absmax/254 per element).
"""
from __future__ import annotations

import jax.numpy as jnp

INT8_MAX = 127.0


def quantize_kv(x, axis: int = -1):
    """Symmetric per-row int8 quantization over ``axis`` (the head dim).

    Returns ``(q, scales)``: ``q`` has ``x``'s shape in int8, ``scales``
    drops ``axis`` and is float32.  All-zero rows get scale 1.0 so the
    round-trip stays exact (and the null page's garbage scales are
    harmless — masked rows are never read).
    """
    xf = jnp.asarray(x).astype(jnp.float32)
    bound = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.where(bound > 0.0, bound / INT8_MAX, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis)


def dequantize_kv(q, scales, axis: int = -1, dtype=jnp.float32):
    """Inverse of ``quantize_kv``: broadcast the scale row back over
    ``axis``.  fp32 by default — the XLA fallback attention paths then
    contract exactly what the fused kernels compute in-registers."""
    return (q.astype(jnp.float32)
            * jnp.expand_dims(scales.astype(jnp.float32), axis)).astype(dtype)
