"""Jit'd kernel entry points with automatic CPU-interpret fallback.

On TPU these run the Mosaic-compiled Pallas kernels; on this CPU container
they run the same kernel bodies under ``interpret=True`` (Python execution,
bit-compatible semantics) so every kernel is correctness-tested offline.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.flash_decode import flash_decode_quant_tpu
from repro.kernels.flash_decode import flash_decode_tpu
from repro.kernels.mamba2_scan import ssd_scan_tpu
from repro.kernels.moe_gmm import grouped_matmul_tpu
from repro.kernels.paged_decode import paged_decode_quant_tpu
from repro.kernels.paged_decode import paged_decode_tpu
from repro.kernels.paged_verify import paged_verify_quant_tpu
from repro.kernels.paged_verify import paged_verify_tpu
from repro.kernels.rmsnorm import rmsnorm_tpu


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return flash_attention_tpu(q, k, v, **kw)


def flash_decode(q, k_cache, v_cache, cache_positions, pos, **kw):
    kw.setdefault("interpret", _interpret())
    return flash_decode_tpu(q, k_cache, v_cache, cache_positions, pos, **kw)


def paged_decode(q, k_pages, v_pages, block_tables, pos, **kw):
    kw.setdefault("interpret", _interpret())
    return paged_decode_tpu(q, k_pages, v_pages, block_tables, pos, **kw)


def flash_decode_quant(q, k_cache, v_cache, k_scales, v_scales,
                       cache_positions, pos, **kw):
    kw.setdefault("interpret", _interpret())
    return flash_decode_quant_tpu(q, k_cache, v_cache, k_scales, v_scales,
                                  cache_positions, pos, **kw)


def paged_decode_quant(q, k_pages, v_pages, k_scales, v_scales,
                       block_tables, pos, **kw):
    kw.setdefault("interpret", _interpret())
    return paged_decode_quant_tpu(q, k_pages, v_pages, k_scales, v_scales,
                                  block_tables, pos, **kw)


def paged_verify(q, k_pages, v_pages, block_tables, pos, **kw):
    kw.setdefault("interpret", _interpret())
    return paged_verify_tpu(q, k_pages, v_pages, block_tables, pos, **kw)


def paged_verify_quant(q, k_pages, v_pages, k_scales, v_scales,
                       block_tables, pos, **kw):
    kw.setdefault("interpret", _interpret())
    return paged_verify_quant_tpu(q, k_pages, v_pages, k_scales, v_scales,
                                  block_tables, pos, **kw)


def ssd_scan(x, dt, a_neg, B, C, **kw):
    kw.setdefault("interpret", _interpret())
    return ssd_scan_tpu(x, dt, a_neg, B, C, **kw)


def grouped_matmul(x, w, **kw):
    kw.setdefault("interpret", _interpret())
    return grouped_matmul_tpu(x, w, **kw)


def rmsnorm(x, scale, **kw):
    kw.setdefault("interpret", _interpret())
    return rmsnorm_tpu(x, scale, **kw)
