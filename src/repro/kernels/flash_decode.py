"""Pallas TPU flash-decode: one query token vs. a long KV cache.

The GPU trick here is split-KV with a warp-shuffle reduction; the TPU-native
equivalent processes KV blocks sequentially per (batch, kv-head) grid cell
with running (m, l, acc) in VMEM scratch, and processes all G = H/Hkv query
heads of a kv head together so the s = q k^T contraction has an MXU-friendly
row count.  Sharded-KV stat combination across chips is done by the caller
(one psum over partial (m, l, o) — see repro/serving).

``flash_decode_quant_tpu`` is the fused-dequant variant for int8 caches
(repro/kernels/quant.py): K/V stay int8 in HBM and the per-row fp32
scales ride as extra VMEM operands sliced by the same KV-block index map,
so dequantization happens in-registers after the DMA.  Flash-softmax
state and accumulation are fp32 either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, cpos_ref, o_ref, m_scr, l_scr,
            acc_scr, *, scale, block_k, window, ks_ref=None, vs_ref=None):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)
    if ks_ref is not None:  # int8 cache: in-register dequant, fp32 onward
        k = k * ks_ref[0, 0][:, None]  # [bk] scales over the head dim
        v = v * vs_ref[0, 0][:, None]
    cpos = cpos_ref[0]  # [bk]
    pos = pos_ref[0]  # scalar current position
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (cpos >= 0) & (cpos <= pos)
    if window:
        valid &= (pos - cpos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(jk == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def flash_decode_tpu(q, k_cache, v_cache, cache_positions, pos, *,
                     window: int = 0, block_k: int = 512,
                     interpret: bool = False):
    """q [B,H,D]; caches [B,S,Hkv,D]; cache_positions [B,S]; pos [B]."""
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = D ** -0.5
    block_k = min(block_k, S)
    nk = -(-S // block_k)
    pk = nk * block_k - S
    if pk:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        cache_positions = jnp.pad(cache_positions, ((0, 0), (0, pk)),
                                  constant_values=-1)
    qg = q.reshape(B, Hkv, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)  # [B,Hkv,S',D]
    vt = v_cache.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k,
                          window=window),
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),  # pos
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, qg, kt, vt, cache_positions)
    return out.reshape(B, H, D)


def _quant_kernel(pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, cpos_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, scale, block_k, window):
    """Positional-ref adapter: same body, int8 K/V + scale operands."""
    _kernel(pos_ref, q_ref, k_ref, v_ref, cpos_ref, o_ref, m_scr, l_scr,
            acc_scr, scale=scale, block_k=block_k, window=window,
            ks_ref=ks_ref, vs_ref=vs_ref)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def flash_decode_quant_tpu(q, k_cache, v_cache, k_scales, v_scales,
                           cache_positions, pos, *, window: int = 0,
                           block_k: int = 512, interpret: bool = False):
    """Fused-dequant flash decode over an int8 contiguous cache.

    q [B,H,D]; caches [B,S,Hkv,D] **int8**; k_scales/v_scales [B,S,Hkv]
    float32 per-row symmetric scales; cache_positions [B,S]; pos [B].
    """
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = D ** -0.5
    block_k = min(block_k, S)
    nk = -(-S // block_k)
    pk = nk * block_k - S
    k_scales = k_scales.astype(jnp.float32)
    v_scales = v_scales.astype(jnp.float32)
    if pk:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_scales = jnp.pad(k_scales, ((0, 0), (0, pk), (0, 0)))
        v_scales = jnp.pad(v_scales, ((0, 0), (0, pk), (0, 0)))
        cache_positions = jnp.pad(cache_positions, ((0, 0), (0, pk)),
                                  constant_values=-1)
    qg = q.reshape(B, Hkv, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)  # [B,Hkv,S',D] int8
    vt = v_cache.transpose(0, 2, 1, 3)
    kst = k_scales.transpose(0, 2, 1)  # [B,Hkv,S']
    vst = v_scales.transpose(0, 2, 1)

    out = pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, block_k=block_k,
                          window=window),
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),  # pos
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k), lambda b, h, j: (b, h, j)),
            pl.BlockSpec((1, 1, block_k), lambda b, h, j: (b, h, j)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, qg, kt, vt, kst, vst, cache_positions)
    return out.reshape(B, H, D)
