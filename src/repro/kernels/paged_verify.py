"""Pallas TPU paged multi-token verify: T query tokens vs. a block-table KV.

Speculative decoding scores a slot's k drafted tokens in *one* pass: the
engine first scatters the drafts' K/V into the paged pool (the same
write-then-attend shape as ``Model.prefill_chunk_paged``), then this
kernel attends every draft position over prefix + drafts with a causal
per-row mask.  Row ``t`` of the query block sits at logical position
``pos[b] + t`` and may see cache entries up to and including itself —
so the accept/reject decision downstream (models/api.verify_step_paged)
sees exactly the attention a sequential decode of the same tokens would.

Layout mirrors ``paged_decode``: K/V pages ``[P, bs, Hkv, D]``, block
tables ``[B, NB]`` (-1 = unallocated) and positions ride in as scalar
prefetch so the BlockSpec index maps DMA exactly the page each grid cell
needs.  The only new ingredient is the query block: all T tokens ×
G = H/Hkv query heads of one kv head are flattened to ``T*G`` rows, and
the causal offset of a row is recovered in-kernel as ``row // G`` — the
flash-softmax state simply grows from [G, ...] to [T*G, ...] scratch.

``paged_verify_quant_tpu`` is the fused-dequant int8 variant; like
``paged_decode_quant_tpu`` the per-row fp32 scales ride in as extra
operands addressed by the same block-table index map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, scale, block_size, window, group_size,
            ks_ref=None, vs_ref=None):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [T*G, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bs, D]
    v = v_ref[0, 0].astype(jnp.float32)
    if ks_ref is not None:  # int8 page: in-register dequant, fp32 onward
        k = k * ks_ref[0, 0][:, None]  # [bs] scales over the head dim
        v = v * vs_ref[0, 0][:, None]
    pos = pos_ref[b]
    page = bt_ref[b, j]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # row r of the query block is draft token r // G at position
    # pos + r // G; page entry t is at logical position j*bs + t
    row_pos = pos + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], 1), 0) // group_size  # [T*G, 1]
    cpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = (page >= 0) & (cpos <= row_pos)  # [T*G, bs] causal per row
    if window:
        valid &= (row_pos - cpos) < window
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _quant_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, scale, block_size,
                  window, group_size):
    """Positional-ref adapter: same body, int8 K/V + scale operands."""
    _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, scale=scale, block_size=block_size, window=window,
            group_size=group_size, ks_ref=ks_ref, vs_ref=vs_ref)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_verify_tpu(q, k_pages, v_pages, block_tables, pos, *,
                     window: int = 0, interpret: bool = False):
    """q [B,T,H,D] draft-position queries; k_pages/v_pages [P,bs,Hkv,D];
    block_tables [B,NB] int32 (-1 = unallocated); pos [B] int32 — the
    logical position of each sequence's *first* query token (query t
    attends causally up to pos + t)."""
    B, T, H, D = q.shape
    P, bs, Hkv, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // Hkv
    scale = D ** -0.5
    # [B,T,Hkv,G,D] -> [B,Hkv,T*G,D]: all T tokens of a kv head together
    qg = q.reshape(B, T, Hkv, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hkv, T * G, D)
    kt = k_pages.transpose(2, 0, 1, 3)  # [Hkv, P, bs, D]
    vt = v_pages.transpose(2, 0, 1, 3)
    block_tables = block_tables.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def page_map(b, h, j, bt_ref, pos_ref):
        return (h, jnp.maximum(bt_ref[b, j], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, pos
        grid=(B, Hkv, NB),
        in_specs=[
            pl.BlockSpec((1, 1, T * G, D), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), page_map),
            pl.BlockSpec((1, 1, bs, D), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, T * G, D),
                               lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_size=bs,
                          window=window, group_size=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, T * G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, pos, qg, kt, vt)
    return out.reshape(B, Hkv, T, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, T, H, D)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_verify_quant_tpu(q, k_pages, v_pages, k_scales, v_scales,
                           block_tables, pos, *, window: int = 0,
                           interpret: bool = False):
    """Fused-dequant multi-token verify over an int8 page pool.

    q [B,T,H,D]; k_pages/v_pages [P,bs,Hkv,D] **int8**; k_scales/v_scales
    [P,bs,Hkv] float32 per-row symmetric scales (repro/kernels/quant.py);
    block_tables [B,NB] int32; pos [B] int32 first-query positions.
    """
    B, T, H, D = q.shape
    P, bs, Hkv, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, T, Hkv, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hkv, T * G, D)
    kt = k_pages.transpose(2, 0, 1, 3)  # [Hkv, P, bs, D] int8
    vt = v_pages.transpose(2, 0, 1, 3)
    kst = k_scales.astype(jnp.float32).transpose(2, 0, 1)  # [Hkv, P, bs]
    vst = v_scales.astype(jnp.float32).transpose(2, 0, 1)
    block_tables = block_tables.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def page_map(b, h, j, bt_ref, pos_ref):
        return (h, jnp.maximum(bt_ref[b, j], 0), 0, 0)

    def scale_map(b, h, j, bt_ref, pos_ref):
        return (h, jnp.maximum(bt_ref[b, j], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, pos
        grid=(B, Hkv, NB),
        in_specs=[
            pl.BlockSpec((1, 1, T * G, D), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), page_map),
            pl.BlockSpec((1, 1, bs, D), page_map),
            pl.BlockSpec((1, 1, bs), scale_map),
            pl.BlockSpec((1, 1, bs), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, T * G, D),
                               lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, 1), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, block_size=bs,
                          window=window, group_size=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, T * G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, pos, qg, kt, vt, kst, vst)
    return out.reshape(B, Hkv, T, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, T, H, D)
