"""Pallas TPU flash attention (causal / sliding-window / GQA).

Grid: (batch, heads, q_blocks, k_blocks) with the k dimension innermost and
"arbitrary" semantics — running (m, l, acc) live in VMEM scratch across k
steps and the output block is written on the last k step.  Block shapes are
128-aligned so the q @ k^T and p @ v contractions are MXU-shaped.

Fully-masked (q, k) block pairs are skipped with ``pl.when`` — the causal and
sliding-window structure is honored block-wise, like the pure-JAX lowering
path in repro/models/attention.py (which is also the numerical oracle, see
kernels/ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, seq_q: int,
            seq_k: int, causal: bool, window: int, q_offset: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = q_offset + iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = jk * block_k
    k_hi = k_lo + block_k - 1
    live_block = True
    if causal:
        live_block = k_lo <= q_hi
    if window:
        live_block = live_block & ((q_lo - k_hi) < window)

    @pl.when(live_block)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_tpu(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q [B,Sq,H,D]; k,v [B,Sk,Hkv,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    scale = D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    pq, pk = nq * block_q - Sq, nk * block_k - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qt = q.transpose(0, 2, 1, 3)  # [B,H,Sq',D]
    kt = k.transpose(0, 2, 1, 3)  # [B,Hkv,Sk',D]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=Sq, seq_k=Sk, causal=causal, window=window,
        q_offset=Sk - Sq if causal else 0)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :Sq]
