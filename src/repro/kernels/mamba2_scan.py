"""Pallas TPU Mamba2 SSD chunked scan.

The GPU reference is a fused Triton kernel with a sequential elementwise
recurrence; the TPU-native version processes chunks as MXU matmuls
(intra-chunk quadratic block + state outer products) with the carried state
[P, N] living in VMEM scratch across the sequential chunk grid dimension.

Grid: (batch, heads, chunks) — chunks "arbitrary" (sequential), state scratch
persists across them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # [Q, P] (already dt-discretized)
    a = a_ref[0, 0, 0].astype(jnp.float32)  # [Q] log-decay
    B = b_ref[0, 0].astype(jnp.float32)  # [Q, N]
    C = c_ref[0, 0].astype(jnp.float32)  # [Q, N]
    a_cum = jnp.cumsum(a)  # [Q]

    # intra-chunk: y_diag = (C B^T * L) x, L[t,s] = exp(acum_t - acum_s) tril
    seg = a_cum[:, None] - a_cum[None, :]
    tril = (jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
            >= jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1))
    L = jnp.where(tril, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    state = state_scr[...]  # [P, N]
    y += jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(a_cum)[:, None]
    # state update
    decay = jnp.exp(a_cum[-1] - a_cum)  # [Q]
    new_state = jax.lax.dot_general(x, B * decay[:, None],
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(a_cum[-1]) + new_state
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_tpu(x, dt, a_neg, B, C, *, chunk: int = 256,
                 interpret: bool = False):
    """Same contract as repro.models.mamba2.ssd_chunked (y only).

    x [b,S,h,p]; dt [b,S,h] (>0); a_neg [h]; B, C [b,S,n] -> y [b,S,h,p].
    """
    b, S, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    a = (dt * a_neg[None, None, :]).transpose(0, 2, 1)  # [b,h,S]
    xd = (x * dt[..., None]).transpose(0, 2, 1, 3)  # [b,h,S,p]
    a_c = a.reshape(b, h, nc, chunk)
    x_c = xd.reshape(b, h, nc, chunk, p)
    B_c = B.reshape(b, nc, chunk, n)
    C_c = C.reshape(b, nc, chunk, n)

    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_c, a_c, B_c, C_c)
    return y.reshape(b, h, S, p).transpose(0, 2, 1, 3)
