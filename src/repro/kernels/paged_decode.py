"""Pallas TPU paged flash-decode: one query token vs. a block-table KV cache.

Unlike ``flash_decode`` (contiguous [B, S] cache), K/V live in a shared page
pool ``[P, bs, Hkv, D]`` and each sequence addresses its pages through a
block table ``[B, NB]`` (-1 = unallocated).  The table and the per-sequence
positions ride in as *scalar prefetch* operands, so the BlockSpec index maps
can dereference ``table[b, j]`` and DMA exactly the page each grid cell
needs — the gathered [B, NB*bs] cache view of the XLA path never
materializes in HBM.

Grid is (B, Hkv, NB); like ``flash_decode`` the KV axis is sequential with
running (m, l, acc) flash-softmax state in VMEM scratch, and all G = H/Hkv
query heads of a kv head are processed together.  Unallocated blocks clamp
to page 0 (the engine's reserved null page) and are masked out, so their
DMA is wasted bandwidth but never wrong.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, scale, block_size, window):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bs, D]
    v = v_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[b]
    page = bt_ref[b, j]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical position of page entry t is j*bs + t (2D iota: TPU-safe)
    cpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = (page >= 0) & (cpos <= pos)
    if window:
        valid &= (pos - cpos) < window
    s = jnp.where(valid, s, NEG_INF)  # [G, bs] via [1, bs] broadcast
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_tpu(q, k_pages, v_pages, block_tables, pos, *,
                     window: int = 0, interpret: bool = False):
    """q [B,H,D]; k_pages/v_pages [P,bs,Hkv,D]; block_tables [B,NB] int32
    (-1 = unallocated); pos [B] int32 current positions."""
    B, H, D = q.shape
    P, bs, Hkv, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    kt = k_pages.transpose(2, 0, 1, 3)  # [Hkv, P, bs, D]
    vt = v_pages.transpose(2, 0, 1, 3)
    block_tables = block_tables.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def page_map(b, h, j, bt_ref, pos_ref):
        return (h, jnp.maximum(bt_ref[b, j], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, pos
        grid=(B, Hkv, NB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), page_map),
            pl.BlockSpec((1, 1, bs, D), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_size=bs, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, pos, qg, kt, vt)
    return out.reshape(B, H, D)
