"""Pallas TPU paged flash-decode: one query token vs. a block-table KV cache.

Unlike ``flash_decode`` (contiguous [B, S] cache), K/V live in a shared page
pool ``[P, bs, Hkv, D]`` and each sequence addresses its pages through a
block table ``[B, NB]`` (-1 = unallocated).  The table and the per-sequence
positions ride in as *scalar prefetch* operands, so the BlockSpec index maps
can dereference ``table[b, j]`` and DMA exactly the page each grid cell
needs — the gathered [B, NB*bs] cache view of the XLA path never
materializes in HBM.

Grid is (B, Hkv, NB); like ``flash_decode`` the KV axis is sequential with
running (m, l, acc) flash-softmax state in VMEM scratch, and all G = H/Hkv
query heads of a kv head are processed together.  Unallocated blocks clamp
to page 0 (the engine's reserved null page) and are masked out, so their
DMA is wasted bandwidth but never wrong.

``paged_decode_quant_tpu`` is the fused-dequant variant for the int8 page
pool (``repro/kernels/quant.py``): K/V pages stay int8 in HBM — halving
the per-tick KV stream, which is what bounds decode — and the per-row
fp32 scales ride in as extra VMEM operands addressed by the *same*
block-table index map, so each grid cell dequantizes its page
in-registers right after the DMA.  The flash-softmax state and
accumulation are fp32 either way; only the K/V load path changes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, scale, block_size, window, ks_ref=None, vs_ref=None):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bs, D]
    v = v_ref[0, 0].astype(jnp.float32)
    if ks_ref is not None:  # int8 page: in-register dequant, fp32 onward
        k = k * ks_ref[0, 0][:, None]  # [bs] scales over the head dim
        v = v * vs_ref[0, 0][:, None]
    pos = pos_ref[b]
    page = bt_ref[b, j]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical position of page entry t is j*bs + t (2D iota: TPU-safe)
    cpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = (page >= 0) & (cpos <= pos)
    if window:
        valid &= (pos - cpos) < window
    s = jnp.where(valid, s, NEG_INF)  # [G, bs] via [1, bs] broadcast
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _quant_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, scale, block_size,
                  window):
    """Positional-ref adapter: same body, int8 K/V + scale operands."""
    _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, scale=scale, block_size=block_size, window=window,
            ks_ref=ks_ref, vs_ref=vs_ref)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_tpu(q, k_pages, v_pages, block_tables, pos, *,
                     window: int = 0, interpret: bool = False):
    """q [B,H,D]; k_pages/v_pages [P,bs,Hkv,D]; block_tables [B,NB] int32
    (-1 = unallocated); pos [B] int32 current positions."""
    B, H, D = q.shape
    P, bs, Hkv, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    kt = k_pages.transpose(2, 0, 1, 3)  # [Hkv, P, bs, D]
    vt = v_pages.transpose(2, 0, 1, 3)
    block_tables = block_tables.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def page_map(b, h, j, bt_ref, pos_ref):
        return (h, jnp.maximum(bt_ref[b, j], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, pos
        grid=(B, Hkv, NB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), page_map),
            pl.BlockSpec((1, 1, bs, D), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_size=bs, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, pos, qg, kt, vt)
    return out.reshape(B, H, D)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_quant_tpu(q, k_pages, v_pages, k_scales, v_scales,
                           block_tables, pos, *, window: int = 0,
                           interpret: bool = False):
    """Fused-dequant paged decode over an int8 page pool.

    q [B,H,D]; k_pages/v_pages [P,bs,Hkv,D] **int8**; k_scales/v_scales
    [P,bs,Hkv] float32 per-row symmetric scales (repro/kernels/quant.py);
    block_tables [B,NB] int32 (-1 = unallocated); pos [B] int32.  Pages
    and scales are addressed by the same block-table index map, so each
    grid cell DMAs its int8 page + its [bs] scale rows and dequantizes
    in-registers; nothing bf16-sized ever leaves HBM.
    """
    B, H, D = q.shape
    P, bs, Hkv, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    kt = k_pages.transpose(2, 0, 1, 3)  # [Hkv, P, bs, D] int8
    vt = v_pages.transpose(2, 0, 1, 3)
    kst = k_scales.astype(jnp.float32).transpose(2, 0, 1)  # [Hkv, P, bs]
    vst = v_scales.astype(jnp.float32).transpose(2, 0, 1)
    block_tables = block_tables.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def page_map(b, h, j, bt_ref, pos_ref):
        return (h, jnp.maximum(bt_ref[b, j], 0), 0, 0)

    def scale_map(b, h, j, bt_ref, pos_ref):
        return (h, jnp.maximum(bt_ref[b, j], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, pos
        grid=(B, Hkv, NB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), page_map),
            pl.BlockSpec((1, 1, bs, D), page_map),
            pl.BlockSpec((1, 1, bs), scale_map),
            pl.BlockSpec((1, 1, bs), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, block_size=bs,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, pos, qg, kt, vt, kst, vst)
    return out.reshape(B, H, D)
