"""Pallas TPU fused RMSNorm: one pass over rows, fp32 accumulation in-kernel
(no separate mean/rsqrt/mul HLO round-trips through HBM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float, zero_centered: bool):
    x = x_ref[...].astype(jnp.float32)  # [bt, d]
    var = jnp.mean(x * x, -1, keepdims=True)
    scale = s_ref[...].astype(jnp.float32)
    if zero_centered:
        scale = scale + 1.0
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "zero_centered",
                                             "block_t", "interpret"))
def rmsnorm_tpu(x, scale, *, eps: float = 1e-6, zero_centered: bool = False,
                block_t: int = 256, interpret: bool = False):
    """x [..., d]; scale [d]."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    bt = min(block_t, T)
    nt = -(-T // bt)
    if nt * bt - T:
        xf = jnp.pad(xf, ((0, nt * bt - T), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, zero_centered=zero_centered),
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * bt, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:T].reshape(shape)
