"""Version compatibility for ``jax.experimental.pallas.tpu`` renames.

jax >= 0.5 exposes ``pltpu.CompilerParams``; 0.4.x calls the same class
``TPUCompilerParams``.  Import ``CompilerParams`` from here so every kernel
works on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
