"""Pallas TPU grouped matmul for MoE expert compute.

[E, C, K] x [E, K, N] -> [E, C, N]: one expert per grid row, tiled over the
(C, N) output with a sequential K reduction in fp32 VMEM scratch.  Tiles are
128-aligned for the MXU.  This is the contraction produced by the sort-based
dispatch in repro/models/moe.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, acc_scr):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == pl.num_programs(3) - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_n", "block_k",
                                             "interpret"))
def grouped_matmul_tpu(x, w, *, block_c: int = 128, block_n: int = 128,
                       block_k: int = 512, interpret: bool = False):
    """x [E, C, K]; w [E, K, N] -> [E, C, N]."""
    E, C, K = x.shape
    _, _, N = w.shape
    bc, bn, bk = min(block_c, C), min(block_n, N), min(block_k, K)
    nc, nn, nk = -(-C // bc), -(-N // bn), -(-K // bk)
    if nc * bc - C:
        x = jnp.pad(x, ((0, 0), (0, nc * bc - C), (0, 0)))
    if nk * bk - K:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, nk * bk - K)))
        w = jnp.pad(w, ((0, 0), (0, nk * bk - K), (0, 0)))
    if nn * bn - N:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, nn * bn - N)))

    out = pl.pallas_call(
        _kernel,
        grid=(E, nc, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, nc * bc, nn * bn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:, :C, :N]
