"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention as _decode_ref
from repro.models.attention import decode_attention_quant as _decode_q_ref
from repro.models.attention import paged_decode_attention as _paged_ref
from repro.models.attention import (
    paged_decode_attention_quant as _paged_q_ref,
)
from repro.models.attention import paged_verify_attention as _verify_ref
from repro.models.attention import (
    paged_verify_attention_quant as _verify_q_ref,
)
from repro.models.attention import reference_attention


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    return reference_attention(q, k, v, causal=causal, window=window)


def flash_decode_ref(q, k_cache, v_cache, cache_positions, pos, *, window=0):
    return _decode_ref(q, k_cache, v_cache, cache_positions, pos,
                       window=window)


def paged_decode_ref(q, k_pages, v_pages, block_tables, pos, *, window=0):
    """Gather-through-block-table oracle (and the engine's CPU fallback)."""
    return _paged_ref(q, k_pages, v_pages, block_tables, pos, window=window)


def flash_decode_quant_ref(q, k_cache, v_cache, k_scales, v_scales,
                           cache_positions, pos, *, window=0):
    """Dequantize-then-attend oracle for the fused int8 flash decode."""
    return _decode_q_ref(q, k_cache, v_cache, k_scales, v_scales,
                         cache_positions, pos, window=window)


def paged_decode_quant_ref(q, k_pages, v_pages, k_scales, v_scales,
                           block_tables, pos, *, window=0):
    """Dequantize-then-gather oracle for the fused int8 paged decode (and
    the quantized engine's CPU fallback)."""
    return _paged_q_ref(q, k_pages, v_pages, k_scales, v_scales,
                        block_tables, pos, window=window)


def paged_verify_ref(q, k_pages, v_pages, block_tables, pos, *, window=0):
    """Gather-through-block-table multi-token verify oracle (and the
    speculative engine's CPU fallback)."""
    return _verify_ref(q, k_pages, v_pages, block_tables, pos, window=window)


def paged_verify_quant_ref(q, k_pages, v_pages, k_scales, v_scales,
                           block_tables, pos, *, window=0):
    """Dequantize-then-gather oracle for the fused int8 multi-token
    verify (and the quantized speculative engine's CPU fallback)."""
    return _verify_q_ref(q, k_pages, v_pages, k_scales, v_scales,
                         block_tables, pos, window=window)


def ssd_scan_ref(x, dt, a_neg, B, C):
    """Sequential per-token SSD recurrence (repro.models.mamba2 oracle)."""
    from repro.models.mamba2 import ssd_reference
    y, _ = ssd_reference(x, dt, a_neg, B, C)
    return y


def grouped_matmul_ref(x, w):
    return jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_ref(x, scale, *, eps=1e-6, zero_centered=False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    s = scale.astype(jnp.float32)
    if zero_centered:
        s = s + 1.0
    return (xf * jax.lax.rsqrt(var + eps) * s).astype(x.dtype)
