"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, recurrent scan).

The mLSTM chunkwise formulation mirrors the SSD trick: intra-chunk quadratic
attention-like matmuls (MXU-shaped) + an inter-chunk state recurrence, with
log-space max-stabilization carried through the scan (the TPU-idiomatic
replacement for the fused CUDA recurrence in the paper's reference code).
sLSTM is inherently sequential (recurrent connections through h_{t-1}) and is
implemented as a lax.scan over time — only 1 in 8 blocks is sLSTM.

Per-head dims: dk = dv = d_in / nh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.spec import TensorSpec

NEG_INF = -1e30


# --------------------------------------------------------------------- specs


def mlstm_spec(n_stack: tuple, d: int, d_in: int, nh: int, conv_width: int):
    """n_stack: leading stacking dims, e.g. (groups, per_group)."""
    L = n_stack
    ax = tuple(["layers"] + [None] * (len(L) - 1))
    dh = d_in // nh

    def t(shape, axes, init="normal", scale=None):
        return TensorSpec(L + shape, ax + axes, init, scale)

    return {
        "norm": t((d,), ("embed",), "ones"),
        "up_x": t((d, d_in), ("embed", "mlp"), scale=d ** -0.5),
        "up_z": t((d, d_in), ("embed", "mlp"), scale=d ** -0.5),
        "conv_w": t((conv_width, d_in), (None, "mlp"),
                    scale=conv_width ** -0.5),
        "conv_b": t((d_in,), ("mlp",), "zeros"),
        "wq": t((d_in, d_in), ("mlp", "heads"), scale=d_in ** -0.5),
        "wk": t((d_in, d_in), ("mlp", "heads"), scale=d_in ** -0.5),
        "wv": t((d_in, d_in), ("mlp", "heads"), scale=d_in ** -0.5),
        "w_i": t((d_in, nh), ("mlp", None), scale=d_in ** -0.5),
        "w_f": t((d_in, nh), ("mlp", None), scale=d_in ** -0.5),
        "b_i": t((nh,), (None,), "zeros"),
        "b_f": t((nh,), (None,), "ones"),  # bias toward remembering
        "out_norm": t((d_in,), ("mlp",), "ones"),
        "down": t((d_in, d), ("mlp", "embed"), scale=d_in ** -0.5),
    }


def slstm_spec(n_stack: tuple, d: int, nh: int):
    L = n_stack
    ax = tuple(["layers"] + [None] * (len(L) - 1))
    dh = d // nh

    def t(shape, axes, init="normal", scale=None):
        return TensorSpec(L + shape, ax + axes, init, scale)

    return {
        "norm": t((d,), ("embed",), "ones"),
        "w": t((d, 4 * d), ("embed", "mlp"), scale=d ** -0.5),  # z,i,f,o
        "r": t((nh, dh, 4 * dh), (None, "heads", "mlp"), scale=dh ** -0.5),
        "b": t((4 * d,), ("mlp",), "zeros"),
        "out_norm": t((d,), ("embed",), "ones"),
        "up_gate": t((d, int(d * 4 / 3)), ("embed", "mlp"), scale=d ** -0.5),
        "up": t((d, int(d * 4 / 3)), ("embed", "mlp"), scale=d ** -0.5),
        "down": t((int(d * 4 / 3), d), ("mlp", "embed"),
                  scale=(d * 4 / 3) ** -0.5),
    }


# --------------------------------------------------------------------- mLSTM


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def mlstm_chunkwise(q, k, v, ilog, flog, *, chunk: int, init=None):
    """Stabilized chunkwise mLSTM.

    q,k,v [b,S,h,dk]; ilog,flog [b,S,h] (log input gate / log forget gate).
    Returns (h [b,S,h,dv], (C [b,h,dk,dv], n [b,h,dk], m [b,h])).
    State is stored max-stabilized: C_tilde = C_true * exp(-m).
    """
    b, S, h, dk = q.shape
    dv = v.shape[-1]
    nc = S // chunk
    assert nc * chunk == S
    scale = dk ** -0.5

    def r(t, shape):
        return t.reshape((b, nc, chunk) + shape).swapaxes(0, 1)

    qc, kc, vc = r(q, (h, dk)), r(k, (h, dk)), r(v, (h, dv))
    ic = r(ilog, (h,)).transpose(0, 1, 3, 2)  # [nc,b,h,Q]
    fc = r(flog, (h,)).transpose(0, 1, 3, 2)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, inp):
        C, n, m = carry  # [b,h,dk,dv], [b,h,dk], [b,h]
        q_k, k_k, v_k, i_k, f_k = inp
        bcum = jnp.cumsum(f_k, -1)  # [b,h,Q]
        # log decay matrix D[t,j] = bcum[t] - bcum[j] + i[j], j<=t
        Dlog = jnp.where(tri[None, None],
                         bcum[..., :, None] - bcum[..., None, :] +
                         i_k[..., None, :], NEG_INF)  # [b,h,Q,Q]
        inter_log = bcum + m[..., None]  # [b,h,Q]
        m_t = jnp.maximum(Dlog.max(-1), inter_log)  # [b,h,Q] stabilizer
        W_mat = jnp.exp(Dlog - m_t[..., None])  # decay weights
        S_mat = jnp.einsum("bqhd,bkhd->bhqk", q_k, k_k,
                           preferred_element_type=jnp.float32) * scale * W_mat
        inter_w = jnp.exp(inter_log - m_t)  # [b,h,Q]
        num = jnp.einsum("bhqk,bkhd->bqhd", S_mat, v_k.astype(jnp.float32))
        num += jnp.einsum("bqhd,bhde,bhq->bqhe", q_k.astype(jnp.float32),
                          C, inter_w) * scale
        # stabilized normalizer vector (decayed sum of k's)
        n_t = jnp.einsum("bhqk,bkhd->bqhd", W_mat, k_k.astype(jnp.float32))
        n_t += n[:, None] * inter_w.transpose(0, 2, 1)[..., None]
        qn = jnp.einsum("bqhd,bqhd->bqh", q_k.astype(jnp.float32), n_t) * scale
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t.transpose(0, 2, 1)))
        h_out = num / denom[..., None]
        # ---- end-of-chunk state update
        b_Q = bcum[..., -1:]  # [b,h,1]
        w_in = jnp.exp(b_Q - bcum + i_k)  # [b,h,Q] decay of each pos to end
        m_new = jnp.maximum(b_Q[..., 0] + m, (b_Q - bcum + i_k).max(-1))
        carry_w = jnp.exp(b_Q[..., 0] + m - m_new)  # [b,h]
        in_w = jnp.exp(b_Q - bcum + i_k - m_new[..., None])  # [b,h,Q]
        C = C * carry_w[..., None, None] + jnp.einsum(
            "bqhd,bqhe,bhq->bhde", k_k.astype(jnp.float32),
            v_k.astype(jnp.float32), in_w)
        n = n * carry_w[..., None] + jnp.einsum(
            "bqhd,bhq->bhd", k_k.astype(jnp.float32), in_w)
        return (C, n, m_new), h_out

    if init is None:
        C0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        C0, n0, m0 = init
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h_out = hs.swapaxes(0, 1).reshape(b, S, h, dv)
    return h_out.astype(q.dtype), (C, n, m)


def mlstm_decode_step(state, q_t, k_t, v_t, ilog_t, flog_t):
    """One token. q/k/v [b,h,d]; gates [b,h]. state = (C, n, m) stabilized."""
    C, n, m = state
    dk = q_t.shape[-1]
    scale = dk ** -0.5
    m_new = jnp.maximum(flog_t + m, ilog_t)
    fw = jnp.exp(flog_t + m - m_new)
    iw = jnp.exp(ilog_t - m_new)
    C = C * fw[..., None, None] + iw[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k_t.astype(jnp.float32), v_t.astype(jnp.float32))
    n = n * fw[..., None] + iw[..., None] * k_t.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q_t.astype(jnp.float32), C) * scale
    qn = jnp.einsum("bhd,bhd->bh", q_t.astype(jnp.float32), n) * scale
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h = (num / denom[..., None]).astype(q_t.dtype)
    return h, (C, n, m_new)


def mlstm_reference(q, k, v, ilog, flog, init=None):
    """Sequential oracle (tests only)."""
    b, S, h, dk = q.shape
    dv = v.shape[-1]
    if init is None:
        state = (jnp.zeros((b, h, dk, dv), jnp.float32),
                 jnp.zeros((b, h, dk), jnp.float32),
                 jnp.zeros((b, h), jnp.float32))
    else:
        state = init
    outs = []
    for t in range(S):
        o, state = mlstm_decode_step(state, q[:, t], k[:, t], v[:, t],
                                     ilog[:, t], flog[:, t])
        outs.append(o)
    return jnp.stack(outs, 1), state


# --------------------------------------------------------------------- block
# applies (params WITHOUT leading stack dims)

from repro.models.mamba2 import causal_conv, causal_conv_step  # noqa: E402


def mlstm_block(p, x, *, nh: int, chunk: int = 256, init=None,
                gather_qkv: bool = False):
    """x [B,S,d] -> (y, state). Pre-LN residual block.

    ``gather_qkv``: constrain the conv output to be replicated before the
    three d_in->d_in projections — one all-gather replaces three TP psums
    (Megatron column-parallel trick; see EXPERIMENTS.md §Perf cell C).
    """
    B, S, d = x.shape
    d_in = p["up_x"].shape[-1]
    dh = d_in // nh
    xn = _rms(x, p["norm"])
    u = xn @ p["up_x"].astype(x.dtype)
    z = xn @ p["up_z"].astype(x.dtype)
    conv_init = None if init is None else init[0]
    if init is None:
        c = causal_conv(u, p["conv_w"].astype(x.dtype),
                        p["conv_b"].astype(x.dtype))
        conv_state = u[:, -(p["conv_w"].shape[0] - 1):]
    else:
        W = p["conv_w"].shape[0]
        padded = jnp.concatenate([conv_init.astype(x.dtype), u], 1)
        c = sum(padded[:, i:i + S] * p["conv_w"].astype(x.dtype)[i][None, None]
                for i in range(W)) + p["conv_b"].astype(x.dtype)[None, None]
        conv_state = padded[:, -(W - 1):]
    c = jax.nn.silu(c)
    if gather_qkv:
        from jax.sharding import PartitionSpec as P
        c = jax.lax.with_sharding_constraint(c, P())
        u = jax.lax.with_sharding_constraint(u, P())
    q = (c @ p["wq"].astype(x.dtype)).reshape(B, S, nh, dh)
    k = (c @ p["wk"].astype(x.dtype)).reshape(B, S, nh, dh)
    v = (u @ p["wv"].astype(x.dtype)).reshape(B, S, nh, dh)
    ilog = (c.astype(jnp.float32) @ p["w_i"].astype(jnp.float32)
            + p["b_i"].astype(jnp.float32))
    flog = jax.nn.log_sigmoid(
        c.astype(jnp.float32) @ p["w_f"].astype(jnp.float32)
        + p["b_f"].astype(jnp.float32))
    h, mstate = mlstm_chunkwise(q, k, v, ilog, flog, chunk=min(chunk, S),
                                init=None if init is None else init[1])
    h = h.reshape(B, S, d_in)
    h = _rms(h, p["out_norm"]) * jax.nn.silu(z)
    y = h @ p["down"].astype(x.dtype)
    return x + y, (conv_state, mstate)


def mlstm_block_decode(p, x_t, state, *, nh: int):
    """x_t [B,d]."""
    B, d = x_t.shape
    d_in = p["up_x"].shape[-1]
    dh = d_in // nh
    conv_state, mstate = state
    xn = _rms(x_t, p["norm"])
    u = xn @ p["up_x"].astype(x_t.dtype)
    z = xn @ p["up_z"].astype(x_t.dtype)
    c, conv_state = causal_conv_step(conv_state, u,
                                     p["conv_w"].astype(x_t.dtype),
                                     p["conv_b"].astype(x_t.dtype))
    c = jax.nn.silu(c)
    q = (c @ p["wq"].astype(x_t.dtype)).reshape(B, nh, dh)
    k = (c @ p["wk"].astype(x_t.dtype)).reshape(B, nh, dh)
    v = (u @ p["wv"].astype(x_t.dtype)).reshape(B, nh, dh)
    ilog = (c.astype(jnp.float32) @ p["w_i"].astype(jnp.float32)
            + p["b_i"].astype(jnp.float32))
    flog = jax.nn.log_sigmoid(
        c.astype(jnp.float32) @ p["w_f"].astype(jnp.float32)
        + p["b_f"].astype(jnp.float32))
    h, mstate = mlstm_decode_step(mstate, q, k, v, ilog, flog)
    h = h.reshape(B, d_in)
    h = _rms(h, p["out_norm"]) * jax.nn.silu(z)
    return x_t + h @ p["down"].astype(x_t.dtype), (conv_state, mstate)


# --------------------------------------------------------------------- sLSTM


def slstm_cell_step(state, gates, nh: int):
    """state = (c, n, m, h) each [B, d]; gates [B, 4d] pre-activation
    (already includes W x + R h_prev + b)."""
    c, n, m, h_prev = state
    B, d4 = gates.shape
    d = d4 // 4
    zr, ir, fr, orr = jnp.split(gates.astype(jnp.float32), 4, -1)
    z = jnp.tanh(zr)
    o = jax.nn.sigmoid(orr)
    flog = jax.nn.log_sigmoid(fr)
    m_new = jnp.maximum(flog + m, ir)
    fw = jnp.exp(flog + m - m_new)
    iw = jnp.exp(ir - m_new)
    c = fw * c + iw * z
    n = fw * n + iw
    h = o * (c / jnp.maximum(n, 1e-6))
    return (c, n, m_new, h)


def slstm_scan(p, x, *, nh: int, init=None):
    """Sequential sLSTM over time. x [B,S,d] -> (h [B,S,d], state)."""
    B, S, d = x.shape
    dh = d // nh
    wx = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)  # [B,S,4d]

    def step(state, wx_t):
        c, n, m, h = state
        # recurrent contribution: block-diagonal per head
        hh = h.reshape(B, nh, dh).astype(jnp.float32)
        rec = jnp.einsum("bhd,hde->bhe", hh,
                         p["r"].astype(jnp.float32))  # [B,nh,4dh]
        rec = rec.reshape(B, nh, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
        state = slstm_cell_step((c, n, m, h), wx_t.astype(jnp.float32) + rec,
                                nh)
        return state, state[3]

    if init is None:
        z = jnp.zeros((B, d), jnp.float32)
        init = (z, z, z, z)
    state, hs = jax.lax.scan(step, init, wx.swapaxes(0, 1))
    return hs.swapaxes(0, 1), state


def slstm_block(p, x, *, nh: int, init=None):
    xn = _rms(x, p["norm"])
    h, state = slstm_scan(p, xn, nh=nh, init=init)
    h = _rms(h.astype(x.dtype), p["out_norm"])
    y = x + h
    # gated FFN (pf 4/3)
    yn = _rms(y, p["norm"])
    g = jax.nn.silu(yn @ p["up_gate"].astype(x.dtype)) * (
        yn @ p["up"].astype(x.dtype))
    return y + g @ p["down"].astype(x.dtype), state


def slstm_block_decode(p, x_t, state, *, nh: int):
    B, d = x_t.shape
    dh = d // nh
    xn = _rms(x_t, p["norm"])
    wx = xn @ p["w"].astype(x_t.dtype) + p["b"].astype(x_t.dtype)
    c, n, m, h = state
    hh = h.reshape(B, nh, dh).astype(jnp.float32)
    rec = jnp.einsum("bhd,hde->bhe", hh, p["r"].astype(jnp.float32))
    rec = rec.reshape(B, nh, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    state = slstm_cell_step((c, n, m, h), wx.astype(jnp.float32) + rec, nh)
    hout = _rms(state[3].astype(x_t.dtype), p["out_norm"])
    y = x_t + hout
    yn = _rms(y, p["norm"])
    g = jax.nn.silu(yn @ p["up_gate"].astype(x_t.dtype)) * (
        yn @ p["up"].astype(x_t.dtype))
    return y + g @ p["down"].astype(x_t.dtype), state
