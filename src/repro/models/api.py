"""Public model API: spec/init, train_step, prefill, serve_step (decode).

Entry points lowered by the dry-run, one per shape kind:
  * train   — ``make_train_step``: fwd + chunked-xent + bwd + AdamW.
  * prefill — ``prefill``: build the KV/recurrent cache, return last logits.
  * decode  — ``serve_step``: one new token against a seq_len cache.

Cache layouts (stacked over layers so every step is a scan):
  attn:    k,v [L,B,Sa,Hkv,Dh] bf16; pos_map [B,Sa] int32 (-1 = empty)
  paged:   k_pages,v_pages [L,P,bs,Hkv,Dh] bf16 + per-slot block tables
           [B,NB] int32 (page id per bs-token logical block, -1 = empty);
           kv_dtype="int8" stores the pools int8 with fp32 row scales
           k_scales,v_scales [L,P,bs,Hkv] alongside (kernels/quant.py);
           see repro/serving/kv_cache.py for the pool/prefix-trie side
  zamba2:  conv [G,P,B,W-1,Ch], ssm [G,P,B,nh,hd,N] fp32, shared-attn KV [G,...]
  xlstm:   per-block (conv, C, n, m) for mLSTM; (c, n, m, h) for sLSTM
  whisper: self-KV [L,...] + static cross-KV [L,B,Se,Hkv,Dh]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm
from repro.models import mamba2 as m2
from repro.models import xlstm as xl
from repro.kernels.paged_decode import paged_decode_quant_tpu, paged_decode_tpu
from repro.kernels.paged_verify import paged_verify_quant_tpu, paged_verify_tpu
from repro.kernels.quant import dequantize_kv, quantize_kv
from repro.models.attention import (chunk_prefill_attention, decode_attention,
                                    flash_attention,
                                    paged_chunk_prefill_attention,
                                    paged_chunk_prefill_attention_quant,
                                    paged_decode_attention,
                                    paged_decode_attention_quant,
                                    paged_verify_attention,
                                    paged_verify_attention_quant)
from repro.nn.layers import apply_rope
from repro.nn.spec import abstract_params, init_params
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

Tree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    @functools.cached_property
    def spec(self):
        return lm.build_spec(self.cfg)

    # ------------------------------------------------------------- params
    def init(self, key, param_dtype=jnp.bfloat16):
        return init_params(self.spec, key, param_dtype)

    def abstract(self, param_dtype=jnp.bfloat16):
        return abstract_params(self.spec, param_dtype)

    # ------------------------------------------------------------- train
    def train_loss(self, params, batch, *, remat=True):
        return lm.train_loss(self.cfg, params, batch, remat=remat)

    def make_train_step(self, opt_cfg: AdamWConfig | None = None):
        cfg = self.cfg
        opt_cfg = opt_cfg or AdamWConfig()

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: lm.train_loss(cfg, p, batch))(params)
            params, opt_state, metrics = adamw_update(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, {"loss": loss, **metrics}

        return train_step

    def init_opt(self, params):
        return adamw_init(params)

    # ------------------------------------------------------------- inputs
    def input_specs(self, shape: ShapeConfig, *, mode: str | None = None):
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        mode = mode or shape.kind
        if mode == "train":
            out = {"tokens": _sds((B, S), jnp.int32),
                   "labels": _sds((B, S), jnp.int32)}
            if cfg.cross_attention:
                out["encoder_frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                             jnp.bfloat16)
            return out
        if mode == "prefill":
            out = {"tokens": _sds((B, S), jnp.int32)}
            if cfg.cross_attention:
                out["encoder_frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                             jnp.bfloat16)
            return out
        # decode: one token + cache
        return {"tokens": _sds((B,), jnp.int32), "pos": _sds((B,), jnp.int32)}

    # ------------------------------------------------------------- caches
    def abstract_cache(self, B: int, Sa: int):
        cfg = self.cfg
        Hkv, Dh, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
        if cfg.block_kind == "mamba_hybrid":
            G = L // cfg.shared_attn_every
            P = cfg.shared_attn_every
            Ch = cfg.d_inner + 2 * cfg.ssm_state
            nh = cfg.d_inner // cfg.ssm_headdim
            return {
                "conv": _sds((G, P, B, cfg.conv_width - 1, Ch), jnp.bfloat16),
                "ssm": _sds((G, P, B, nh, cfg.ssm_headdim, cfg.ssm_state),
                            jnp.float32),
                "k": _sds((G, B, Sa, Hkv, Dh), jnp.bfloat16),
                "v": _sds((G, B, Sa, Hkv, Dh), jnp.bfloat16),
                "pos_map": _sds((B, Sa), jnp.int32),
            }
        if cfg.block_kind == "xlstm":
            P = cfg.mlstm_per_slstm
            G = L // (P + 1)
            d_in = int(cfg.proj_factor * cfg.d_model)
            dh = d_in // cfg.n_heads
            d = cfg.d_model
            return {
                "mconv": _sds((G, P, B, cfg.conv_width - 1, d_in), jnp.bfloat16),
                "mC": _sds((G, P, B, cfg.n_heads, dh, dh), jnp.float32),
                "mn": _sds((G, P, B, cfg.n_heads, dh), jnp.float32),
                "mm": _sds((G, P, B, cfg.n_heads), jnp.float32),
                "sc": _sds((G, B, d), jnp.float32),
                "sn": _sds((G, B, d), jnp.float32),
                "sm": _sds((G, B, d), jnp.float32),
                "sh": _sds((G, B, d), jnp.float32),
            }
        out = {"k": _sds((L, B, Sa, Hkv, Dh), jnp.bfloat16),
               "v": _sds((L, B, Sa, Hkv, Dh), jnp.bfloat16),
               "pos_map": _sds((B, Sa), jnp.int32)}
        if cfg.cross_attention:
            out["xk"] = _sds((L, B, cfg.encoder_seq, Hkv, Dh), jnp.bfloat16)
            out["xv"] = _sds((L, B, cfg.encoder_seq, Hkv, Dh), jnp.bfloat16)
        return out

    @property
    def supports_paged(self) -> bool:
        """Paged KV serving covers the pure-attention family (full and
        local:global); recurrent/hybrid/cross-attention caches are dense."""
        return self.cfg.block_kind == "attn" and not self.cfg.cross_attention

    @property
    def supports_embed_spans(self) -> bool:
        """Embedding-span (multimodal) prefill needs the embed-at-the-
        boundary attention path: recurrent/hybrid state updates are fused
        with their token scans, and whisper carries media through its own
        encoder instead.  Same pure-attention-family predicate as paged
        serving (either cache backend works; the *family* is what gates)."""
        return self.supports_paged

    def abstract_paged_cache(self, num_pages: int, block_size: int,
                             kv_dtype: str = "bf16"):
        """Paged layout: K/V pages shared across the batch, addressed by a
        per-slot block table instead of a dense [B, max_seq] region.

        ``kv_dtype="int8"`` stores the pages quantized (symmetric per-row
        int8, repro/kernels/quant.py) with fp32 scale tensors riding
        alongside the pools — ``k_scales``/``v_scales`` [L, P, bs, Hkv]
        share the page axis, so page-id bookkeeping (copy-on-write,
        eviction, prefix reuse) moves scales and values together.  Halves
        KV bytes per token and roughly doubles the page budget a fixed
        HBM allowance buys (see ServingEngine ``kv_budget_bytes``)."""
        cfg = self.cfg
        if not self.supports_paged:
            raise ValueError(f"{cfg.name}: paged KV cache needs attn family")
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {kv_dtype!r}")
        shape = (cfg.n_layers, num_pages, block_size, cfg.n_kv_heads, cfg.hd)
        if kv_dtype == "int8":
            return {"k_pages": _sds(shape, jnp.int8),
                    "v_pages": _sds(shape, jnp.int8),
                    "k_scales": _sds(shape[:-1], jnp.float32),
                    "v_scales": _sds(shape[:-1], jnp.float32)}
        return {"k_pages": _sds(shape, jnp.bfloat16),
                "v_pages": _sds(shape, jnp.bfloat16)}

    @property
    def kv_geometry(self) -> "tuple[int, int, int]":
        """(n_layers, n_kv_heads, head_dim) — the paged page shape minus
        the page axes; the structural compatibility key a ``KVSnapshot``
        carries for cross-engine migration."""
        cfg = self.cfg
        return (cfg.n_layers, cfg.n_kv_heads, cfg.hd)

    def export_paged_kv(self, cache, pages) -> "dict":
        """Gather ``pages`` (a request's block table, in logical block
        order) out of the paged pool to host numpy — one leaf per cache
        leaf, page axis reordered to logical blocks: ``k_pages``/
        ``v_pages`` ``[L, NB, bs, Hkv, Dh]`` plus ``k_scales``/
        ``v_scales`` ``[L, NB, bs, Hkv]`` when the pool is int8.  The
        storage form is exported verbatim (int8 rows + scales untouched),
        so a same-precision import reads bit-identical cache values."""
        idx = jnp.asarray(np.asarray(pages, np.int32))
        return {name: np.asarray(leaf[:, idx])
                for name, leaf in cache.items()}

    def import_paged_kv(self, cache, pages, leaves, src_dtype: str, *,
                        from_block: int = 0):
        """Scatter exported logical blocks ``[from_block, from_block +
        len(pages))`` of ``leaves`` (``export_paged_kv`` layout) into this
        pool at page ids ``pages``, converting precision when the source
        form disagrees with the pool:

          * int8 -> int8 / bf16 -> bf16: verbatim rows (and scales), so
            decode reads exactly what the source engine would have read —
            the bit-identical-migration contract;
          * bf16 -> int8: the same write-then-quantize recipe as the
            engine's scatter path (quantize exact bf16 rows, scales ride
            at the same indices) — identical to having quantized at the
            source, so pricing the transfer at the destination's byte
            width loses nothing;
          * int8 -> bf16: rows dequantize through the same kernel-shared
            helper the fused decode paths use.
        """
        quant = "k_scales" in cache
        lo, hi = from_block, from_block + len(pages)
        idx = jnp.asarray(np.asarray(pages, np.int32))
        k, v = leaves["k_pages"], leaves["v_pages"]
        if src_dtype == "int8" and quant:
            upd = {name: np.asarray(leaves[name][:, lo:hi])
                   for name in ("k_pages", "v_pages", "k_scales",
                                "v_scales")}
        elif src_dtype == "int8":
            upd = {"k_pages": dequantize_kv(jnp.asarray(k[:, lo:hi]),
                                            jnp.asarray(
                                                leaves["k_scales"][:, lo:hi]),
                                            dtype=jnp.bfloat16),
                   "v_pages": dequantize_kv(jnp.asarray(v[:, lo:hi]),
                                            jnp.asarray(
                                                leaves["v_scales"][:, lo:hi]),
                                            dtype=jnp.bfloat16)}
        elif quant:
            k8, ks = quantize_kv(jnp.asarray(k[:, lo:hi]))
            v8, vs = quantize_kv(jnp.asarray(v[:, lo:hi]))
            upd = {"k_pages": k8, "v_pages": v8,
                   "k_scales": ks, "v_scales": vs}
        else:
            upd = {"k_pages": np.asarray(k[:, lo:hi]),
                   "v_pages": np.asarray(v[:, lo:hi])}
        out = dict(cache)
        for name, val in upd.items():
            leaf = cache[name]
            out[name] = leaf.at[:, idx].set(
                jnp.asarray(val).astype(leaf.dtype))
        return out

    # ------------------------------------------------------------- prefill
    @property
    def supports_bucketed_prefill(self) -> bool:
        """Shape-bucketed (padded) prefill needs a *positional* cache so the
        padding writes nothing a later decode step can see: attention K/V
        entries past the true length are masked via pos_map and overwritten
        in place as decoding reaches them.  Recurrent state (mamba, xlstm)
        integrates every input token, so padding would corrupt it."""
        return self.cfg.block_kind == "attn"

    @property
    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill appends chunk K/V into the serving cache and
        attends back through it — same family gate as paged serving."""
        return self.supports_paged

    def prefill(self, params, batch):
        """Returns (last-token logits [B,V], cache).

        ``batch["length"]`` [B] int32 optionally carries true prompt lengths
        when ``tokens`` is right-padded to a shape bucket (the serving
        engine's anti-recompile-storm path): pos_map marks padded positions
        empty (-1) and the logits are taken at ``length - 1`` instead of the
        last column.  Causal masking guarantees the padded tail never
        influences real positions.  Only attention-family caches support
        this (``supports_bucketed_prefill``).

        ``batch["embeds"]`` [B, S, d] + ``batch["embed_mask"]`` [B, S]
        optionally inject precomputed embedding spans (image patches /
        audio frames; repro/serving/segments.py) at masked positions —
        token→embedding lookup and span injection both happen once here at
        the entry point (``lm.embed_inputs``), everything below operates
        on embeddings.  Attention family only (``supports_embed_spans``).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        length = batch.get("length")
        embeds = batch.get("embeds")
        B, S = tokens.shape
        if length is not None and not self.supports_bucketed_prefill:
            raise ValueError(
                f"{cfg.name}: bucketed (padded) prefill needs a positional "
                "cache; recurrent state would integrate the padding")
        if embeds is not None and not self.supports_embed_spans:
            raise ValueError(
                f"{cfg.name}: embedding-span prefill needs the attention "
                "family (see Model.supports_embed_spans)")
        if length is None:
            pos_map = jnp.broadcast_to(jnp.arange(S), (B, S))
        else:
            pos_map = lm.prompt_pos_map(length, S)
        if cfg.cross_attention:
            enc = lm.whisper_encode(cfg, params, batch["encoder_frames"])
            h, kvs = lm.whisper_decode_forward(cfg, params, tokens, enc,
                                               return_cache=True)
            k, v, xk, xv = kvs
            cache = {"k": k, "v": v, "xk": xk, "xv": xv, "pos_map": pos_map}
        elif cfg.block_kind == "mamba_hybrid":
            h, caches = lm.zamba2_forward(cfg, params, tokens,
                                          return_cache=True)
            (conv, ssm), (k, v) = caches
            cache = {"conv": conv, "ssm": ssm, "k": k, "v": v,
                     "pos_map": pos_map}
        elif cfg.block_kind == "xlstm":
            h, caches = lm.xlstm_forward(cfg, params, tokens,
                                         return_cache=True)
            (mconv, (mC, mn, mm)), (sc, sn, sm, sh) = caches
            cache = {"mconv": mconv, "mC": mC, "mn": mn, "mm": mm,
                     "sc": sc, "sn": sn, "sm": sm, "sh": sh}
        else:
            h, (k, v) = lm.attn_forward(cfg, params, tokens,
                                        return_cache=True, embeds=embeds,
                                        embed_mask=batch.get("embed_mask"))
            cache = {"k": k, "v": v, "pos_map": pos_map}
        logits = lm.last_logits(cfg, params, lm.last_hidden(h, length))
        return logits, cache

    def prefill_with_prefix(self, params, batch, prefix_k, prefix_v):
        """Suffix prefill against cached prefix K/V (prefix-cache hit path).

        ``batch["tokens"]`` [B, Ssfx] are the tokens *after* the cached
        prefix; ``prefix_k``/``prefix_v`` [L, B, Spre, Hkv, Dh] hold the
        prefix K/V (already rope'd, as stored by prefill).  Returns
        (last-token logits [B, V], (k_sfx, v_sfx) [L, B, Ssfx, Hkv, Dh]) —
        the prefix blocks are reused, only the suffix is computed.

        ``batch["length"]`` [B] int32 optionally carries the true suffix
        length when the suffix is right-padded to a shape bucket; the
        caller then scatters only the first ``length`` K/V columns.
        ``batch["embeds"]``/``batch["embed_mask"]`` inject embedding spans
        of the suffix, as in ``prefill``.
        """
        cfg = self.cfg
        if not self.supports_paged:
            raise ValueError(f"{cfg.name}: prefix prefill needs attn family")
        h, (k, v) = lm.attn_forward(cfg, params, batch["tokens"],
                                    return_cache=True,
                                    prefix_kv=(prefix_k, prefix_v),
                                    embeds=batch.get("embeds"),
                                    embed_mask=batch.get("embed_mask"))
        logits = lm.last_logits(cfg, params,
                                lm.last_hidden(h, batch.get("length")))
        return logits, (k, v)

    # ------------------------------------------------------------- decode
    def serve_step(self, params, cache, batch):
        """One token for the whole batch. batch = {tokens [B], pos [B]}."""
        cfg = self.cfg
        tokens, pos = batch["tokens"], batch["pos"]
        x = lm.embed_tokens(cfg, params, tokens)  # [B, d]

        if cfg.block_kind == "mamba_hybrid":
            return self._zamba2_decode(params, cache, x, pos)
        if cfg.block_kind == "xlstm":
            return self._xlstm_decode(params, cache, x, pos)
        if cfg.cross_attention:
            return self._whisper_decode(params, cache, x, pos)
        return self._attn_decode(params, cache, x, pos)

    def _decode_layer(self, pl, x, kv, pos, rope, window, attend):
        """One attn-family decode layer; window is python-static.

        ``attend(q1, k1, v1, kv, window) -> (o, kv)`` owns the cache write
        and the attention contraction — dense (slot-indexed [B, Sa] cache)
        and paged (block-table page pool) serving share everything else.
        """
        cfg = self.cfg
        B = x.shape[0]
        cos, sin = rope
        xn = lm._norm(pl, x[:, None], cfg.norm, "ln1")
        q, k, v = lm._qkv(pl["attn"], cfg, xn, B, 1)
        q = apply_rope(q, cos, sin, pos[:, None])
        k = apply_rope(k, cos, sin, pos[:, None])
        o, kv = attend(q[:, 0], k[:, 0], v[:, 0], kv, window)
        o = lm._attn_out(pl["attn"], cfg, o.reshape(B, -1), x.dtype)
        if cfg.post_norms:
            o = lm._norm(pl, o, cfg.norm, "pn1")
        y = x + o
        yn = lm._norm(pl, y[:, None], cfg.norm, "ln2")
        if cfg.n_experts:
            f = lm.moe_lib.moe_apply(pl["moe"], yn[:, 0], top_k=cfg.top_k,
                                     norm_topk=cfg.norm_topk,
                                     capacity_factor=cfg.capacity_factor,
                                     act=lm._act(cfg.act),
                                     tp_axis=cfg.tp_axis,
                                     tp_shards=cfg.tp_shards)
        else:
            f = lm._mlp(pl["mlp"], cfg, yn)[:, 0]
        if cfg.post_norms:
            f = lm._norm(pl, f, cfg.norm, "pn2")
        return y + f, kv

    def _chunk_layer(self, pl, x, kv, qpos, rope, window, attend):
        """One attn-family chunked-prefill layer; mirrors ``_decode_layer``
        with a C-token chunk of queries instead of a single token.

        x [B, C, d]; qpos [B, C] absolute query positions; ``attend`` owns
        the cache write and the contraction, so the dense (slot-region) and
        paged (block-table) serving paths share everything else.
        """
        cfg = self.cfg
        B, C, _ = x.shape
        cos, sin = rope
        xn = lm._norm(pl, x, cfg.norm, "ln1")
        q, k, v = lm._qkv(pl["attn"], cfg, xn, B, C)
        q = apply_rope(q, cos, sin, qpos)
        k = apply_rope(k, cos, sin, qpos)
        o, kv = attend(q, k, v, kv, window)
        o = lm._attn_out(pl["attn"], cfg, o.reshape(B, C, -1), x.dtype)
        if cfg.post_norms:
            o = lm._norm(pl, o, cfg.norm, "pn1")
        return lm._ffn(pl, cfg, x + o), kv

    def _attn_decode_scan(self, params, x, pos, kv_all, rope_len,
                          attend, layer_fn=None):
        """Layer-scan driver shared by the dense and paged decode paths
        (``layer_fn=_decode_layer``, the default) and their chunked-prefill
        counterparts (``layer_fn=_chunk_layer``; x/pos then carry a C-token
        chunk dim).

        ``kv_all`` is a tuple of per-layer cache leaves stacked on dim 0:
        ``(k, v)`` ([L, B, Sa, ...] dense, [L, P, bs, ...] paged), plus
        ``(k_scales, v_scales)`` for the int8 page pool — the driver
        threads the tuple opaquely (``attend`` owns its meaning), so every
        cache precision shares one scan.  Returns ``(hidden, kv_new)``
        with the same stacking and arity.
        """
        cfg = self.cfg
        layer_fn = layer_fn or self._decode_layer
        rope_l, rope_g = lm._rope_tables(cfg, rope_len)
        kv_all = tuple(kv_all)

        if cfg.attn_pattern != "local_global":
            def body(x, xs):
                y, kv = layer_fn(xs[0], x, xs[1:], pos, rope_g, 0, attend)
                return y, tuple(kv)

            x, kv_new = jax.lax.scan(
                body, x, (params["layers"],) + kv_all)
            return x, tuple(kv_new)

        grouped, tail, G, P_, n_tail = lm._regroup_layers(
            cfg, params["layers"])
        n_full = G * P_
        kv_g = tuple(a[:n_full].reshape((G, P_) + a.shape[1:])
                     for a in kv_all)

        def gbody(x, xs):
            pg = xs[0]
            outs = []
            for idx in range(P_):
                pl = jax.tree.map(lambda a: a[idx], pg)
                is_g = idx == P_ - 1
                x, kv = layer_fn(
                    pl, x, tuple(c[idx] for c in xs[1:]), pos,
                    rope_g if is_g else rope_l,
                    0 if is_g else cfg.window, attend)
                outs.append(kv)
            return x, tuple(jnp.stack([o[i] for o in outs])
                            for i in range(len(kv_all)))

        x, kv_g_new = jax.lax.scan(gbody, x, (grouped,) + kv_g)
        tail_new = []
        for t in range(n_tail):
            pl = jax.tree.map(lambda a: a[t], tail)
            x, kv = layer_fn(
                pl, x, tuple(a[n_full + t] for a in kv_all),
                pos, rope_l, cfg.window, attend)
            tail_new.append(kv)
        kv_new = tuple(
            jnp.concatenate([g.reshape((n_full,) + g.shape[2:])]
                            + [kv[i][None] for kv in tail_new], 0)
            for i, g in enumerate(kv_g_new))
        return x, kv_new

    def _attn_decode(self, params, cache, x, pos):
        cfg = self.cfg
        B = x.shape[0]
        Sa = cache["k"].shape[2]
        pos_map = cache["pos_map"].at[jnp.arange(B), pos].set(pos)

        def attend(q1, k1, v1, kv, window):
            kc, vc = kv
            kc = kc.at[jnp.arange(B), pos].set(k1.astype(kc.dtype))
            vc = vc.at[jnp.arange(B), pos].set(v1.astype(vc.dtype))
            o = decode_attention(q1, kc, vc, pos_map, pos, window=window,
                                 repeat_kv=cfg.decode_repeat_kv)
            return o, (kc, vc)

        x, (k_new, v_new) = self._attn_decode_scan(
            params, x, pos, (cache["k"], cache["v"]), Sa, attend)
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x)
        return logits, {"k": k_new, "v": v_new, "pos_map": pos_map}

    def serve_step_paged(self, params, cache, batch):
        """One token for the whole batch against the paged KV cache.

        cache  = {k_pages, v_pages [L, P, bs, Hkv, Dh]} — bf16 pools — or
                 the int8 layout with ``k_scales``/``v_scales``
                 [L, P, bs, Hkv] fp32 alongside (``abstract_paged_cache``
                 with ``kv_dtype="int8"``); the cache's own leaves select
                 the path, so the engine just passes its pool through.
        batch  = {tokens [B], pos [B], block_tables [B, NB] int32}

        Block table entry ``[b, j]`` is the physical page holding positions
        ``[j*bs, (j+1)*bs)`` of slot b, -1 if unallocated.  The new K/V is
        scattered into page ``tables[b, pos//bs]`` (clamped to the null
        page 0 for inactive slots, whose rows are all -1); on the int8
        path the fresh rows are quantized first and their scales scattered
        at the same (page, offset), then attention runs the fused-dequant
        kernel — pages stay int8 in HBM.
        """
        cfg = self.cfg
        tokens, pos = batch["tokens"], batch["pos"]
        tables = batch["block_tables"]
        B = tokens.shape[0]
        bs = cache["k_pages"].shape[2]
        NB = tables.shape[1]
        quant = "k_scales" in cache
        x = lm.embed_tokens(cfg, params, tokens)  # [B, d]

        page = jnp.maximum(tables[jnp.arange(B), pos // bs], 0)
        off = pos % bs
        # Mosaic kernel on TPU (no gathered cache view in HBM); XLA gather
        # path elsewhere — interpret-mode Pallas inside the serving jit
        # would run the kernel body in Python per tick
        use_kernel = jax.default_backend() == "tpu"

        def attend(q1, k1, v1, kv, window):
            if quant:
                kp, vp, ksc, vsc = kv
                k8, k1s = quantize_kv(k1)  # [B, Hkv, D] -> int8 + [B, Hkv]
                v8, v1s = quantize_kv(v1)
                kp = kp.at[page, off].set(k8)
                vp = vp.at[page, off].set(v8)
                ksc = ksc.at[page, off].set(k1s)
                vsc = vsc.at[page, off].set(v1s)
                if use_kernel:
                    o = paged_decode_quant_tpu(q1, kp, vp, ksc, vsc, tables,
                                               pos, window=window)
                else:
                    o = paged_decode_attention_quant(q1, kp, vp, ksc, vsc,
                                                     tables, pos,
                                                     window=window)
                return o, (kp, vp, ksc, vsc)
            kp, vp = kv
            kp = kp.at[page, off].set(k1.astype(kp.dtype))
            vp = vp.at[page, off].set(v1.astype(vp.dtype))
            if use_kernel:
                o = paged_decode_tpu(q1, kp, vp, tables, pos, window=window)
            else:
                o = paged_decode_attention(q1, kp, vp, tables, pos,
                                           window=window)
            return o, (kp, vp)

        names = (("k_pages", "v_pages", "k_scales", "v_scales") if quant
                 else ("k_pages", "v_pages"))
        x, kv_new = self._attn_decode_scan(
            params, x, pos, tuple(cache[n] for n in names), NB * bs,
            attend)
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x)
        return logits, dict(zip(names, kv_new))

    def verify_step_paged(self, params, cache, batch):
        """Score T candidate tokens per slot in one pass (speculative
        verify) against the paged KV cache.

        cache  = the same bf16 or int8 paged pool ``serve_step_paged``
                 takes; batch = {tokens [B, T], pos [B], block_tables
                 [B, NB] int32}.  ``tokens[:, 0]`` is the last *accepted*
                 token (the one plain decode would feed next) and
                 ``tokens[:, 1:]`` the draft model's k = T-1 candidates;
                 ``pos[b]`` is the position ``tokens[b, 0]`` lands at.

        Write-then-attend, exactly like ``prefill_chunk_paged`` but
        batched over slots: every token's K/V is scattered into page
        ``tables[b, (pos+t)//bs]`` (rows whose block index runs past the
        table, e.g. inactive slots parked at ``pos = max_seq``, drop via
        out-of-bounds page ids), then the T queries attend causally over
        prefix + drafts through the multi-token verify kernel
        (``kernels/paged_verify.py``; XLA gather fallback off-TPU).
        Returns (logits [B, T, V], cache): ``argmax(logits[:, t])`` is
        the target model's next token *given* tokens[:, :t+1] — the
        greedy accept rule compares it to the next draft, so accepted
        prefixes are bit-identical to sequential ``serve_step_paged``
        calls.  Rejected positions keep their scattered K/V; they sit
        past the accepted position, are masked by every causal read, and
        are overwritten when decoding actually reaches them — rollback
        is positional, not physical (the engine's decode pages are
        private, ref == 1).
        """
        cfg = self.cfg
        tokens, pos = batch["tokens"], batch["pos"]
        tables = batch["block_tables"]
        B, T = tokens.shape
        P, bs = cache["k_pages"].shape[1:3]
        NB = tables.shape[1]
        quant = "k_scales" in cache
        x = lm.embed_tokens(cfg, params, tokens)  # [B, T, d]
        positions = (pos[:, None] + jnp.arange(T)[None, :]).astype(jnp.int32)
        blk = positions // bs
        page = tables[jnp.arange(B)[:, None], jnp.clip(blk, 0, NB - 1)]
        wpage = jnp.where((page >= 0) & (blk < NB), page, P)  # OOB -> dropped
        off = positions % bs
        use_kernel = jax.default_backend() == "tpu"

        def attend(q, k, v, kv, window):
            if quant:
                kp, vp, ksc, vsc = kv
                k8, k1s = quantize_kv(k)  # [B,T,Hkv,D] -> int8 + [B,T,Hkv]
                v8, v1s = quantize_kv(v)
                kp = kp.at[wpage, off].set(k8)
                vp = vp.at[wpage, off].set(v8)
                ksc = ksc.at[wpage, off].set(k1s)
                vsc = vsc.at[wpage, off].set(v1s)
                if use_kernel:
                    o = paged_verify_quant_tpu(q, kp, vp, ksc, vsc, tables,
                                               pos, window=window)
                else:
                    o = paged_verify_attention_quant(q, kp, vp, ksc, vsc,
                                                     tables, pos,
                                                     window=window)
                return o, (kp, vp, ksc, vsc)
            kp, vp = kv
            kp = kp.at[wpage, off].set(k.astype(kp.dtype))
            vp = vp.at[wpage, off].set(v.astype(vp.dtype))
            if use_kernel:
                o = paged_verify_tpu(q, kp, vp, tables, pos, window=window)
            else:
                o = paged_verify_attention(q, kp, vp, tables, pos,
                                           window=window)
            return o, (kp, vp)

        names = (("k_pages", "v_pages", "k_scales", "v_scales") if quant
                 else ("k_pages", "v_pages"))
        x, kv_new = self._attn_decode_scan(
            params, x, positions, tuple(cache[n] for n in names), NB * bs,
            attend, layer_fn=self._chunk_layer)
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x)  # [B, T, V]
        return logits, dict(zip(names, kv_new))

    # ------------------------------------------------------- chunked prefill
    def prefill_chunk_dense(self, params, cache, batch):
        """One bucketed prefill chunk into one dense-cache slot.

        cache  = the engine's batched dense cache {k, v [L, B, Sa, Hkv, Dh],
                 pos_map [B, Sa]}
        batch  = {tokens [1, C] (right-padded to the chunk bucket),
                  slot [] int32, pos [] int32 (tokens already in the slot),
                  length [] int32 (true chunk length)}

        The chunk's K/V is written at positions ``[pos, pos+length)`` of row
        ``slot`` (padded columns are dropped via out-of-bounds scatter
        indices, which XLA discards), then the chunk queries attend back
        through the whole slot region — write-then-attend, so in-chunk
        causality falls out of the pos_map mask.  Returns (logits [1, V] of
        the chunk's last real token, cache).  Compile variants are bounded
        by the number of chunk buckets: every other argument is
        shape-static.

        ``batch["embeds"]``/``batch["embed_mask"]`` [1, C, d] / [1, C]
        optionally inject this chunk's slice of a prompt's embedding spans
        (``lm.embed_inputs``) — a media span crossing a chunk boundary
        just lands in two consecutive chunks.
        """
        cfg = self.cfg
        tokens, slot = batch["tokens"], batch["slot"]
        pos0, n = batch["pos"], batch["length"]
        B, C = tokens.shape
        Sa = cache["k"].shape[2]
        x = lm.embed_inputs(cfg, params, tokens, batch.get("embeds"),
                            batch.get("embed_mask"))  # [1, C, d]
        positions = (pos0 + jnp.arange(C)).astype(jnp.int32)  # [C]
        wpos = jnp.where(jnp.arange(C) < n, positions, Sa)  # OOB -> dropped
        qpos = positions[None]  # [1, C]
        pos_map = cache["pos_map"].at[slot, wpos].set(positions)

        def attend(q, k, v, kv, window):
            kc, vc = kv
            kc = kc.at[slot, wpos].set(k[0].astype(kc.dtype))
            vc = vc.at[slot, wpos].set(v[0].astype(vc.dtype))
            o = chunk_prefill_attention(q, kc[slot][None], vc[slot][None],
                                        pos_map[slot][None], qpos,
                                        window=window)
            return o, (kc, vc)

        x, (k_new, v_new) = self._attn_decode_scan(
            params, x, qpos, (cache["k"], cache["v"]), Sa, attend,
            layer_fn=self._chunk_layer)
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x[jnp.arange(B), n - 1])
        return logits, {"k": k_new, "v": v_new, "pos_map": pos_map}

    def prefill_chunk_paged(self, params, cache, batch):
        """One bucketed prefill chunk into a paged-cache block table.

        cache  = {k_pages, v_pages [L, P, bs, Hkv, Dh]}
        batch  = {tokens [1, C] (right-padded to the chunk bucket),
                  block_tables [1, NB] int32 (the slot's table, covering at
                  least ``pos+length`` positions), pos [] int32, length []
                  int32}

        Scatters the chunk's K/V into its pages (padded columns dropped via
        out-of-bounds page ids) and attends back through the block table —
        the prefix-cache hit path needs no special casing: hit pages are
        simply already present in the table and ``pos`` starts past them.

        With the int8 pool (cache carries ``k_scales``/``v_scales``) this
        is the write-then-quantize path: the chunk's fresh K/V rows are
        quantized before the scatter and the chunk attends back through
        the *dequantized* pool — so a prefix-cache hit and a cold run of
        the same prompt see bit-identical cache values.
        """
        cfg = self.cfg
        tokens, tables = batch["tokens"], batch["block_tables"]
        pos0, n = batch["pos"], batch["length"]
        B, C = tokens.shape
        P, bs = cache["k_pages"].shape[1:3]
        NB = tables.shape[1]
        quant = "k_scales" in cache
        x = lm.embed_inputs(cfg, params, tokens, batch.get("embeds"),
                            batch.get("embed_mask"))  # [1, C, d]
        positions = (pos0 + jnp.arange(C)).astype(jnp.int32)  # [C]
        valid = jnp.arange(C) < n
        blk = jnp.clip(positions // bs, 0, NB - 1)
        page = jnp.maximum(tables[0, blk], 0)
        wpage = jnp.where(valid, page, P)  # OOB -> dropped
        off = positions % bs
        qpos = positions[None]  # [1, C]

        def attend(q, k, v, kv, window):
            if quant:
                kp, vp, ksc, vsc = kv
                k8, k1s = quantize_kv(k[0])  # [C, Hkv, D] -> int8 + [C, Hkv]
                v8, v1s = quantize_kv(v[0])
                kp = kp.at[wpage, off].set(k8)
                vp = vp.at[wpage, off].set(v8)
                ksc = ksc.at[wpage, off].set(k1s)
                vsc = vsc.at[wpage, off].set(v1s)
                o = paged_chunk_prefill_attention_quant(
                    q, kp, vp, ksc, vsc, tables, qpos, window=window)
                return o, (kp, vp, ksc, vsc)
            kp, vp = kv
            kp = kp.at[wpage, off].set(k[0].astype(kp.dtype))
            vp = vp.at[wpage, off].set(v[0].astype(vp.dtype))
            o = paged_chunk_prefill_attention(q, kp, vp, tables, qpos,
                                              window=window)
            return o, (kp, vp)

        names = (("k_pages", "v_pages", "k_scales", "v_scales") if quant
                 else ("k_pages", "v_pages"))
        x, kv_new = self._attn_decode_scan(
            params, x, qpos, tuple(cache[n_] for n_ in names), NB * bs,
            attend, layer_fn=self._chunk_layer)
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x[jnp.arange(B), n - 1])
        return logits, dict(zip(names, kv_new))

    def _zamba2_decode(self, params, cache, x, pos):
        cfg = self.cfg
        B = x.shape[0]
        x0 = x
        Sa = cache["k"].shape[2]
        ropes = lm._rope_tables(cfg, Sa)
        pos_map = cache["pos_map"].at[jnp.arange(B), pos].set(pos)

        def group(x, xs):
            pm, conv_g, ssm_g, kc, vc = xs

            def inner(carry, xs_i):
                xc = carry
                pl, cs, ss = xs_i
                y, cs, ss = m2.mamba2_decode(pl, xc, cs, ss,
                                             n_state=cfg.ssm_state,
                                             headdim=cfg.ssm_headdim)
                return xc + y, (cs, ss)

            x, (conv_g, ssm_g) = jax.lax.scan(inner, x, (pm, conv_g, ssm_g))
            y, (kc, vc) = lm._shared_attn_apply(
                cfg, params["shared_attn"], x, x0, ropes, None,
                kv_cache=(kc, vc, pos_map), pos_scalar=pos)
            return y, (conv_g, ssm_g, kc, vc)

        x, (conv, ssm, k, v) = jax.lax.scan(
            group, x, (params["mamba"], cache["conv"], cache["ssm"],
                       cache["k"], cache["v"]))
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x)
        return logits, {"conv": conv, "ssm": ssm, "k": k, "v": v,
                        "pos_map": pos_map}

    def _xlstm_decode(self, params, cache, x, pos):
        cfg = self.cfg

        def group(x, xs):
            pm, psl, mconv, mC, mn, mm, sc, sn, sm, sh = xs

            def inner(carry, xs_i):
                xc = carry
                pl, cs, C, n, m = xs_i
                y, (cs, (C, n, m)) = xl.mlstm_block_decode(
                    pl, xc, (cs, (C, n, m)), nh=cfg.n_heads)
                return y, (cs, C, n, m)

            x, (mconv, mC, mn, mm) = jax.lax.scan(
                inner, x, (pm, mconv, mC, mn, mm))
            x, (sc, sn, sm, sh) = xl.slstm_block_decode(
                psl, x, (sc, sn, sm, sh), nh=cfg.n_heads)
            return x, (mconv, mC, mn, mm, sc, sn, sm, sh)

        x, ys = jax.lax.scan(
            group, x, (params["mlstm"], params["slstm"], cache["mconv"],
                       cache["mC"], cache["mn"], cache["mm"], cache["sc"],
                       cache["sn"], cache["sm"], cache["sh"]))
        mconv, mC, mn, mm, sc, sn, sm, sh = ys
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x)
        return logits, {"mconv": mconv, "mC": mC, "mn": mn, "mm": mm,
                        "sc": sc, "sn": sn, "sm": sm, "sh": sh}

    def _whisper_decode(self, params, cache, x, pos):
        cfg = self.cfg
        B = x.shape[0]
        d = cfg.d_model
        Sa = cache["k"].shape[2]
        half = d // 2
        freqs = jnp.exp(-jnp.arange(half) / (half - 1) * jnp.log(10000.0))
        pe = jnp.concatenate([jnp.sin(pos[:, None] * freqs[None]),
                              jnp.cos(pos[:, None] * freqs[None])], -1)
        x = x + pe.astype(x.dtype)
        pos_map = cache["pos_map"].at[jnp.arange(B), pos].set(pos)

        def body(x, xs):
            pl, kc, vc, xk, xv = xs
            xn = lm._norm(pl, x[:, None], cfg.norm, "ln1")
            q, k, v = lm._qkv(pl["attn"], cfg, xn, B, 1)
            kc = kc.at[jnp.arange(B), pos].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[jnp.arange(B), pos].set(v[:, 0].astype(vc.dtype))
            o = decode_attention(q[:, 0], kc, vc, pos_map, pos,
                     repeat_kv=cfg.decode_repeat_kv)
            x = x + o.reshape(B, -1) @ pl["attn"]["wo"].astype(x.dtype)
            xn = lm._norm(pl, x[:, None], cfg.norm, "lnx")
            q2, _, _ = lm._qkv(pl["xattn"], cfg, xn, B, 1)
            xpos = jnp.broadcast_to(jnp.arange(xk.shape[1]), xk.shape[:2])
            o2 = decode_attention(q2[:, 0], xk, xv, xpos,
                                  jnp.full((B,), xk.shape[1], jnp.int32))
            x = x + o2.reshape(B, -1) @ pl["xattn"]["wo"].astype(x.dtype)
            xn = lm._norm(pl, x[:, None], cfg.norm, "ln2")
            return x + lm._mlp(pl["mlp"], cfg, xn)[:, 0], (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = lm._norm(params, x, cfg.norm, "final")
        logits = lm.last_logits(cfg, params, x)
        return logits, {"k": k_new, "v": v_new, "xk": cache["xk"],
                        "xv": cache["xv"], "pos_map": pos_map}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
