"""Unified model zoo: one stack covering all 10 assigned architectures.

Families:
  * ``attn``         — dense / MoE / VLM decoder-only transformers
                       (qwen2, codeqwen, llama3, gemma3, chameleon, qwen2-moe,
                       granite-moe), homogeneous scan-over-layers with traced
                       per-layer flags for gemma3's 5:1 local:global pattern.
  * ``mamba_hybrid`` — zamba2: 9 groups of 6 Mamba2 layers, one *shared*
                       (weight-reused) attention+MLP block applied at the end
                       of each group on concat(x, x0).
  * ``xlstm``        — 6 groups of (7 mLSTM + 1 sLSTM) blocks.
  * ``encdec``       — whisper: full-attention encoder over precomputed frame
                       embeddings (frontend stub) + causal decoder with
                       cross-attention.

Every family exposes: spec / forward (train logits path) / prefill (build KV
or recurrent state cache, return last-token logits) / decode_step (one token).
All sequence-quadratic work goes through the chunked flash path, so nothing
ever materializes an [S, S] tensor — this is what lets 32k/500k shapes lower
with bounded per-device memory in the dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import mamba2 as m2
from repro.models import xlstm as xl
from repro.models.attention import decode_attention, flash_attention
from repro.nn.layers import apply_rope, rope_frequencies
from repro.nn.spec import TensorSpec

Tree = Any


# ------------------------------------------------------------------ helpers


def embed_tokens(cfg: ArchConfig, params, tokens):
    """Token-table lookup in the activation dtype (+ gemma embed scale).

    The single place token ids become vectors — every prefill/decode entry
    point routes through here, so everything past it operates on
    embeddings and is modality-agnostic.
    """
    dt = jnp.dtype(cfg.act_dtype)
    x = params["embed"]["table"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    return x


def embed_inputs(cfg: ArchConfig, params, tokens, embeds=None,
                 embed_mask=None):
    """Entry-point embedding: token lookup + embedding-span injection.

    ``tokens`` [..., S] int; ``embeds`` [..., S, d] optionally carries
    precomputed embedding spans (image patches / audio frames — see
    repro/serving/segments.py) with ``embed_mask`` [..., S] True at
    injected positions.  Masked positions take the ``embeds`` row *as-is*
    (encoder outputs are already at model scale — no embed_scale);
    unmasked positions take the token lookup.  Token ids are clamped to 0
    first so the bookkeeping key ids of embedding positions (negative by
    construction) can ride the same array.
    """
    x = embed_tokens(cfg, params, jnp.maximum(tokens, 0))
    if embeds is not None:
        x = jnp.where(embed_mask[..., None], embeds.astype(x.dtype), x)
    return x


def _norm(p, x, kind: str, prefix: str):
    eps = 1e-6
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        return (y * p[prefix + "_s"].astype(jnp.float32)
                + p[prefix + "_b"].astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    scale = p[prefix + "_s"].astype(jnp.float32)
    if kind == "rmsnorm_zero":
        scale = scale + 1.0
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _norm_spec(L, dim, kind, prefix):
    stack = (L,) if L else ()
    ax = ("layers",) if L else ()
    init = "zeros" if kind == "rmsnorm_zero" else "ones"
    out = {prefix + "_s": TensorSpec(stack + (dim,), ax + ("embed",), init)}
    if kind == "layernorm":
        out[prefix + "_b"] = TensorSpec(stack + (dim,), ax + ("embed",), "zeros")
    return out


def _head_rms(x, scale):
    """Per-head qk-norm. x [..., Dh], scale [Dh]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def _act(name):
    if name == "silu_glu":
        return jax.nn.silu
    if name in ("gelu_glu", "gelu"):
        return functools.partial(jax.nn.gelu, approximate=True)
    raise ValueError(name)


def _col_gathered(x, w, cfg: ArchConfig, dt):
    """``x @ w`` where ``x``'s last dim and ``w``'s *output* columns are
    both TP-sharded (``w`` holds the full contraction dim but 1/tp of the
    output columns).

    Two all-gathers — pure data movement, no arithmetic — rebuild the
    replicated input and output around one exact local matmul: every
    output element is the full-contraction dot product computed on
    exactly one shard, so the result is **bitwise identical** to the
    unsharded matmul (XLA's dot gives bitwise column-sliceable results).
    Megatron-style row-parallel + psum would be cheaper on the wire but
    rounds split-K partial sums differently, breaking the engine's
    token-identical-under-sharding contract.
    """
    full = jax.lax.all_gather(x, cfg.tp_axis, axis=x.ndim - 1, tiled=True)
    y = full @ w.astype(dt)
    return jax.lax.all_gather(y, cfg.tp_axis, axis=y.ndim - 1, tiled=True)


def _attn_out(pl_attn, cfg: ArchConfig, o, dt):
    """Attention output projection ``o @ wo``.  TP-sharded heads hand in
    the local heads' outputs; wo holds all H*Dh rows but a 1/tp slice of
    the d_model output columns (see ``_col_gathered``)."""
    if cfg.tp_axis and "heads" in cfg.tp_shards:
        return _col_gathered(o, pl_attn["wo"], cfg, dt)
    return o @ pl_attn["wo"].astype(dt)


# ------------------------------------------------------------- spec builders


def attn_spec(cfg: ArchConfig, L: int, d: int, *, cross: bool = False,
              stack=None):
    """Attention weights (optionally stacked over L layers)."""
    stack = (L,) if L else ()
    ax = ("layers",) if L else ()
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sc = d ** -0.5
    p = {
        "wq": TensorSpec(stack + (d, H * Dh), ax + ("embed", "heads"), "normal", sc),
        "wk": TensorSpec(stack + (d, Hkv * Dh), ax + ("embed", "kv_heads"), "normal", sc),
        "wv": TensorSpec(stack + (d, Hkv * Dh), ax + ("embed", "kv_heads"), "normal", sc),
        "wo": TensorSpec(stack + (H * Dh, cfg.d_model), ax + ("heads", "embed"),
                         "normal", (H * Dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = TensorSpec(stack + (H * Dh,), ax + ("heads",), "zeros")
        p["bk"] = TensorSpec(stack + (Hkv * Dh,), ax + ("kv_heads",), "zeros")
        p["bv"] = TensorSpec(stack + (Hkv * Dh,), ax + ("kv_heads",), "zeros")
    if cfg.qk_norm:
        p["qn"] = TensorSpec(stack + (Dh,), ax + (None,), "ones")
        p["kn"] = TensorSpec(stack + (Dh,), ax + (None,), "ones")
    return p


def mlp_spec(cfg: ArchConfig, L: int, d: int, ff: int):
    stack = (L,) if L else ()
    ax = ("layers",) if L else ()
    sc, sc2 = d ** -0.5, ff ** -0.5
    if cfg.act == "gelu":  # plain MLP with biases (whisper)
        return {
            "w1": TensorSpec(stack + (d, ff), ax + ("embed", "mlp"), "normal", sc),
            "b1": TensorSpec(stack + (ff,), ax + ("mlp",), "zeros"),
            "w2": TensorSpec(stack + (ff, d), ax + ("mlp", "embed"), "normal", sc2),
            "b2": TensorSpec(stack + (d,), ax + ("embed",), "zeros"),
        }
    return {
        "w_gate": TensorSpec(stack + (d, ff), ax + ("embed", "mlp"), "normal", sc),
        "w_up": TensorSpec(stack + (d, ff), ax + ("embed", "mlp"), "normal", sc),
        "w_down": TensorSpec(stack + (ff, d), ax + ("mlp", "embed"), "normal", sc2),
    }


def build_spec(cfg: ArchConfig) -> Tree:
    d, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    spec: dict = {"embed": {"table": TensorSpec((V, d), ("vocab", "embed"), "embed",
                                                scale=d ** -0.5)}}
    spec.update(_norm_spec(0, d, cfg.norm, "final"))
    if not cfg.tie_embeddings:
        spec["lm_head"] = TensorSpec((d, V), ("embed", "vocab"), "normal",
                                     scale=d ** -0.5)

    if cfg.block_kind == "attn" and not cfg.cross_attention:
        layer = {}
        layer.update(_norm_spec(L, d, cfg.norm, "ln1"))
        layer.update(_norm_spec(L, d, cfg.norm, "ln2"))
        if cfg.post_norms:
            layer.update(_norm_spec(L, d, cfg.norm, "pn1"))
            layer.update(_norm_spec(L, d, cfg.norm, "pn2"))
        layer["attn"] = attn_spec(cfg, L, d)
        if cfg.n_experts:
            layer["moe"] = moe_lib.moe_spec(L, d, cfg.n_experts, cfg.moe_ff,
                                            cfg.shared_ff)
        else:
            layer["mlp"] = mlp_spec(cfg, L, d, cfg.d_ff)
        spec["layers"] = layer

    elif cfg.block_kind == "mamba_hybrid":
        groups, per = L // cfg.shared_attn_every, cfg.shared_attn_every
        m = m2.mamba2_spec(L, d, cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim,
                           cfg.conv_width)
        # reshape stacked L dim -> (groups, per) for the nested scan
        spec["mamba"] = jax.tree.map(
            lambda s: TensorSpec((groups, per) + s.shape[1:],
                                 ("layers", None) + s.axes[1:], s.init, s.scale),
            m, is_leaf=lambda x: isinstance(x, TensorSpec))
        shared_cfg = dataclasses.replace(cfg, qkv_bias=False, qk_norm=False)
        shared = {"attn": attn_spec(shared_cfg, 0, 2 * d)}  # input concat(x, x0)
        shared.update(_norm_spec(0, 2 * d, cfg.norm, "ln1"))
        shared.update(_norm_spec(0, cfg.d_model, cfg.norm, "ln2"))
        shared["mlp"] = mlp_spec(cfg, 0, d, cfg.d_ff)
        spec["shared_attn"] = shared

    elif cfg.block_kind == "xlstm":
        per = cfg.mlstm_per_slstm
        groups = L // (per + 1)
        spec["mlstm"] = xl.mlstm_spec((groups, per), d, int(cfg.proj_factor * d),
                                      cfg.n_heads, cfg.conv_width)
        spec["slstm"] = xl.slstm_spec((groups,), d, cfg.n_heads)

    elif cfg.cross_attention:  # whisper enc-dec
        Le = cfg.encoder_layers
        enc = {"attn": attn_spec(cfg, Le, d)}
        enc.update(_norm_spec(Le, d, cfg.norm, "ln1"))
        enc.update(_norm_spec(Le, d, cfg.norm, "ln2"))
        enc["mlp"] = mlp_spec(cfg, Le, d, cfg.d_ff)
        spec["encoder"] = enc
        spec.update(_norm_spec(0, d, cfg.norm, "enc_final"))
        dec = {"attn": attn_spec(cfg, L, d), "xattn": attn_spec(cfg, L, d)}
        dec.update(_norm_spec(L, d, cfg.norm, "ln1"))
        dec.update(_norm_spec(L, d, cfg.norm, "lnx"))
        dec.update(_norm_spec(L, d, cfg.norm, "ln2"))
        dec["mlp"] = mlp_spec(cfg, L, d, cfg.d_ff)
        spec["layers"] = dec
    else:
        raise ValueError(cfg.block_kind)
    return spec


# --------------------------------------------------------------- layer flags


def static_layer_windows(cfg: ArchConfig):
    """Per-layer python-static (is_global, window) list."""
    L = cfg.n_layers
    if cfg.attn_pattern == "local_global" and cfg.global_every:
        return [((i % cfg.global_every) == cfg.global_every - 1)
                for i in range(L)]
    return [True] * L


def _rope_tables(cfg: ArchConfig, max_len: int):
    """Returns (rope_local, rope_global); identical unless the arch uses a
    different theta for global layers (gemma3)."""
    cos_l, sin_l = rope_frequencies(cfg.hd, max_len, cfg.rope_theta)
    if cfg.rope_theta_global:
        cos_g, sin_g = rope_frequencies(cfg.hd, max_len, cfg.rope_theta_global)
    else:
        cos_g, sin_g = cos_l, sin_l
    return (cos_l, sin_l), (cos_g, sin_g)


# -------------------------------------------------------- attention sub-block


def _qkv(pl, cfg, xn, B, S):
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = xn.dtype
    q = xn @ pl["wq"].astype(dt)
    k = xn @ pl["wk"].astype(dt)
    v = xn @ pl["wv"].astype(dt)
    if "bq" in pl:
        q, k, v = q + pl["bq"].astype(dt), k + pl["bk"].astype(dt), v + pl["bv"].astype(dt)
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    if "qn" in pl:
        q = _head_rms(q, pl["qn"])
        k = _head_rms(k, pl["kn"])
    return q, k, v


def _mlp(pl, cfg, xn):
    dt = xn.dtype
    act = _act(cfg.act)
    tp = bool(cfg.tp_axis) and "mlp" in cfg.tp_shards
    if "w1" in pl:  # plain
        h = act(xn @ pl["w1"].astype(dt) + pl["b1"].astype(dt))
        if tp:  # b2 is replicated, added once to the gathered output
            return _col_gathered(h, pl["w2"], cfg, dt) + pl["b2"].astype(dt)
        return h @ pl["w2"].astype(dt) + pl["b2"].astype(dt)
    h = act(xn @ pl["w_gate"].astype(dt)) * (xn @ pl["w_up"].astype(dt))
    if tp:
        return _col_gathered(h, pl["w_down"], cfg, dt)
    return h @ pl["w_down"].astype(dt)


def _ffn(pl, cfg, x):
    """MLP or MoE sub-block with residual, on [B,S,d]."""
    B, S, d = x.shape
    xn = _norm(pl, x, cfg.norm, "ln2")
    if cfg.n_experts:
        xt = xn.reshape(B * S, d)

        def one_chunk(t):
            return moe_lib.moe_apply(pl["moe"], t, top_k=cfg.top_k,
                                     norm_topk=cfg.norm_topk,
                                     capacity_factor=cfg.capacity_factor,
                                     act=_act(cfg.act),
                                     dispatch_axes=cfg.moe_dispatch_axes,
                                     tp_axis=cfg.tp_axis,
                                     tp_shards=cfg.tp_shards)

        nc = cfg.moe_scan_chunks
        if nc and (B * S) % nc == 0 and (B * S) // nc >= 4 * cfg.n_experts:
            # bound the [E, C, d] dispatch buffers: scan token chunks
            xc = xt.reshape(nc, (B * S) // nc, d)
            _, yc = jax.lax.scan(lambda _, t: (None, one_chunk(t)), None, xc)
            y = yc.reshape(B, S, d)
        else:
            y = one_chunk(xt).reshape(B, S, d)
    else:
        y = _mlp(pl["mlp"], cfg, xn)
    if cfg.post_norms:
        y = _norm(pl, y, cfg.norm, "pn2")
    return x + y


# ---------------------------------------------------------------- attn family


def _attn_layer_train(cfg, pl, x, rope, window, positions, pkv=None):
    """One layer; ``window`` is python-static (0 = full causal).

    ``pkv`` optionally carries this layer's already-rope'd prefix K/V
    ``[B, Spre, Hkv, Dh]`` — the suffix queries then attend to
    ``concat(prefix, suffix)`` with the causal diagonal shifted by Spre
    (``flash_attention``'s default ``q_offset = Sk - Sq``).  Only the
    suffix K/V is returned; the prefix is already cached by the caller.
    """
    cos, sin = rope
    B, S, _ = x.shape
    xn = _norm(pl, x, cfg.norm, "ln1")
    q, k, v = _qkv(pl["attn"], cfg, xn, B, S)
    q = apply_rope(q, cos, sin, positions)
    k = apply_rope(k, cos, sin, positions)
    ka, va = k, v
    if pkv is not None:
        ka = jnp.concatenate([pkv[0].astype(k.dtype), k], 1)
        va = jnp.concatenate([pkv[1].astype(v.dtype), v], 1)
    o = flash_attention(q, ka, va, causal=True, window=window)
    o = _attn_out(pl["attn"], cfg, o.reshape(B, S, -1), x.dtype)
    if cfg.post_norms:
        o = _norm(pl, o, cfg.norm, "pn1")
    x = x + o
    return _ffn(pl, cfg, x), (k, v)


def _regroup_layers(cfg: ArchConfig, tree):
    """Split a stacked [L, ...] layer tree into ([G, P, ...], [tail, ...])."""
    P_ = cfg.global_every
    L = cfg.n_layers
    G = L // P_
    n_full = G * P_
    grouped = jax.tree.map(
        lambda a: a[:n_full].reshape((G, P_) + a.shape[1:]), tree)
    tail = jax.tree.map(lambda a: a[n_full:], tree)
    return grouped, tail, G, P_, L - n_full


def attn_forward(cfg: ArchConfig, params, tokens, *, remat=True,
                 return_cache=False, prefix_kv=None, embeds=None,
                 embed_mask=None):
    """tokens [B,S] -> final hidden [B,S,d] (+ optional stacked KV cache).

    ``prefix_kv = (k, v)`` with shapes [L, B, Spre, Hkv, Dh] turns this
    into a *suffix* prefill: the S tokens sit at absolute positions
    [Spre, Spre+S) and attend to the cached prefix K/V without recomputing
    it (the paged serving engine's prefix-cache hit path).  The returned
    cache covers only the suffix.

    ``embeds``/``embed_mask`` optionally inject precomputed embedding
    spans (``embed_inputs``); everything below the embedding boundary is
    identical for token and embedding positions, so a text-only prompt
    produces bit-identical logits through either path.
    """
    B, S = tokens.shape
    x = embed_inputs(cfg, params, tokens, embeds, embed_mask)
    offset = 0 if prefix_kv is None else prefix_kv[0].shape[2]
    positions = offset + jnp.arange(S)
    rope_l, rope_g = _rope_tables(cfg, offset + S)

    if cfg.attn_pattern != "local_global":
        def body(x, xs):
            pl, pkv = (xs, None) if prefix_kv is None else (xs[0], xs[1:])
            y, kv = _attn_layer_train(cfg, pl, x, rope_g, 0, positions,
                                      pkv=pkv)
            return y, kv if return_cache else None

        f = jax.checkpoint(body) if remat else body
        xs = params["layers"] if prefix_kv is None else \
            (params["layers"],) + tuple(prefix_kv)
        x, kvs = jax.lax.scan(f, x, xs)
        x = _norm(params, x, cfg.norm, "final")
        return (x, kvs) if return_cache else x

    # local:global pattern (gemma3): scan over period-sized groups with
    # python-static windows, so fully-masked attention blocks are pruned
    grouped, tail, G, P_, n_tail = _regroup_layers(cfg, params["layers"])
    if prefix_kv is None:
        pk_g = pv_g = pk_t = pv_t = None
    else:
        (pk_g, pk_t), (pv_g, pv_t) = [
            (a[:G * P_].reshape((G, P_) + a.shape[1:]), a[G * P_:])
            for a in prefix_kv]

    def gbody(x, xs):
        pg = xs[0] if prefix_kv is not None else xs
        kvs = []
        for idx in range(P_):
            pl = jax.tree.map(lambda a: a[idx], pg)
            pkv = None if prefix_kv is None else (xs[1][idx], xs[2][idx])
            is_g = idx == P_ - 1
            x, kv = _attn_layer_train(cfg, pl, x, rope_g if is_g else rope_l,
                                      0 if is_g else cfg.window, positions,
                                      pkv=pkv)
            kvs.append(kv)
        if return_cache:
            return x, jax.tree.map(lambda *xs_: jnp.stack(xs_), *kvs)
        return x, None

    f = jax.checkpoint(gbody) if remat else gbody
    gxs = grouped if prefix_kv is None else (grouped, pk_g, pv_g)
    x, kv_groups = jax.lax.scan(f, x, gxs)
    tail_kvs = []
    for t in range(n_tail):
        pl = jax.tree.map(lambda a: a[t], tail)
        pkv = None if prefix_kv is None else (pk_t[t], pv_t[t])
        step = functools.partial(_attn_layer_train, cfg, pl, rope=rope_l,
                                 window=cfg.window, positions=positions,
                                 pkv=pkv)
        x, kv = (jax.checkpoint(lambda x_: step(x_))(x) if remat
                 else step(x))
        tail_kvs.append(kv)
    x = _norm(params, x, cfg.norm, "final")
    if not return_cache:
        return x
    k = jnp.concatenate(
        [kv_groups[0].reshape((G * P_,) + kv_groups[0].shape[2:])]
        + [kv[0][None] for kv in tail_kvs], 0)
    v = jnp.concatenate(
        [kv_groups[1].reshape((G * P_,) + kv_groups[1].shape[2:])]
        + [kv[1][None] for kv in tail_kvs], 0)
    return x, (k, v)


# --------------------------------------------------------------- zamba2 family


def _shared_attn_apply(cfg, ps, x, x0, ropes, positions, *, kv_cache=None,
                       pos_scalar=None):
    """Shared attention+MLP block on concat(x, x0). Returns (y, kv or None)."""
    B = x.shape[0]
    dt = x.dtype
    cat = jnp.concatenate([x, x0], -1)
    if cat.ndim == 2:  # decode: [B, 2d]
        cat = cat[:, None]
    S = cat.shape[1]
    xn = _norm(ps, cat, cfg.norm, "ln1")
    q, k, v = _qkv(ps["attn"], cfg, xn, B, S)
    (cos, sin), _ = ropes
    if kv_cache is None:
        q = apply_rope(q, cos, sin, positions)
        k = apply_rope(k, cos, sin, positions)
        o = flash_attention(q, k, v, causal=True)
        kv = (k, v)
        o = o.reshape(B, S, -1) @ ps["attn"]["wo"].astype(dt)
    else:
        kc, vc, cpos = kv_cache
        q = apply_rope(q, cos, sin, pos_scalar[:, None])[:, 0]
        k = apply_rope(k, cos, sin, pos_scalar[:, None])[:, 0]
        slot = pos_scalar
        kc = kc.at[jnp.arange(B), slot].set(k.astype(kc.dtype))
        vc = vc.at[jnp.arange(B), slot].set(v[:, 0].astype(vc.dtype))
        o = decode_attention(q, kc, vc, cpos, pos_scalar,
                     repeat_kv=cfg.decode_repeat_kv)
        kv = (kc, vc)
        o = o.reshape(B, -1) @ ps["attn"]["wo"].astype(dt)
    y = x + o.reshape(x.shape)
    yn = _norm(ps, y, cfg.norm, "ln2")
    y = y + _mlp(ps["mlp"], cfg, yn).reshape(x.shape)
    return y, kv


def zamba2_forward(cfg: ArchConfig, params, tokens, *, remat=True,
                   return_cache=False):
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    x0 = x
    positions = jnp.arange(S)
    ropes = _rope_tables(cfg, S)

    def group(x, pm):
        def inner(xc, pl):
            y, st = m2.mamba2_forward(pl, xc, n_state=cfg.ssm_state,
                                      headdim=cfg.ssm_headdim,
                                      chunk=cfg.scan_chunk)
            return xc + y, st if return_cache else None

        fi = jax.checkpoint(inner) if remat else inner
        x, states = jax.lax.scan(fi, x, pm)
        y, kv = _shared_attn_apply(cfg, params["shared_attn"], x, x0, ropes,
                                   positions)
        return y, (states, kv) if return_cache else None

    x, caches = jax.lax.scan(group, x, params["mamba"])
    x = _norm(params, x, cfg.norm, "final")
    return (x, caches) if return_cache else x


# ---------------------------------------------------------------- xlstm family


def xlstm_forward(cfg: ArchConfig, params, tokens, *, remat=True,
                  return_cache=False):
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)

    def group(x, pg):
        pm, psl = pg

        def inner(xc, pl):
            y, st = xl.mlstm_block(pl, xc, nh=cfg.n_heads,
                                   chunk=cfg.scan_chunk,
                                   gather_qkv=cfg.xlstm_gather_qkv)
            return y, st if return_cache else None

        fi = jax.checkpoint(inner) if remat else inner
        x, mstates = jax.lax.scan(fi, x, pm)
        x, sstate = xl.slstm_block(psl, x, nh=cfg.n_heads)
        return x, (mstates, sstate) if return_cache else None

    x, caches = jax.lax.scan(group, x, (params["mlstm"], params["slstm"]))
    x = _norm(params, x, cfg.norm, "final")
    return (x, caches) if return_cache else x


# --------------------------------------------------------------- whisper family


def whisper_encode(cfg: ArchConfig, params, frames, *, remat=True):
    """frames [B, Se, d] precomputed (conv frontend stub)."""
    B, Se, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.act_dtype))
    pos = jnp.arange(Se)
    # sinusoidal positions
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half) / (half - 1) * jnp.log(10000.0))
    pe = jnp.concatenate([jnp.sin(pos[:, None] * freqs[None]),
                          jnp.cos(pos[:, None] * freqs[None])], -1)
    x = x + pe[None].astype(x.dtype)

    def body(x, pl):
        xn = _norm(pl, x, cfg.norm, "ln1")
        q, k, v = _qkv(pl["attn"], cfg, xn, B, Se)
        o = flash_attention(q, k, v, causal=False)
        x = x + o.reshape(B, Se, -1) @ pl["attn"]["wo"].astype(x.dtype)
        xn = _norm(pl, x, cfg.norm, "ln2")
        return x + _mlp(pl["mlp"], cfg, xn), None

    f = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(f, x, params["encoder"])
    return _norm(params, x, cfg.norm, "enc_final")


def whisper_decode_forward(cfg: ArchConfig, params, tokens, enc, *, remat=True,
                           return_cache=False):
    B, S = tokens.shape
    d = cfg.d_model
    x = embed_tokens(cfg, params, tokens)
    pos = jnp.arange(S)
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half) / (half - 1) * jnp.log(10000.0))
    pe = jnp.concatenate([jnp.sin(pos[:, None] * freqs[None]),
                          jnp.cos(pos[:, None] * freqs[None])], -1)
    x = x + pe[None].astype(x.dtype)
    Se = enc.shape[1]

    def body(x, pl):
        xn = _norm(pl, x, cfg.norm, "ln1")
        q, k, v = _qkv(pl["attn"], cfg, xn, B, S)
        o = flash_attention(q, k, v, causal=True)
        x = x + o.reshape(B, S, -1) @ pl["attn"]["wo"].astype(x.dtype)
        xn = _norm(pl, x, cfg.norm, "lnx")
        q2, _, _ = _qkv(pl["xattn"], cfg, xn, B, S)
        enc_n = enc
        k2 = (enc_n @ pl["xattn"]["wk"].astype(x.dtype)).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        v2 = (enc_n @ pl["xattn"]["wv"].astype(x.dtype)).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        if "bk" in pl["xattn"]:
            k2 = k2 + pl["xattn"]["bk"].astype(x.dtype).reshape(cfg.n_kv_heads, cfg.hd)
            v2 = v2 + pl["xattn"]["bv"].astype(x.dtype).reshape(cfg.n_kv_heads, cfg.hd)
        o2 = flash_attention(q2, k2, v2, causal=False)
        x = x + o2.reshape(B, S, -1) @ pl["xattn"]["wo"].astype(x.dtype)
        xn = _norm(pl, x, cfg.norm, "ln2")
        kv = (k, v, k2, v2) if return_cache else None
        return x + _mlp(pl["mlp"], cfg, xn), kv

    f = jax.checkpoint(body) if remat else body
    x, kvs = jax.lax.scan(f, x, params["layers"])
    x = _norm(params, x, cfg.norm, "final")
    return (x, kvs) if return_cache else x


# ------------------------------------------------------------------ losses


def chunked_xent(cfg: ArchConfig, params, hidden, labels, *, chunk=512):
    """Per-token mean cross-entropy without a full [B,S,V] logits tensor."""
    B, S, d = hidden.shape
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"])
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def step(acc, inp):
        h, y = inp
        logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        loss = ((lse - gold) * valid).sum()
        return (acc[0] + loss, acc[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def last_hidden(h, length=None):
    """Select the true last-token hidden state of a (possibly padded) batch.

    h [B, S, d]; ``length`` [B] int32 true sequence lengths when the batch
    is padded to a shape bucket (serving prefill), else None for ``h[:, -1]``.
    """
    if length is None:
        return h[:, -1]
    B = h.shape[0]
    return h[jnp.arange(B), length - 1]


def prompt_pos_map(length, S):
    """pos_map row for a bucket-padded prompt: position for the first
    ``length`` entries, -1 (= empty, masked at decode) for the padding."""
    B = length.shape[0]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return jnp.where(pos < length[:, None], pos, -1)


def last_logits(cfg: ArchConfig, params, hidden_last):
    """hidden_last [B, d] -> [B, V] fp32 logits."""
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = (hidden_last @ head.astype(hidden_last.dtype)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def forward_hidden(cfg: ArchConfig, params, batch, *, remat=True):
    """Dispatch per family; returns final hidden states [B,S,d]."""
    if cfg.cross_attention:
        enc = whisper_encode(cfg, params, batch["encoder_frames"], remat=remat)
        return whisper_decode_forward(cfg, params, batch["tokens"], enc,
                                      remat=remat)
    if cfg.block_kind == "mamba_hybrid":
        return zamba2_forward(cfg, params, batch["tokens"], remat=remat)
    if cfg.block_kind == "xlstm":
        return xlstm_forward(cfg, params, batch["tokens"], remat=remat)
    return attn_forward(cfg, params, batch["tokens"], remat=remat)


def train_loss(cfg: ArchConfig, params, batch, *, remat=True):
    h = forward_hidden(cfg, params, batch, remat=remat)
    return chunked_xent(cfg, params, h, batch["labels"])
