"""Lightweight multimodal encoder: media -> LM-injectable embedding spans.

The modality-aware request path (repro/serving/segments.py) carries
precomputed embedding spans; this module is the model that produces them —
small enough to run on an edge device, output dim equal to the serving
LM's ``d_model`` so features inject straight into the prefill entry
points (``lm.embed_inputs``):

  * images — conv patchify (non-overlapping ``patch x patch`` windows,
    implemented as an unfold + linear, which is exactly a stride-``patch``
    conv) followed by ``n_layers`` pre-norm transformer blocks;
  * audio  — per-frame linear projection into the same trunk.

The trunk reuses the repo's attention/norm stack: blocks ride
``models.attention.flash_attention`` (the blocked streaming-softmax path
that lowers to the Pallas flash kernel on TPU) and the rmsnorm apply from
``nn/layers.py``, so no new kernel surface is introduced.

**Compression knob**: ``keep_ratio`` applies keep-top-k pooling to the
encoded span — positions are ranked by fp32 feature L2 norm and only the
top ``ceil(ratio * n)`` are kept *in original order*.  The span (and with
it the feature-uplink bytes and the LM prefill length) shrinks
proportionally; ``sim/cost_model.py``'s split-point decision trades those
bytes against shipping the raw media.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention
from repro.nn.layers import apply_linear, apply_rmsnorm, linear, rmsnorm
from repro.nn.spec import TensorSpec, init_params


@dataclasses.dataclass(frozen=True)
class MMEncoderConfig:
    d_model: int  # output dim == the serving LM's d_model
    img_size: int = 32
    patch: int = 8
    audio_dim: int = 16  # input frame feature dim (mel-bin stand-in)
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 128
    max_span: int = 256  # learned position table length
    keep_ratio: float = 1.0  # keep-top-k pooling fraction (1.0 = keep all)

    @property
    def n_patches(self) -> int:
        return (self.img_size // self.patch) ** 2

    def kept(self, n: int) -> int:
        """Span length after keep-top-k pooling of ``n`` positions."""
        return max(1, min(n, math.ceil(self.keep_ratio * n)))


def mm_encoder_spec(cfg: MMEncoderConfig):
    d, L = cfg.d_model, cfg.n_layers
    pdim = cfg.patch * cfg.patch * 3

    def stack(p):  # add the [L] scan dim to a linear/rmsnorm spec
        return {k: TensorSpec((L,) + s.shape, ("layers",) + s.axes,
                              s.init, s.scale) for k, s in p.items()}

    nn = (None, None)
    return {
        "patch_proj": linear(pdim, d, axes=nn, bias=True,
                             scale=pdim ** -0.5),
        "audio_proj": linear(cfg.audio_dim, d, axes=nn, bias=True,
                             scale=cfg.audio_dim ** -0.5),
        "pos": TensorSpec((cfg.max_span, d), nn, "normal", 0.02),
        "blocks": {
            "ln1": stack(rmsnorm(d, axes=(None,))),
            "wq": stack(linear(d, d, axes=nn, scale=d ** -0.5)),
            "wk": stack(linear(d, d, axes=nn, scale=d ** -0.5)),
            "wv": stack(linear(d, d, axes=nn, scale=d ** -0.5)),
            "wo": stack(linear(d, d, axes=nn, scale=d ** -0.5)),
            "ln2": stack(rmsnorm(d, axes=(None,))),
            "w_gate": stack(linear(d, cfg.d_ff, axes=nn, scale=d ** -0.5)),
            "w_up": stack(linear(d, cfg.d_ff, axes=nn, scale=d ** -0.5)),
            "w_down": stack(linear(cfg.d_ff, d, axes=nn,
                                   scale=cfg.d_ff ** -0.5)),
        },
        "final": rmsnorm(d, axes=(None,)),
    }


def init_mm_encoder(cfg: MMEncoderConfig, key, param_dtype=jnp.float32):
    return init_params(mm_encoder_spec(cfg), key, param_dtype)


def _block(pl, x, n_heads: int):
    """Pre-norm non-causal transformer block on [B, S, d]."""
    B, S, d = x.shape
    dh = d // n_heads
    xn = apply_rmsnorm(pl["ln1"], x)
    q = apply_linear(pl["wq"], xn).reshape(B, S, n_heads, dh)
    k = apply_linear(pl["wk"], xn).reshape(B, S, n_heads, dh)
    v = apply_linear(pl["wv"], xn).reshape(B, S, n_heads, dh)
    o = flash_attention(q, k, v, causal=False)
    x = x + apply_linear(pl["wo"], o.reshape(B, S, d))
    xn = apply_rmsnorm(pl["ln2"], x)
    h = jax.nn.silu(apply_linear(pl["w_gate"], xn)) \
        * apply_linear(pl["w_up"], xn)
    return x + apply_linear(pl["w_down"], h)


def _trunk(cfg: MMEncoderConfig, params, x):
    """Positions + blocks + final norm on projected inputs [B, S, d]."""
    S = x.shape[1]
    if S > cfg.max_span:
        raise ValueError(f"span of {S} exceeds max_span={cfg.max_span}")
    x = x + params["pos"][:S][None].astype(x.dtype)

    def body(x, pl):
        return _block(pl, x, cfg.n_heads), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return apply_rmsnorm(params["final"], x)


def keep_top_k(features, k: int):
    """Keep-top-k pooling: the ``k`` highest-L2-norm positions of each
    span, order preserved — the compression knob for feature uplinks."""
    score = jnp.sqrt(jnp.sum(jnp.square(
        features.astype(jnp.float32)), -1))
    _, idx = jax.lax.top_k(score, k)
    idx = jnp.sort(idx, axis=-1)
    return jnp.take_along_axis(features, idx[..., None], axis=1)


def encode_image(cfg: MMEncoderConfig, params, images):
    """images [B, H, W, 3] float in [0, 1] -> features [B, kept, d]."""
    B, H, W, _ = images.shape
    p = cfg.patch
    if H % p or W % p:
        raise ValueError(f"image {H}x{W} not divisible by patch={p}")
    # unfold into non-overlapping patches == stride-p conv patchify
    x = images.reshape(B, H // p, p, W // p, p, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, (H // p) * (W // p), -1)
    x = _trunk(cfg, params, apply_linear(params["patch_proj"], x))
    return keep_top_k(x, cfg.kept(x.shape[1]))


def encode_audio(cfg: MMEncoderConfig, params, frames):
    """frames [B, T, audio_dim] -> features [B, kept, d]."""
    x = _trunk(cfg, params, apply_linear(params["audio_proj"], frames))
    return keep_top_k(x, cfg.kept(x.shape[1]))
