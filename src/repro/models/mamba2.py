"""Mamba2 (SSD) layer: chunked matmul scan — the TPU-native formulation.

The GPU reference implementation relies on a fused Triton kernel with
sequential elementwise recurrence; on TPU we use the SSD block decomposition
(Dao & Gu 2024, "minimal SSD"): intra-chunk attention-like matmuls (MXU) +
an inter-chunk state recurrence over ``seq/chunk`` steps only.  The chunk
contraction is what ``repro/kernels/mamba2_scan.py`` tiles for VMEM.

Shapes: x [B, S, d_in] with d_in = expand*d, heads nh = d_in/headdim,
state N, one B/C group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.spec import TensorSpec


def mamba2_spec(n_layers: int, d: int, d_in: int, n_state: int, headdim: int,
                conv_width: int):
    nh = d_in // headdim
    conv_ch = d_in + 2 * n_state  # x, B, C all pass through the causal conv
    proj_out = 2 * d_in + 2 * n_state + nh  # z, x, B, C, dt
    L = n_layers
    return {
        "pre_norm": TensorSpec((L, d), ("layers", "embed"), "ones"),
        "in_proj": TensorSpec((L, d, proj_out), ("layers", "embed", "mlp"),
                              "normal", scale=d ** -0.5),
        "conv_w": TensorSpec((L, conv_width, conv_ch), ("layers", None, "mlp"),
                             "normal", scale=conv_width ** -0.5),
        "conv_b": TensorSpec((L, conv_ch), ("layers", "mlp"), "zeros"),
        "a_log": TensorSpec((L, nh), ("layers", None), "ones"),
        "dt_bias": TensorSpec((L, nh), ("layers", None), "zeros"),
        "d_skip": TensorSpec((L, nh), ("layers", None), "ones"),
        "norm": TensorSpec((L, d_in), ("layers", "mlp"), "ones"),
        "out_proj": TensorSpec((L, d_in, d), ("layers", "mlp", "embed"),
                               "normal", scale=d_in ** -0.5),
    }


def _segsum(a):
    """log-decay matrix: out[..., i, j] = sum(a[..., j+1:i+1]), -inf for j>i."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, a_neg, B, C, *, chunk: int, init_state=None):
    """Chunked SSD scan.

    x [b,S,h,p]; dt [b,S,h] (>0, already softplus'ed); a_neg [h] (<0);
    B, C [b,S,n].  Returns (y [b,S,h,p], final_state [b,h,p,n]).
    """
    b, S, h, p = x.shape
    n = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    a = dt * a_neg[None, None, :]  # [b,S,h] log-decay per step
    xd = (x * dt[..., None]).astype(jnp.float32)  # discretized input

    def r(t, shape):  # [b, S, ...] -> [nc, b, chunk, ...]
        return t.reshape((b, nc, chunk) + shape).swapaxes(0, 1)

    ac = r(a, (h,)).transpose(0, 1, 3, 2)  # [nc,b,h,Q]
    xc, Bc, Cc = r(xd, (h, p)), r(B, (n,)), r(C, (n,))

    def step(state, inp):
        x_k, B_k, C_k, a_k = inp  # [b,Q,h,p] [b,Q,n] [b,Q,n] [b,h,Q]
        a_cum = jnp.cumsum(a_k, -1)  # [b,h,Q]
        # intra-chunk (diagonal block): attention-like matmuls on the MXU
        Lmat = jnp.exp(_segsum(a_k))  # [b,h,Q,Q]
        scores = jnp.einsum("bln,bsn->bls", C_k, B_k,
                            preferred_element_type=jnp.float32)
        y = jnp.einsum("bls,bhls,bshp->blhp", scores, Lmat, x_k)
        # off-diagonal: contribution of the carried state
        y += jnp.einsum("bln,bhpn,bhl->blhp", C_k, state, jnp.exp(a_cum))
        # state update
        decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # [b,h,Q]
        new_state = jnp.einsum("bsn,bhs,bshp->bhpn", B_k, decay_states, x_k)
        state = state * jnp.exp(a_cum[..., -1])[..., None, None] + new_state
        return state, y

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, ys = jax.lax.scan(step, s0, (xc, Bc, Cc, ac))
    y = ys.swapaxes(0, 1).reshape(b, S, h, p)
    return y, final


def ssd_reference(x, dt, a_neg, B, C, init_state=None):
    """Sequential per-token oracle (tests only)."""
    b, S, h, p = x.shape
    n = B.shape[-1]
    state = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
             else init_state.astype(jnp.float32))
    ys = []
    for t in range(S):
        dec = jnp.exp(dt[:, t] * a_neg[None, :])  # [b,h]
        upd = jnp.einsum("bhp,bn->bhpn", (x[:, t] * dt[:, t, :, None]).astype(
            jnp.float32), B[:, t].astype(jnp.float32))
        state = state * dec[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state, C[:, t].astype(jnp.float32)))
    return jnp.stack(ys, 1), state


def ssd_decode_step(state, x_t, dt_t, a_neg, B_t, C_t):
    """One-token recurrence. state [b,h,p,n]; x_t [b,h,p]; dt_t [b,h];
    B_t, C_t [b,n]."""
    dec = jnp.exp(dt_t * a_neg[None, :])
    upd = jnp.einsum("bhp,bn->bhpn", (x_t * dt_t[..., None]).astype(jnp.float32),
                     B_t.astype(jnp.float32))
    state = state * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
    return y, state


def causal_conv(x, w, b):
    """Depthwise causal conv. x [B, S, Ch]; w [W, Ch]; returns [B, S, Ch]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    return out + b[None, None]


def causal_conv_step(conv_state, x_t, w, b):
    """conv_state [B, W-1, Ch] (previous inputs); x_t [B, Ch]."""
    window = jnp.concatenate([conv_state, x_t[:, None]], 1)  # [B, W, Ch]
    y = jnp.einsum("bwc,wc->bc", window, w) + b[None]
    return y, window[:, 1:]


def mamba2_forward(p, x, *, n_state: int, headdim: int, chunk: int = 256,
                   init=None):
    """One mamba2 layer (p has no leading L dim). x [B, S, d] -> [B, S, d].

    init: None or (conv_state [B, W-1, Ch], ssm_state [B,h,p,n]) for chunked
    continuation.  Returns (y, (conv_state, ssm_state)).
    """
    Bsz, S, d = x.shape
    d_in = p["out_proj"].shape[0]
    nh = p["a_log"].shape[0]
    xf = x.astype(jnp.float32)
    xn = (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
          * p["pre_norm"].astype(jnp.float32)).astype(x.dtype)
    proj = xn @ p["in_proj"].astype(x.dtype)
    z, xi, Bc, Cc, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n_state, 2 * d_in + 2 * n_state], -1)
    conv_in = jnp.concatenate([xi, Bc, Cc], -1)
    W = p["conv_w"].shape[0]
    if init is None:
        conv_out = causal_conv(conv_in, p["conv_w"].astype(x.dtype),
                               p["conv_b"].astype(x.dtype))
        conv_state = conv_in[:, -(W - 1):]
    else:  # exact continuation from a carried conv window
        padded = jnp.concatenate([init[0].astype(x.dtype), conv_in], 1)
        conv_out = sum(
            padded[:, i:i + S] * p["conv_w"].astype(x.dtype)[i][None, None]
            for i in range(W)) + p["conv_b"].astype(x.dtype)[None, None]
        conv_state = padded[:, -(W - 1):]
    conv_out = jax.nn.silu(conv_out)
    xi, Bc, Cc = jnp.split(conv_out, [d_in, d_in + n_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32)[None, None])
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xi.reshape(Bsz, S, nh, headdim)
    y, ssm_state = ssd_chunked(xh, dt, a_neg, Bc, Cc, chunk=min(chunk, S),
                               init_state=None if init is None else init[1])
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[
        None, None, :, None]
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    # gated RMSNorm
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, -1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(jnp.float32)
         ).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (conv_state, ssm_state)


def mamba2_decode(p, x_t, conv_state, ssm_state, *, n_state: int,
                  headdim: int):
    """One-token step. x_t [B, d] -> (y [B, d], new states)."""
    Bsz, d = x_t.shape
    d_in = p["out_proj"].shape[0]
    nh = p["a_log"].shape[0]
    xf = x_t.astype(jnp.float32)
    xn = (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
          * p["pre_norm"].astype(jnp.float32)).astype(x_t.dtype)
    proj = xn @ p["in_proj"].astype(x_t.dtype)
    z, xi, Bc, Cc, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n_state, 2 * d_in + 2 * n_state], -1)
    conv_in = jnp.concatenate([xi, Bc, Cc], -1)
    conv_out, conv_state = causal_conv_step(
        conv_state, conv_in, p["conv_w"].astype(x_t.dtype),
        p["conv_b"].astype(x_t.dtype))
    conv_out = jax.nn.silu(conv_out)
    xi, Bc, Cc = jnp.split(conv_out, [d_in, d_in + n_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32)[None])
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xi.reshape(Bsz, nh, headdim)
    y, ssm_state = ssd_decode_step(ssm_state, xh, dt, a_neg, Bc, Cc)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, d_in).astype(x_t.dtype) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, -1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(jnp.float32)
         ).astype(x_t.dtype)
    return y @ p["out_proj"].astype(x_t.dtype), conv_state, ssm_state
