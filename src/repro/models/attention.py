"""Attention primitives shared across the model zoo.

``flash_attention`` is a pure-JAX blocked (streaming-softmax) attention. It is
simultaneously (a) the memory-bounded lowering path used by the dry-run — no
[S, S] score tensor ever materializes — and (b) the numerical oracle for the
Pallas kernel in ``repro/kernels/flash_attention.py``.

The causal path enumerates only the (q-chunk, k-chunk) pairs that can contain
unmasked entries (lower triangle, further pruned by a static sliding window),
so HLO FLOPs match the true causal/windowed work — fully-masked blocks are
never computed, exactly like the TPU kernel.

GQA is handled by repeating K/V to the full head count up front: it keeps the
head dim shardable over the model axis without (Hkv, G) reshape tricks that
GSPMD cannot propagate through.

Layouts: q [B, Sq, H, D]; k, v [B, Sk, Hkv, D].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _repeat_kv(k, H):
    Hkv = k.shape[2]
    if Hkv == H:
        return k
    return jnp.repeat(k, H // Hkv, axis=2)


def _block_pairs(nq, nk, cq, ck, q_off, window):
    """Static list of (q-chunk, k-chunk) pairs with any live entries."""
    pairs = []
    for i in range(nq):
        qlo, qhi = q_off + i * cq, q_off + (i + 1) * cq - 1
        for j in range(nk):
            klo, khi = j * ck, (j + 1) * ck - 1
            if klo > qhi:
                continue  # fully in the future
            if window and (qlo - khi) >= window:
                continue  # fully outside the sliding window
            pairs.append((i, j))
    return pairs


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int | None = None, chunk_q: int = 1024,
                    chunk_k: int = 1024, scale: float | None = None,
                    softcap: float = 0.0):
    """Blocked streaming-softmax attention (static shapes, static pruning)."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    scale = scale if scale is not None else D ** -0.5
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    if q_offset is None:
        q_offset = Sk - Sq if causal else 0

    if not causal and not window:
        return _kv_scan_attention(q, k, v, chunk_k=chunk_k, scale=scale,
                                  softcap=softcap)
    if softcap:  # rare; fall back to plain autodiff through the fwd scan
        o, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset,
                               min(chunk_q, Sq), min(chunk_k, Sk), scale,
                               softcap)
        return o
    return _flash(q, k, v, causal, window, q_offset, min(chunk_q, Sq),
                  min(chunk_k, Sk), scale)


def _pair_arrays(nq, nk, cq, ck, q_off, window, Sk):
    pairs = _block_pairs(nq, nk, cq, ck, q_off, window)
    ii = jnp.asarray(np.array([p[0] for p in pairs], np.int32))
    jj = jnp.asarray(np.array([p[1] for p in pairs], np.int32))
    return ii, jj


def _live_mask(i, j, cq, ck, q_offset, window, Sk):
    qpos = q_offset + i * cq + jnp.arange(cq)
    kpos = j * ck + jnp.arange(ck)
    live = kpos[None, :] <= qpos[:, None]
    if window:
        live &= (qpos[:, None] - kpos[None, :]) < window
    live &= kpos[None, :] < Sk  # k padding
    return live


def _flash_fwd_impl(q, k, v, causal, window, q_offset, cq, ck, scale,
                    softcap=0.0):
    """Returns (o [B,Sq,H,D], lse [B,H,Sq']).  k/v already repeated."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    nq, nk = -(-Sq // cq), -(-Sk // ck)
    pq, pk = nq * cq - Sq, nk * ck - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qh = q.transpose(0, 2, 1, 3)  # [B,H,Sq',D]
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    ii, jj = _pair_arrays(nq, nk, cq, ck, q_offset, window, Sk)

    def step(carry, idx):
        m, l, o = carry
        i, j = idx
        q_blk = jax.lax.dynamic_slice_in_dim(qh, i * cq, cq, 2)
        k_blk = jax.lax.dynamic_slice_in_dim(kh, j * ck, ck, 2)
        v_blk = jax.lax.dynamic_slice_in_dim(vh, j * ck, ck, 2)
        s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        live = _live_mask(i, j, cq, ck, q_offset, window, Sk)
        s = jnp.where(live[None, None], s, NEG_INF)
        m_i = jax.lax.dynamic_slice_in_dim(m, i * cq, cq, 2)
        l_i = jax.lax.dynamic_slice_in_dim(l, i * cq, cq, 2)
        o_i = jax.lax.dynamic_slice_in_dim(o, i * cq, cq, 2)
        m_new = jnp.maximum(m_i, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_i - m_new)
        l_i = l_i * corr + p.sum(-1, keepdims=True)
        o_i = o_i * corr + jnp.einsum("bhqk,bhkd->bhqd", p,
                                      v_blk.astype(jnp.float32))
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * cq, 2)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_i, i * cq, 2)
        o = jax.lax.dynamic_update_slice_in_dim(o, o_i, i * cq, 2)
        return (m, l, o), None

    m0 = jnp.full((B, H, nq * cq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, nq * cq, 1), jnp.float32)
    o0 = jnp.zeros((B, H, nq * cq, D), jnp.float32)
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (ii, jj))
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]  # [B,H,Sq']
    o = o / jnp.maximum(l, 1e-30)
    o = o.transpose(0, 2, 1, 3)[:, :Sq]
    return o.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, q_offset, cq, ck, scale):
    o, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, cq, ck, scale)
    return o


def _flash_fwd(q, k, v, causal, window, q_offset, cq, ck, scale):
    o, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, cq, ck, scale)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, q_offset, cq, ck, scale, res, do):
    """Standard flash backward: recompute p per block from saved lse."""
    q, k, v, o, lse = res
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    nq, nk = -(-Sq // cq), -(-Sk // ck)
    pq, pk = nq * cq - Sq, nk * ck - Sk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    dop = jnp.pad(do, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else do
    op = jnp.pad(o, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else o
    qh = qp.transpose(0, 2, 1, 3)
    kh = kp.transpose(0, 2, 1, 3)
    vh = vp.transpose(0, 2, 1, 3)
    doh = dop.transpose(0, 2, 1, 3).astype(jnp.float32)
    oh = op.transpose(0, 2, 1, 3).astype(jnp.float32)
    Dv = jnp.sum(doh * oh, -1, keepdims=True)  # [B,H,Sq',1]
    ii, jj = _pair_arrays(nq, nk, cq, ck, q_offset, window, Sk)

    def step(carry, idx):
        dq, dk, dv = carry
        i, j = idx
        q_blk = jax.lax.dynamic_slice_in_dim(qh, i * cq, cq, 2)
        k_blk = jax.lax.dynamic_slice_in_dim(kh, j * ck, ck, 2)
        v_blk = jax.lax.dynamic_slice_in_dim(vh, j * ck, ck, 2)
        do_blk = jax.lax.dynamic_slice_in_dim(doh, i * cq, cq, 2)
        D_blk = jax.lax.dynamic_slice_in_dim(Dv, i * cq, cq, 2)
        lse_blk = jax.lax.dynamic_slice_in_dim(lse, i * cq, cq, 2)
        s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        live = _live_mask(i, j, cq, ck, q_offset, window, Sk)
        s = jnp.where(live[None, None], s, NEG_INF)
        p = jnp.exp(s - lse_blk[..., None])  # [B,H,cq,ck]
        dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, do_blk)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do_blk,
                        v_blk.astype(jnp.float32))
        ds = p * (dp - D_blk) * scale
        dq_i = jnp.einsum("bhqk,bhkd->bhqd", ds, k_blk.astype(jnp.float32))
        dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, q_blk.astype(jnp.float32))
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * cq, cq, 2) + dq_i,
            i * cq, 2)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * ck, ck, 2) + dk_j,
            j * ck, 2)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * ck, ck, 2) + dv_j,
            j * ck, 2)
        return (dq, dk, dv), None

    z = jnp.zeros((B, H, nq * cq, D), jnp.float32)
    zk = jnp.zeros((B, H, nk * ck, D), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(step, (z, zk, zk), (ii, jj))
    dq = dq.transpose(0, 2, 1, 3)[:, :Sq].astype(q.dtype)
    dk = dk.transpose(0, 2, 1, 3)[:, :Sk].astype(k.dtype)
    dv = dv.transpose(0, 2, 1, 3)[:, :Sk].astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def _kv_scan_attention(q, k, v, *, chunk_k, scale, softcap):
    """Non-causal path: scan over KV chunks, all queries at once."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    ck = min(chunk_k, Sk)
    nk = -(-Sk // ck)
    pk = nk * ck - Sk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qh = q.transpose(0, 2, 1, 3)
    kc = k.reshape(B, nk, ck, H, D).transpose(1, 0, 3, 2, 4)  # [nk,B,H,ck,D]
    vc = v.reshape(B, nk, ck, H, D).transpose(1, 0, 3, 2, 4)
    kmask = (jnp.arange(nk * ck) < Sk).reshape(nk, ck)

    def step(carry, xs):
        m, l, o = carry
        k_blk, v_blk, live = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, k_blk,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(live[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1, keepdims=True)
        o = o * corr + jnp.einsum("bhqk,bhkd->bhqd", p,
                                  v_blk.astype(jnp.float32))
        return (m_new, l, o), None

    m0 = jnp.full((B, H, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    o0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (_, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (kc, vc, kmask))
    o = o / jnp.maximum(l, 1e-30)
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_positions, pos, *,
                     window: int = 0, scale: float | None = None,
                     softcap: float = 0.0, repeat_kv: bool = False):
    """Single-token attention against a KV cache.

    q [B, H, D]; k_cache/v_cache [B, S, Hkv, D]; cache_positions [B, S]
    absolute position per cache slot (-1 = empty); pos [B] query position.

    Default path keeps the cache at Hkv heads and groups q as [B, Hkv, G, D]
    (GQA einsum) — the ``repeat_kv=True`` variant materializes the G-times
    inflated cache and is kept only as the §Perf before/after baseline: for
    chameleon-34b decode_32k it round-trips 8x the cache bytes through HBM.
    """
    B, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    if repeat_kv:
        kc = _repeat_kv(k_cache, H)
        vc = _repeat_kv(v_cache, H)
        s = jnp.einsum("bhd,bshd->bhs", q, kc,
                       preferred_element_type=jnp.float32) * scale
    else:
        qg = q.reshape(B, Hkv, G, D)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        s = s.reshape(B, H, S)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = (cache_positions >= 0) & (cache_positions <= pos[:, None])
    if window:
        valid &= (pos[:, None] - cache_positions) < window
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if repeat_kv:
        o = jnp.einsum("bhs,bshd->bhd", p, vc.astype(jnp.float32))
    else:
        # keep the cache in bf16; fp32 accumulation via the MXU preferred
        # type — an explicit astype would materialize an f32 cache copy
        o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype)
                       .reshape(B, Hkv, G, S), v_cache,
                       preferred_element_type=jnp.float32).reshape(B, H, D)
    return o.astype(q.dtype)


def chunk_prefill_attention(q, k_cache, v_cache, cache_positions, qpos, *,
                            window: int = 0, scale: float | None = None,
                            softcap: float = 0.0):
    """Chunked-prefill attention: C query tokens against a KV cache.

    q [B, C, H, D]; k_cache/v_cache [B, S, Hkv, D]; cache_positions [B, S]
    absolute position per cache entry (-1 = empty); qpos [B, C] absolute
    query positions.  The chunk's own K/V must already be written into the
    cache (write-then-attend): in-chunk causality then falls out of the
    ``cache_positions <= qpos`` mask, and padded/bucketed query rows
    (qpos beyond the true chunk length) produce garbage the caller ignores.
    Generalizes ``decode_attention`` from C=1 to a whole prefill chunk.
    """
    B, C, H, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, C, Hkv, G, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = (cache_positions >= 0)[:, None, :] \
        & (cache_positions[:, None, :] <= qpos[:, :, None])  # [B, C, S]
    if window:
        valid &= (qpos[:, :, None] - cache_positions[:, None, :]) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, C, H, D).astype(q.dtype)


def paged_chunk_prefill_attention(q, k_pages, v_pages, block_tables, qpos, *,
                                  window: int = 0, scale: float | None = None,
                                  softcap: float = 0.0):
    """Chunked-prefill attention against a paged KV cache (one layer).

    q [B, C, H, D]; k_pages/v_pages [P, bs, Hkv, D]; block_tables [B, NB]
    int32 (-1 = unallocated); qpos [B, C] absolute query positions.  The
    chunk's K/V must already be scattered into its pages; entries past a
    query's position (stale data in freshly-allocated pages, bucketing
    padding) are masked exactly like ``paged_decode_attention``.
    """
    B = q.shape[0]
    P, bs, Hkv, D = k_pages.shape
    NB = block_tables.shape[1]
    bt = jnp.maximum(block_tables, 0)  # clamp -1 -> null page, masked below
    kc = k_pages[bt].reshape(B, NB * bs, Hkv, D)
    vc = v_pages[bt].reshape(B, NB * bs, Hkv, D)
    logical = (jnp.arange(NB)[:, None] * bs
               + jnp.arange(bs)[None, :])  # [NB, bs]
    cpos = jnp.where((block_tables >= 0)[:, :, None], logical[None], -1)
    return chunk_prefill_attention(q, kc, vc, cpos.reshape(B, NB * bs), qpos,
                                   window=window, scale=scale,
                                   softcap=softcap)


def paged_verify_attention(q, k_pages, v_pages, block_tables, pos, *,
                           window: int = 0, scale: float | None = None,
                           softcap: float = 0.0):
    """Multi-token verify attention against a paged KV cache (one layer).

    q [B, T, H, D] — the T candidate-token queries of a speculative
    verify pass; k_pages/v_pages [P, bs, Hkv, D]; block_tables [B, NB]
    int32 (-1 = unallocated); pos [B] the logical position of each
    sequence's *first* query token.  Query t sits at position
    ``pos + t`` and attends causally over prefix + drafts — exactly the
    attention a sequential decode of the same tokens would see.  The
    drafts' K/V must already be scattered into their pages
    (write-then-attend).  XLA fallback / oracle for
    ``repro/kernels/paged_verify.paged_verify_tpu``.
    """
    T = q.shape[1]
    qpos = pos[:, None] + jnp.arange(T)[None, :]  # [B, T]
    return paged_chunk_prefill_attention(q, k_pages, v_pages, block_tables,
                                         qpos, window=window, scale=scale,
                                         softcap=softcap)


def paged_verify_attention_quant(q, k_pages, v_pages, k_scales, v_scales,
                                 block_tables, pos, *, window: int = 0,
                                 scale: float | None = None,
                                 softcap: float = 0.0):
    """``paged_verify_attention`` over an int8 page pool: dequantize the
    pool (the drafts' just-scattered rows included) and delegate — the
    oracle computes the same values the fused kernel dequantizes
    in-registers."""
    from repro.kernels.quant import dequantize_kv
    return paged_verify_attention(
        q, dequantize_kv(k_pages, k_scales), dequantize_kv(v_pages, v_scales),
        block_tables, pos, window=window, scale=scale, softcap=softcap)


def paged_decode_attention(q, k_pages, v_pages, block_tables, pos, *,
                           window: int = 0, scale: float | None = None,
                           softcap: float = 0.0):
    """Single-token attention against a paged KV cache (one layer).

    q [B, H, D]; k_pages/v_pages [P, bs, Hkv, D] — the physical page pool
    for this layer; block_tables [B, NB] int32 page id per logical block
    (-1 = unallocated); pos [B] query position.  Logical position of page
    entry (j, t) is ``j*bs + t``; entries past ``pos`` or in unallocated
    blocks are masked.  This is the XLA gather path — the Pallas kernel in
    ``repro/kernels/paged_decode.py`` computes the same contraction without
    materializing the gathered [B, NB*bs] cache view.
    """
    B = q.shape[0]
    P, bs, Hkv, D = k_pages.shape
    NB = block_tables.shape[1]
    bt = jnp.maximum(block_tables, 0)  # clamp -1 -> null page, masked below
    kc = k_pages[bt].reshape(B, NB * bs, Hkv, D)
    vc = v_pages[bt].reshape(B, NB * bs, Hkv, D)
    logical = (jnp.arange(NB)[:, None] * bs
               + jnp.arange(bs)[None, :])  # [NB, bs]
    cpos = jnp.where((block_tables >= 0)[:, :, None], logical[None], -1)
    return decode_attention(q, kc, vc, cpos.reshape(B, NB * bs), pos,
                            window=window, scale=scale, softcap=softcap)


def decode_attention_quant(q, k_cache, v_cache, k_scales, v_scales,
                           cache_positions, pos, *, window: int = 0,
                           scale: float | None = None, softcap: float = 0.0):
    """``decode_attention`` over an int8 cache: dequantize to fp32 (the
    same values the fused Pallas kernel computes in-registers) and run the
    full-precision contraction.  XLA fallback / oracle path — it
    materializes the dequantized cache, which is exactly what the fused
    kernels avoid."""
    from repro.kernels.quant import dequantize_kv
    kc = dequantize_kv(k_cache, k_scales, axis=-1)
    vc = dequantize_kv(v_cache, v_scales, axis=-1)
    return decode_attention(q, kc, vc, cache_positions, pos, window=window,
                            scale=scale, softcap=softcap)


def paged_decode_attention_quant(q, k_pages, v_pages, k_scales, v_scales,
                                 block_tables, pos, *, window: int = 0,
                                 scale: float | None = None,
                                 softcap: float = 0.0):
    """``paged_decode_attention`` over an int8 page pool: dequantize the
    pool to fp32 (scale rows share the page ids, so copy-on-write /
    eviction / prefix reuse need no special casing) and delegate — the
    worst-case pool is one page larger than the gathered view, so the
    cost matches the bf16 fallback.  XLA fallback for
    ``repro/kernels/paged_decode.paged_decode_quant_tpu`` and its parity
    oracle."""
    from repro.kernels.quant import dequantize_kv
    return paged_decode_attention(
        q, dequantize_kv(k_pages, k_scales), dequantize_kv(v_pages, v_scales),
        block_tables, pos, window=window, scale=scale, softcap=softcap)


def paged_chunk_prefill_attention_quant(q, k_pages, v_pages, k_scales,
                                        v_scales, block_tables, qpos, *,
                                        window: int = 0,
                                        scale: float | None = None,
                                        softcap: float = 0.0):
    """``paged_chunk_prefill_attention`` over an int8 page pool: the
    chunk's K/V (including its own write-then-attend rows) is read back
    dequantized, so chunked prefill sees exactly the cache decode will —
    a prefix-cache hit and a cold run attend to identical values."""
    from repro.kernels.quant import dequantize_kv
    return paged_chunk_prefill_attention(
        q, dequantize_kv(k_pages, k_scales), dequantize_kv(v_pages, v_scales),
        block_tables, qpos, window=window, scale=scale, softcap=softcap)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def reference_attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    """O(S^2)-memory oracle (tests only — small shapes)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq) + (Sk - Sq if causal else 0)
    kp = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window:
        m &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(m[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
