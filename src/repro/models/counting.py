"""Analytic FLOP accounting: MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE).

N counts matmul-participating parameters (embedding gathers excluded; a tied
embedding table is counted once, as the LM head).  Zamba2's shared attention
block is weight-reused, so its parameters count once per APPLICATION (9x) —
6*N*D measures compute, not storage.  Whisper adds the encoder at its own
token count.  Attention's quadratic term is excluded by the 6ND convention;
the gap shows up in the MODEL_FLOPS / HLO_FLOPS ratio, as intended.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig


def _attn_params(cfg: ArchConfig, d_in: int) -> int:
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return d_in * H * Dh + 2 * d_in * Hkv * Dh + H * Dh * cfg.d_model


def _mlp_params(cfg: ArchConfig, ff: int) -> int:
    if cfg.act == "gelu":  # plain 2-matmul MLP
        return 2 * cfg.d_model * ff
    return 3 * cfg.d_model * ff  # GLU


def active_matmul_params(cfg: ArchConfig) -> int:
    d, L = cfg.d_model, cfg.n_layers
    head = d * cfg.vocab  # tied or not, the head matmul runs per token
    if cfg.block_kind == "attn" and not cfg.cross_attention:
        per = _attn_params(cfg, d)
        if cfg.n_experts:
            per += d * cfg.n_experts  # router
            per += 3 * d * cfg.moe_ff * cfg.top_k  # active experts
            if cfg.shared_ff:
                per += 3 * d * cfg.shared_ff + d
        else:
            per += _mlp_params(cfg, cfg.d_ff)
        return L * per + head
    if cfg.block_kind == "mamba_hybrid":
        d_in = cfg.d_inner
        nh = d_in // cfg.ssm_headdim
        per = d * (2 * d_in + 2 * cfg.ssm_state + nh) + d_in * d
        n_apps = L // cfg.shared_attn_every  # shared block applications
        shared = _attn_params(cfg, 2 * d) + _mlp_params(cfg, cfg.d_ff)
        return L * per + n_apps * shared + head
    if cfg.block_kind == "xlstm":
        per_g = cfg.mlstm_per_slstm + 1
        G = L // per_g
        d_in = int(cfg.proj_factor * d)
        mlstm = 2 * d * d_in + 3 * d_in * d_in + 2 * d_in * cfg.n_heads \
            + d_in * d
        dh = d // cfg.n_heads
        slstm = 4 * d * d + cfg.n_heads * dh * 4 * dh \
            + 2 * d * int(d * 4 / 3) + int(d * 4 / 3) * d
        return G * (cfg.mlstm_per_slstm * mlstm + slstm) + head
    if cfg.cross_attention:  # whisper decoder side
        per = 2 * _attn_params(cfg, d) + _mlp_params(cfg, cfg.d_ff)
        return L * per + head
    raise ValueError(cfg.block_kind)


def encoder_matmul_params(cfg: ArchConfig) -> int:
    if not cfg.cross_attention:
        return 0
    return cfg.encoder_layers * (_attn_params(cfg, cfg.d_model)
                                 + _mlp_params(cfg, cfg.d_ff))


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference shapes."""
    N = active_matmul_params(cfg)
    mult = 6.0 if shape.kind == "train" else 2.0
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per sequence
    else:
        tokens = shape.tokens
    total = mult * N * tokens
    if cfg.cross_attention and shape.kind != "decode":
        total += mult * encoder_matmul_params(cfg) * (
            shape.global_batch * cfg.encoder_seq)
    return total
