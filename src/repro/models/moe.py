"""Mixture-of-Experts: sort-based (Megablocks-style) token dispatch.

Dense one-hot dispatch einsums cost O(T * E * C * d) FLOPs — for 60-expert
top-4 that is >2x the useful expert compute, so we use the sort/gather
formulation instead: FLOPs are exactly the expert matmuls; dispatch is pure
data movement (gather/scatter), which XLA shards with an all-to-all when
experts live on the model axis.

Static shapes throughout (capacity-factor drop policy), so it lowers under
pjit for the dry-run.  The grouped [E, C, d] x [E, d, f] einsum is the
contraction the Pallas grouped-matmul kernel (repro/kernels/moe_gmm.py)
implements on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.spec import TensorSpec


def moe_spec(n_layers: int, d: int, n_experts: int, ff: int,
             shared_ff: int = 0):
    p = {
        "router": TensorSpec((n_layers, d, n_experts), ("layers", "embed", None),
                             "normal", scale=d ** -0.5),
        "w_gate": TensorSpec((n_layers, n_experts, d, ff),
                             ("layers", "experts", "embed", "mlp"), "normal",
                             scale=d ** -0.5),
        "w_up": TensorSpec((n_layers, n_experts, d, ff),
                           ("layers", "experts", "embed", "mlp"), "normal",
                           scale=d ** -0.5),
        "w_down": TensorSpec((n_layers, n_experts, ff, d),
                             ("layers", "experts", "mlp", "embed"), "normal",
                             scale=ff ** -0.5),
    }
    if shared_ff:
        p["shared_gate"] = TensorSpec((n_layers, d, shared_ff),
                                      ("layers", "embed", "mlp"), "normal",
                                      scale=d ** -0.5)
        p["shared_up"] = TensorSpec((n_layers, d, shared_ff),
                                    ("layers", "embed", "mlp"), "normal",
                                    scale=d ** -0.5)
        p["shared_down"] = TensorSpec((n_layers, shared_ff, d),
                                      ("layers", "mlp", "embed"), "normal",
                                      scale=shared_ff ** -0.5)
        p["shared_router"] = TensorSpec((n_layers, d, 1),
                                        ("layers", "embed", None), "normal",
                                        scale=d ** -0.5)
    return p


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float = 1.25, align: int = 8) -> int:
    c = int(n_tokens * top_k / n_experts * capacity_factor)
    return max(align, -(-c // align) * align)


def moe_apply(p, x, *, top_k: int, norm_topk: bool,
              capacity_factor: float = 1.25, act=jax.nn.silu,
              dispatch_axes=None, tp_axis: str = "", tp_shards=()):
    """x [T, d] -> [T, d].  p holds one layer's weights (no leading L dim).

    ``dispatch_axes``: mesh axes to pin the capacity dim of the [E, C, d]
    dispatch/combine tensors to (C is aligned to 128 so it divides).  Without
    the constraint GSPMD tends to all-reduce the whole dispatch buffer per
    layer; with it the cross-shard token movement lowers to all-to-all /
    all-gather of token rows (see EXPERIMENTS.md §Perf cell D).

    ``tp_axis``/``tp_shards`` (distributed/tp.py, inside shard_map): the
    router is always replicated (its E axis is unsharded) and the full-E
    dispatch runs on every shard, so gating/top-k/sort are bit-identical
    everywhere.  With ``"experts"`` in ``tp_shards`` each shard holds
    ``E_loc = E / tp`` experts' weights: it slices its experts' rows out
    of the dispatch buffer, runs the local grouped matmuls, and an
    all-gather rebuilds the full [E, C, d] expert outputs — the combine
    is then identical to single-device (expert parallelism, bit-exact).
    With ``"expert_ff"`` each shard holds a 1/tp slice of every expert's
    ff dim plus a 1/tp output-column slice of the down projection: an
    all-gather rebuilds the full ff activations, the local grouped
    down-projection computes exact output columns, and a second gather
    replicates them (the non-divisible-E fallback, sharding.make_plan) —
    bit-identical, like every collective here (no split-K partial sums).
    """
    T, d = x.shape
    E = p["router"].shape[-1]
    C = capacity(T, E, top_k, capacity_factor,
                 align=128 if dispatch_axes else 8)

    def pin(t, spec):
        if dispatch_axes is None:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(t, P(*spec))

    cap_ax = tuple(dispatch_axes) if dispatch_axes else None

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # [T, k]
    if norm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch, GATHER-ONLY formulation: scatters lower to
    # huge materialized index tensors under SPMD, gathers do not.
    flat_expert = expert_ids.reshape(-1)  # [T*k]
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)  # sorted-by-expert slots
    se = flat_expert[order]
    st = order // top_k  # token of each sorted slot

    # contiguous run of each expert in the sorted order
    first = jnp.searchsorted(se, jnp.arange(E), side="left")  # [E]
    last = jnp.searchsorted(se, jnp.arange(E), side="right")
    src = first[:, None] + jnp.arange(C)[None, :]  # [E, C] sorted-slot index
    valid = jnp.arange(C)[None, :] < (last - first)[:, None]
    tok = st[jnp.clip(src, 0, T * top_k - 1)]  # [E, C] token index (gather)
    xe = jnp.where(valid[..., None], x[tok], 0)  # [E, C, d] (gather)
    xe = pin(xe, (None, cap_ax, None))

    # ---- grouped expert compute (the Pallas-kernel contraction on TPU)
    expert_par = bool(tp_axis) and "experts" in tp_shards
    ff_par = bool(tp_axis) and "expert_ff" in tp_shards
    if expert_par:
        E_loc = p["w_gate"].shape[0]
        rank = jax.lax.axis_index(tp_axis)
        xe_loc = jax.lax.dynamic_slice_in_dim(xe, rank * E_loc, E_loc, 0)
        g = jnp.einsum("ecd,edf->ecf", xe_loc, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe_loc, p["w_up"].astype(x.dtype))
        ye_loc = jnp.einsum("ecf,efd->ecd", act(g) * u,
                            p["w_down"].astype(x.dtype))
        # axis-index order rebuilds experts [0, E) in order
        ye = jax.lax.all_gather(ye_loc, tp_axis, axis=0, tiled=True)
    else:
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
        if ff_par:
            # gather the local ff activations to full width, then the
            # down projection (full contraction, 1/tp output columns) is
            # exact — see lm._col_gathered for why this beats a psum
            gu = jax.lax.all_gather(act(g) * u, tp_axis, axis=2, tiled=True)
            ye = jax.lax.all_gather(
                jnp.einsum("ecf,efd->ecd", gu, p["w_down"].astype(x.dtype)),
                tp_axis, axis=2, tiled=True)
        else:
            ye = jnp.einsum("ecf,efd->ecd", act(g) * u,
                            p["w_down"].astype(x.dtype))
    ye = pin(ye, (None, cap_ax, None))

    # ---- combine: each (token, k) slot gathers its expert output
    inv = jnp.argsort(order)  # flat slot -> position in sorted order
    c_of = inv - first[flat_expert]  # rank within expert run
    kept = c_of < C  # capacity drop
    rows = flat_expert * C + jnp.clip(c_of, 0, C - 1)  # [T*k]
    vals = ye.reshape(E * C, d)[rows]  # gather
    vals = jnp.where(kept[:, None], vals, 0).reshape(T, top_k, d)
    y = jnp.einsum("tkd,tk->td", vals.astype(jnp.float32),
                   gate_vals * kept.reshape(T, top_k))

    if "shared_gate" in p:
        sgx = act(x @ p["shared_gate"].astype(x.dtype)) * (
            x @ p["shared_up"].astype(x.dtype))
        if bool(tp_axis) and "shared_ff" in tp_shards:
            sgx_full = jax.lax.all_gather(sgx, tp_axis, axis=1, tiled=True)
            shared = jax.lax.all_gather(
                sgx_full @ p["shared_down"].astype(x.dtype),
                tp_axis, axis=1, tiled=True)
        else:
            shared = sgx @ p["shared_down"].astype(x.dtype)
        sg_gate = jax.nn.sigmoid(
            x.astype(jnp.float32) @ p["shared_router"].astype(jnp.float32))
        y = y + shared.astype(jnp.float32) * sg_gate
    return y.astype(x.dtype)


def moe_reference(p, x, *, top_k: int, norm_topk: bool, act=jax.nn.silu):
    """Dense all-experts oracle (tests only): no capacity drop."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    if norm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    E = p["router"].shape[-1]
    weights = jnp.zeros(probs.shape, jnp.float32)
    for j in range(top_k):
        weights = weights.at[jnp.arange(x.shape[0]), expert_ids[:, j]].add(
            gate_vals[:, j])
    g = jnp.einsum("td,edf->tef", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", x, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("tef,efd->ted", act(g) * u, p["w_down"].astype(x.dtype))
    y = jnp.einsum("ted,te->td", ye.astype(jnp.float32), weights)
    if "shared_gate" in p:
        sgx = act(x @ p["shared_gate"].astype(x.dtype)) * (
            x @ p["shared_up"].astype(x.dtype))
        shared = sgx @ p["shared_down"].astype(x.dtype)
        sg_gate = jax.nn.sigmoid(
            x.astype(jnp.float32) @ p["shared_router"].astype(jnp.float32))
        y = y + shared.astype(jnp.float32) * sg_gate
    return y.astype(x.dtype)
