"""Named-axis sharding rules per (arch x shape x mesh).

Logical axes (see repro/nn/spec.py) map to mesh axes per arch, with per-leaf
divisibility checks: a mesh axis is only used on a dim whose size it divides,
so no GSPMD padding is ever silently introduced.

Baseline plan (hillclimb variants layer on top, see EXPERIMENTS.md §Perf):
  * batch        -> (pod?, data)
  * heads/kv/mlp/vocab/experts -> model (tensor/expert parallelism)
  * optimizer state (fp32 m/v/master) additionally sharded over data on the
    first free divisible dim (ZeRO-1)
  * KV caches: batch -> data; kv_heads -> model when divisible, else cache
    sequence -> model (flash-decode-style KV-sequence sharding)
  * long_500k (batch=1): cache sequence -> (data, model) or (data,)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.nn.spec import TensorSpec, tree_map_specs

Tree = Any


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _leaf_pspec(spec: TensorSpec, rules: dict, mesh: Mesh) -> P:
    used: set = set()
    out = []
    for dim, name in zip(spec.shape, spec.axes):
        mesh_axis = rules.get(name)
        flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if (mesh_axis is None or any(a in used for a in flat)
                or dim % _axis_size(mesh, mesh_axis) != 0):
            out.append(None)
        else:
            used.update(flat)
            out.append(mesh_axis)
    return P(*out)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    batch_axes: tuple  # mesh axes carrying the batch dim
    rules: dict  # logical axis -> mesh axis (params/activations)

    # ------------------------------------------------------------ params
    def params(self, spec_tree: Tree) -> Tree:
        return tree_map_specs(
            lambda _p, s: NamedSharding(self.mesh, _leaf_pspec(s, self.rules,
                                                               self.mesh)),
            spec_tree)

    def opt_state(self, spec_tree: Tree):
        """ZeRO-1: m/v/master take the param pspec plus `data` on the first
        free divisible dim."""
        data_sz = _axis_size(self.mesh, self.batch_axes)

        def one(_path, s: TensorSpec):
            ps = list(_leaf_pspec(s, self.rules, self.mesh))
            for i, (dim, cur) in enumerate(zip(s.shape, ps)):
                if cur is None and dim % data_sz == 0 and dim > 0:
                    ps[i] = self.batch_axes
                    break
            return NamedSharding(self.mesh, P(*ps))

        from repro.train.optimizer import AdamWState
        f32 = tree_map_specs(one, spec_tree)
        scalar = NamedSharding(self.mesh, P())
        return AdamWState(scalar, f32, f32, f32)

    # ------------------------------------------------------------ batches
    def batch(self, batch_tree: Tree) -> Tree:
        def one(leaf):
            b = leaf.shape[0] if leaf.ndim else 0
            ax = self.batch_axes if b and b % _axis_size(
                self.mesh, self.batch_axes) == 0 else None
            rest = [None] * (leaf.ndim - 1)
            return NamedSharding(self.mesh, P(ax, *rest))

        return jax.tree.map(one, batch_tree)

    # ------------------------------------------------------------ caches
    def cache(self, cfg: ArchConfig, cache_tree: dict) -> dict:
        mesh = self.mesh
        model_sz = _axis_size(mesh, "model")
        data_ax = self.batch_axes

        data_sz = _axis_size(mesh, data_ax)
        data_flat = data_ax if isinstance(data_ax, tuple) else (data_ax,)

        def shard_cache_leaf(name, leaf):
            shp = leaf.shape
            if name in ("k_pages", "v_pages", "k_scales", "v_scales"):
                # paged pool: [L, P, bs, Hkv(, Dh)].  The page axis (1)
                # must stay unsharded — host-side CoW copies, scatters and
                # snapshot export/import all index it — so shard the kv
                # heads over "model" when divisible, else fall back to the
                # in-page sequence axis (bs), the paged analogue of the
                # dense KV-sequence fallback.
                Hkv, bs = shp[3], shp[2]
                ps = [None] * leaf.ndim
                if Hkv % model_sz == 0:
                    ps[3] = "model"
                elif bs % model_sz == 0:
                    ps[2] = "model"
                return NamedSharding(mesh, P(*ps))
            if name in ("k", "v", "xk", "xv"):
                # [L?, B, S, Hkv, Dh]
                Ld = leaf.ndim - 4
                B, S, Hkv = shp[Ld], shp[Ld + 1], shp[Ld + 2]
                ps = [None] * Ld
                b_ok = B % data_sz == 0
                ps.append(data_ax if b_ok else None)
                if Hkv % model_sz == 0:
                    ps += [None, "model", None]
                else:  # KV-sequence sharding (flash-decode style)
                    seq_ax = ("model",) if b_ok else data_flat + ("model",)
                    while seq_ax and S % _axis_size(mesh, seq_ax) != 0:
                        seq_ax = seq_ax[1:]
                    ps += [seq_ax or None, None, None]
                return NamedSharding(mesh, P(*ps))
            if name == "pos_map":
                ps = [data_ax if shp[0] % data_sz == 0 else None, None]
                return NamedSharding(mesh, P(*ps))
            # recurrent states (mamba/xlstm): batch -> data; widest divisible
            # trailing dim -> model
            ps = [None] * leaf.ndim
            b_idx = {"conv": 2, "ssm": 2, "mconv": 2, "mC": 2, "mn": 2,
                     "mm": 2, "sc": 1, "sn": 1, "sm": 1, "sh": 1}.get(name, 0)
            if shp[b_idx] % data_sz == 0:
                ps[b_idx] = data_ax
            best, best_dim = None, 0
            for i in range(leaf.ndim - 1, b_idx, -1):
                if ps[i] is None and shp[i] % model_sz == 0 and shp[i] > best_dim:
                    best, best_dim = i, shp[i]
            if best is not None:
                ps[best] = "model"
            return NamedSharding(mesh, P(*ps))

        return {k: shard_cache_leaf(k, v) for k, v in cache_tree.items()}

    def logits(self):
        return NamedSharding(self.mesh, P(self.batch_axes, None))


def make_plan(cfg: ArchConfig, mesh: Mesh, *, rules_override: dict | None = None
              ) -> ShardingPlan:
    multi_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    model_sz = mesh.shape["model"]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rules = {
        "embed": None,
        "layers": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model" if cfg.n_experts and cfg.n_experts % model_sz == 0
        else None,
        "heads": "model" if (H * Dh) % model_sz == 0 and H % model_sz == 0
        else None,
        "kv_heads": "model" if (Hkv * Dh) % model_sz == 0 and
        Hkv % model_sz == 0 else None,
        "state": None,
        "conv": None,
        "batch": batch_axes,
        None: None,
    }
    if cfg.n_experts and rules["experts"] is None:
        # 60 experts on a 16-wide model axis: experts cannot split across
        # devices, so fall back to sharding each expert's ff dim through
        # the "mlp" rule (w_gate/w_up/w_down all carry it).  When even the
        # per-expert ff dim does not divide, drop the mlp rule too —
        # otherwise dense/shared mlp leaves would shard while the expert
        # ff stayed replicated, a mixed layout the serving collective
        # contract (one sharding mode per MoE block) cannot express.
        if cfg.moe_ff % model_sz != 0 or (
                cfg.shared_ff and cfg.shared_ff % model_sz != 0):
            rules["mlp"] = None
    if rules_override:
        rules.update(rules_override)
        batch_axes = rules["batch"]  # may be overridden (e.g. pure-DP plan)
    return ShardingPlan(mesh=mesh, batch_axes=batch_axes, rules=rules)


def abstract_opt_state(abstract_params_tree: Tree):
    """ShapeDtypeStructs for AdamWState(step, m, v, master) with fp32 moments."""
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, np.float32),
        abstract_params_tree)
    step = jax.ShapeDtypeStruct((), np.int32)
    from repro.train.optimizer import AdamWState
    # ShapeDtypeStructs are immutable; the three moment trees can share
    # the same struct objects instead of two no-op tree_map copies
    return AdamWState(step, f32, f32, f32)
