from repro.distributed.sharding import (  # noqa: F401
    ShardingPlan,
    abstract_opt_state,
    make_plan,
)
