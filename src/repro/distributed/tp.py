"""Tensor-parallel sharded serving: the Model serving surface under shard_map.

``ShardedServing`` wraps the hot jitted entry points of ``models/api.Model``
(``prefill``, ``prefill_with_prefix``, ``serve_step_paged``,
``verify_step_paged``, ``prefill_chunk_paged``) in ``shard_map`` over a 1-D
``model`` mesh, so a serving engine can spread one replica's weights and
paged KV pool across ``tp`` devices.

Every collective is an **all-gather — pure data movement, zero
arithmetic — so sharded decode is bitwise identical to single-device
decode** at any width.  Megatron-style row-parallel projections (split-K
fp32 partials + psum) would halve the wire traffic, but their partial
sums round in a different order than XLA's fused matmul and flip greedy
argmax on near-ties; instead every second projection is sharded on its
*output* columns with the full contraction dim kept local
(``lm._col_gathered``):

  * attention: q/kv heads split over ``model`` (column-parallel qkv,
    exact local per-head attention); ``wo`` holds all H*Dh rows and 1/tp
    of the d_model output columns, gather-matmul-gather.  The paged
    pool's ``Hkv`` axis carries the head split, laid out by
    ``ShardingPlan.cache``, so the per-shard pool is just a narrower pool
    and every host-side page operation (CoW copies, scatters, snapshot
    export/import — all indexing the *unsharded* page axis 1) works
    untouched;
  * dense mlp: column-parallel gate/up, output-column-parallel down;
  * MoE: the router stays replicated (bit-identical top-k everywhere);
    expert parallelism slices the dispatch buffer per-rank and all-gathers
    expert outputs, falling back to sharding every expert's ff dim (and
    the down projection's output columns) when ``E % tp != 0`` (the
    ``make_plan`` expert-fallback rule);
  * embedding / lm_head: replicated (``vocab`` rule overridden to None),
    so last-token logits are identical on every shard and the greedy
    argmax needs no collective.

The *local* model inside each shard_map body is an ordinary ``Model`` whose
config holds the per-shard dimensions (``n_heads / tp`` etc.) plus
``tp_axis``/``tp_shards`` telling the forward pass where to gather — no
model-code fork, just ``dataclasses.replace``.

When the kv heads do not divide ``tp``, attention (and its pool) stays
replicated while the mlp/expert dims still shard — decode stays correct,
only the attention memory win is lost (the dense-cache KV-sequence
fallback of ``ShardingPlan.cache`` has no paged-compute analogue; see
README "Tensor-parallel serving").

Snapshots gather to host numpy (``export_paged_kv``) and re-shard on
adoption via the destination pool's own layout, which is what makes
cross-mesh migration (TP=4 cloud -> TP=1 edge) bit-identical for free.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

try:  # moved out of experimental in newer JAX
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.sharding import shard_map  # type: ignore

from repro.configs.base import ArchConfig
from repro.distributed.sharding import (ShardingPlan, _leaf_pspec, make_plan)
from repro.models.api import Model
from repro.nn.spec import tree_map_specs

Tree = Any


def serving_mesh(tp: int, devices=None) -> Mesh:
    """1-D ``model`` mesh of ``tp`` devices (plus a size-1 ``data`` axis so
    the ``make_plan`` batch rules stay well-formed).  On CPU hosts, spawn
    the devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    before importing jax."""
    devices = list(jax.devices() if devices is None else devices)
    if tp < 1 or tp > len(devices):
        raise ValueError(f"tp={tp} needs {tp} devices, have {len(devices)} "
                         "(set --xla_force_host_platform_device_count)")
    return Mesh(np.asarray(devices[:tp]).reshape(tp, 1), ("model", "data"))


@dataclasses.dataclass(frozen=True)
class ShardedServing:
    """Sharded view of one ``Model``'s serving surface over ``mesh``.

    Construction is cheap (layout decisions only); the shard_map wrappers
    trace lazily under the engine's ``jax.jit`` exactly like the unsharded
    methods they shadow.
    """
    model: Model
    mesh: Mesh

    @property
    def cfg(self) -> ArchConfig:
        return self.model.cfg

    @functools.cached_property
    def tp(self) -> int:
        return int(self.mesh.shape["model"])

    # ------------------------------------------------------------- layout
    @functools.cached_property
    def tp_shards(self) -> "tuple[str, ...]":
        """Which components actually shard at this width — every entry is
        gated on divisibility, mirroring ``make_plan``'s never-pad rule."""
        cfg, tp = self.cfg, self.tp
        shards: "list[str]" = []
        if tp == 1:
            # nothing to split: run the plain model inside shard_map (no
            # collectives at all) so a TP=1 mesh is trivially
            # bit-identical to the unsharded engine
            return ()
        # output-column modes also split d_model (wo / down projections
        # hold 1/tp of their d_model output columns)
        d_ok = cfg.d_model % tp == 0
        if d_ok and cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0:
            shards += ["heads", "kv_heads"]
        if cfg.n_experts:
            if cfg.n_experts % tp == 0:
                shards.append("experts")
            elif d_ok and cfg.moe_ff % tp == 0 and (
                    not cfg.shared_ff or cfg.shared_ff % tp == 0):
                # the make_plan expert-ff fallback, serving-side
                shards.append("expert_ff")
                if cfg.shared_ff:
                    shards.append("shared_ff")
        elif d_ok and cfg.d_ff and cfg.d_ff % tp == 0:
            shards.append("mlp")
        return tuple(shards)

    @functools.cached_property
    def kv_sharded(self) -> bool:
        return "kv_heads" in self.tp_shards

    @functools.cached_property
    def plan(self) -> ShardingPlan:
        """Serving plan: the training rules with vocab/embed pinned
        replicated (identical logits on every shard -> argmax without a
        collective) and each component rule matching ``tp_shards``."""
        sh = self.tp_shards
        override = {
            "vocab": None,
            "embed": None,
            "heads": "model" if "heads" in sh else None,
            "kv_heads": "model" if "kv_heads" in sh else None,
            "experts": "model" if "experts" in sh else None,
            "mlp": "model" if ("mlp" in sh or "expert_ff" in sh) else None,
            "batch": ("data",),
        }
        return make_plan(self.cfg, self.mesh, rules_override=override)

    @functools.cached_property
    def local_model(self) -> Model:
        """The per-shard model: same arch, 1/tp of every sharded dim, and
        ``tp_axis``/``tp_shards`` marking where the forward pass reduces.
        ``head_dim`` is pinned explicitly — the local ``d_model /
        n_heads`` fallback would be wrong once heads shrink."""
        cfg, tp, sh = self.cfg, self.tp, self.tp_shards
        if not sh:  # tp == 1 (or nothing divisible): plain replicated model
            return self.model
        upd: dict = dict(tp_axis="model", tp_shards=sh, head_dim=cfg.hd)
        if "heads" in sh:
            upd.update(n_heads=cfg.n_heads // tp,
                       n_kv_heads=cfg.n_kv_heads // tp)
        if "mlp" in sh:
            upd["d_ff"] = cfg.d_ff // tp
        if "expert_ff" in sh:
            upd["moe_ff"] = cfg.moe_ff // tp
            if "shared_ff" in sh:
                upd["shared_ff"] = cfg.shared_ff // tp
        # "experts": n_experts stays global — moe_apply reads the local
        # expert count off the sharded w_gate leaf and the (replicated)
        # router still sees all E logits
        return Model(dataclasses.replace(cfg, **upd))

    # ------------------------------------------------------------- params
    @functools.cached_property
    def param_pspecs(self) -> Tree:
        """Per-leaf pspecs.  Projections that *close* a sharded dim (wo,
        mlp/expert down) are laid out output-column-parallel — full
        contraction rows, 1/tp of the trailing ``embed`` columns — so the
        local matmul after an input all-gather is exact (see
        ``lm._col_gathered``).  Everything else follows the plan rules
        (column-parallel openings, expert-sharded MoE leaves,
        replicated vocab/norms)."""
        rules, mesh, sh = self.plan.rules, self.mesh, self.tp_shards

        def leaf(_p, s):
            ax = s.axes
            if len(ax) >= 2 and ax[-1] == "embed" and (
                    (ax[-2] == "heads" and "heads" in sh)
                    or (ax[-2] == "mlp" and ("mlp" in sh or "expert_ff" in sh
                                             or "shared_ff" in sh))):
                return P(*([None] * (len(ax) - 1) + ["model"]))
            return _leaf_pspec(s, rules, mesh)

        return tree_map_specs(leaf, self.model.spec)

    @functools.cached_property
    def param_shardings(self) -> Tree:
        return jax.tree.map(lambda ps: NamedSharding(self.mesh, ps),
                            self.param_pspecs)

    def shard_params(self, params: Tree) -> Tree:
        return jax.tree.map(jax.device_put, params, self.param_shardings)

    # ------------------------------------------------------------- caches
    def cache_shardings(self, cache_tree: dict) -> dict:
        """NamedShardings for the paged pool leaves.  ``ShardingPlan.cache``
        lays the pool out when the kv heads shard; otherwise the pool is
        replicated (its in-page sequence fallback is a *storage* layout —
        the paged compute path cannot split offsets within a page)."""
        if not self.kv_sharded:
            return {k: NamedSharding(self.mesh, P()) for k in cache_tree}
        return self.plan.cache(self.cfg, cache_tree)

    def _cache_pspecs(self, cache_tree: dict) -> dict:
        return {k: s.spec
                for k, s in self.cache_shardings(cache_tree).items()}

    @functools.cached_property
    def _kv_pspec(self) -> P:
        """Dense fresh-KV leaves [L, B, S, Hkv, Dh] out of the prefill
        paths: sharded on the kv-head axis exactly like the pool, so the
        engine's host-side scatter lines the shards up for free."""
        if self.kv_sharded:
            return P(None, None, None, "model", None)
        return P()

    # ---------------------------------------------------------- wrappers
    @staticmethod
    def _rep(tree: Tree) -> Tree:
        return jax.tree.map(lambda _: P(), tree)

    def _smap(self, fn, in_specs, out_specs):
        return shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

    def prefill(self, params, batch):
        """Monolithic/bucketed prefill (``Model.prefill``), sharded."""
        local = self.local_model
        kv = self._kv_pspec
        f = self._smap(lambda p, b: local.prefill(p, b),
                       (self.param_pspecs, self._rep(batch)),
                       (P(), {"k": kv, "v": kv, "pos_map": P()}))
        return f(params, batch)

    def prefill_with_prefix(self, params, batch, prefix_k, prefix_v):
        local = self.local_model
        kv = self._kv_pspec
        f = self._smap(
            lambda p, b, pk, pv: local.prefill_with_prefix(p, b, pk, pv),
            (self.param_pspecs, self._rep(batch), kv, kv),
            (P(), (kv, kv)))
        return f(params, batch, prefix_k, prefix_v)

    def serve_step_paged(self, params, cache, batch):
        local = self.local_model
        cs = self._cache_pspecs(cache)
        f = self._smap(lambda p, c, b: local.serve_step_paged(p, c, b),
                       (self.param_pspecs, cs, self._rep(batch)),
                       (P(), cs))
        return f(params, cache, batch)

    def verify_step_paged(self, params, cache, batch):
        local = self.local_model
        cs = self._cache_pspecs(cache)
        f = self._smap(lambda p, c, b: local.verify_step_paged(p, c, b),
                       (self.param_pspecs, cs, self._rep(batch)),
                       (P(), cs))
        return f(params, cache, batch)

    def prefill_chunk_paged(self, params, cache, batch):
        local = self.local_model
        cs = self._cache_pspecs(cache)
        f = self._smap(lambda p, c, b: local.prefill_chunk_paged(p, c, b),
                       (self.param_pspecs, cs, self._rep(batch)),
                       (P(), cs))
        return f(params, cache, batch)
