"""Frozen pre-trained encoder architectures (paper Sec. IV-A, component 1).

Exact architectures of ``google/vit-base-patch16-224`` and
``distilbert-base-uncased`` in JAX.  The container is offline, so the
pretrained weights are replaced by seeded random weights — frozen random
transformers are valid (untrained-feature) encoders; the learnable
projections / fusion / heads train on top exactly as in the paper.  This is
documented as a fidelity deviation (README.md, Design notes).

``profile`` scales the encoder for CPU budget:
  * "paper" — ViT-B/16 @ 224px (196+1 tokens), DistilBERT L=256
  * "fast"  — same layer count/width, 64px images (16+1 tokens), L=64
  * "tiny"  — 2 layers, width 128 (unit tests)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.nn.spec import TensorSpec, init_params


@dataclasses.dataclass(frozen=True)
class EncoderProfile:
    name: str
    img_size: int
    patch: int
    vit_layers: int
    vit_dim: int
    vit_heads: int
    vit_mlp: int
    text_len: int
    bert_layers: int
    bert_dim: int
    bert_heads: int
    bert_mlp: int
    bert_vocab: int


PROFILES = {
    "paper": EncoderProfile("paper", 224, 16, 12, 768, 12, 3072,
                            256, 6, 768, 12, 3072, 30522),
    "fast": EncoderProfile("fast", 64, 16, 12, 768, 12, 3072,
                           64, 6, 768, 12, 3072, 30522),
    "tiny": EncoderProfile("tiny", 32, 16, 2, 128, 4, 256,
                           16, 2, 128, 4, 256, 1024),
}


def _tx_layer_spec(L, d, mlp):
    def t(shape, init="normal", scale=None):
        return TensorSpec((L,) + shape, ("layers",) + (None,) * len(shape),
                          init, scale)

    return {
        "ln1_s": t((d,), "ones"), "ln1_b": t((d,), "zeros"),
        "ln2_s": t((d,), "ones"), "ln2_b": t((d,), "zeros"),
        "wq": t((d, d), scale=d ** -0.5), "bq": t((d,), "zeros"),
        "wk": t((d, d), scale=d ** -0.5), "bk": t((d,), "zeros"),
        "wv": t((d, d), scale=d ** -0.5), "bv": t((d,), "zeros"),
        "wo": t((d, d), scale=d ** -0.5), "bo": t((d,), "zeros"),
        "w1": t((d, mlp), scale=d ** -0.5), "b1": t((mlp,), "zeros"),
        "w2": t((mlp, d), scale=mlp ** -0.5), "b2": t((d,), "zeros"),
    }


def vit_spec(p: EncoderProfile):
    n_patches = (p.img_size // p.patch) ** 2
    return {
        "patch_proj": TensorSpec((p.patch * p.patch * 3, p.vit_dim),
                                 (None, None), "normal",
                                 (p.patch * p.patch * 3) ** -0.5),
        "patch_bias": TensorSpec((p.vit_dim,), (None,), "zeros"),
        "cls": TensorSpec((p.vit_dim,), (None,), "normal", 0.02),
        "pos": TensorSpec((n_patches + 1, p.vit_dim), (None, None),
                          "normal", 0.02),
        "layers": _tx_layer_spec(p.vit_layers, p.vit_dim, p.vit_mlp),
        "lnf_s": TensorSpec((p.vit_dim,), (None,), "ones"),
        "lnf_b": TensorSpec((p.vit_dim,), (None,), "zeros"),
    }


def bert_spec(p: EncoderProfile):
    return {
        "tok": TensorSpec((p.bert_vocab, p.bert_dim), (None, None),
                          "normal", 0.02),
        "pos": TensorSpec((p.text_len, p.bert_dim), (None, None),
                          "normal", 0.02),
        "emb_ln_s": TensorSpec((p.bert_dim,), (None,), "ones"),
        "emb_ln_b": TensorSpec((p.bert_dim,), (None,), "zeros"),
        "layers": _tx_layer_spec(p.bert_layers, p.bert_dim, p.bert_mlp),
    }


def _ln(x, s, b):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-12) * s + b).astype(x.dtype)


def _tx_stack(params, x, heads, mask=None, post_ln=True):
    """Post-LN (BERT) or pre-LN (ViT) encoder stack via scan."""
    B, S, d = x.shape
    dh = d // heads

    def attn(pl, xin):
        q = (xin @ pl["wq"] + pl["bq"]).reshape(B, S, heads, dh)
        k = (xin @ pl["wk"] + pl["bk"]).reshape(B, S, heads, dh)
        v = (xin @ pl["wv"] + pl["bv"]).reshape(B, S, heads, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * dh ** -0.5
        if mask is not None:
            s = jnp.where(mask[:, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, -1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, S, d)
        return o @ pl["wo"] + pl["bo"]

    def body(x, pl):
        if post_ln:  # BERT
            a = attn(pl, x)
            x = _ln(x + a, pl["ln1_s"], pl["ln1_b"])
            h = jax.nn.gelu(x @ pl["w1"] + pl["b1"]) @ pl["w2"] + pl["b2"]
            x = _ln(x + h, pl["ln2_s"], pl["ln2_b"])
        else:  # ViT pre-LN
            a = attn(pl, _ln(x, pl["ln1_s"], pl["ln1_b"]))
            x = x + a
            xn = _ln(x, pl["ln2_s"], pl["ln2_b"])
            h = jax.nn.gelu(xn @ pl["w1"] + pl["b1"]) @ pl["w2"] + pl["b2"]
            x = x + h
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return x


def vit_encode(params, images, p: EncoderProfile):
    """images [B, H, W, 3] -> [CLS] feature [B, vit_dim]  (Eq. 8)."""
    B = images.shape[0]
    ph = p.img_size // p.patch
    x = images.reshape(B, ph, p.patch, ph, p.patch, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, ph * ph, -1)
    x = x @ params["patch_proj"] + params["patch_bias"]
    cls = jnp.broadcast_to(params["cls"], (B, 1, p.vit_dim))
    x = jnp.concatenate([cls, x], 1) + params["pos"][None]
    x = _tx_stack(params, x, p.vit_heads, post_ln=False)
    x = _ln(x, params["lnf_s"], params["lnf_b"])
    return x[:, 0]


def bert_encode(params, token_ids, attn_mask, p: EncoderProfile):
    """token_ids [B, L] -> mean-pooled feature [B, bert_dim]  (Eqs. 6-7)."""
    B, L = token_ids.shape
    x = params["tok"][token_ids] + params["pos"][None, :L]
    x = _ln(x, params["emb_ln_s"], params["emb_ln_b"])
    x = _tx_stack(params, x, p.bert_heads, mask=attn_mask.astype(bool),
                  post_ln=True)
    m = attn_mask.astype(jnp.float32)[..., None]
    return (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)


@functools.lru_cache(maxsize=4)
def frozen_encoders(profile: str = "fast", seed: int = 0):
    """(vit_params, bert_params, profile) with seeded frozen weights."""
    p = PROFILES[profile]
    key = jax.random.PRNGKey(seed)
    kv, kb = jax.random.split(key)
    vit = init_params(vit_spec(p), kv, jnp.float32)
    bert = init_params(bert_spec(p), kb, jnp.float32)
    return vit, bert, p


def encode_batch(images, token_ids, attn_mask, *, profile: str = "fast",
                 seed: int = 0):
    """Frozen forward: returns (f_img [B,768], f_text [B,768])."""
    vit, bert, p = frozen_encoders(profile, seed)
    f_i = jax.jit(vit_encode, static_argnums=2)(vit, images, p)
    f_t = jax.jit(bert_encode, static_argnums=3)(bert, token_ids, attn_mask, p)
    return f_i, f_t
