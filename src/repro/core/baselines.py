"""Baselines (paper Sec. V-C): All-Cloud, Greedy, plain D3QN, SAC,
QoS-Aware RL.

Heuristics are plain policies over the CEMLLM-Sim episode; the learning
baselines reuse the QLMIO training harness with degraded state (that is
exactly what makes them baselines — no MILP/MGQP foresight, and for
QoS-Aware RL no image modality + a linear-regression latency estimate).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlmio as Q
from repro.core.d3qn import qnet_spec, q_values
from repro.nn.spec import init_params
from repro.sim.cemllm import Servers, greedy_latencies
from repro.sim.miobench import MIOBench, SERVER_CLASSES


# ------------------------------------------------------------- heuristics


def all_cloud_policy(servers: Servers):
    cloud = int(np.argmax(servers.is_cloud))

    def policy(ep):
        return cloud

    return policy


def greedy_policy():
    def policy(ep):
        return int(np.argmin(ep.queue_s))

    return policy


def random_policy(rng: np.random.Generator):
    def policy(ep):
        return int(rng.integers(ep.servers.n))

    return policy


# --------------------------------------------------------------- plain D3QN


def make_plain_d3qn(bench, servers, features, cfg=None) -> Q.QLMIO:
    """The D3QN baseline: no task features, no predictors."""
    cfg = cfg or Q.QLMIOConfig()
    cfg = dataclasses.replace(cfg, use_milp=False, use_mgqp=False,
                              use_task_features=False)
    zeros = np.zeros((bench.tasks.n, len(SERVER_CLASSES)), np.float32)
    return Q.QLMIO(bench, servers, features, zeros, zeros, cfg)


# --------------------------------------------------------------- QoS-RL


def linreg_latency(bench: MIOBench, train_ids) -> np.ndarray:
    """QoS-Aware RL's latency estimate: per-server-class linear regression on
    prompt length only (no multimodal features) — its documented weakness."""
    x = bench.tasks.text_len.astype(np.float64)
    preds = np.zeros_like(bench.latency_s)
    for c in range(bench.latency_s.shape[1]):
        y = bench.latency_s[train_ids, c]
        xt = x[train_ids]
        A = np.stack([xt, np.ones_like(xt)], 1)
        w, *_ = np.linalg.lstsq(A, y, rcond=None)
        preds[:, c] = np.maximum(A_full(x) @ w, 0.05)
    return preds


def A_full(x):
    return np.stack([x, np.ones_like(x)], 1)


def make_qos_rl(bench, servers, features, train_ids, cfg=None) -> Q.QLMIO:
    cfg = cfg or Q.QLMIOConfig()
    cfg = dataclasses.replace(cfg, use_mgqp=False, use_img=False)
    lin = linreg_latency(bench, train_ids).astype(np.float32)
    zeros = np.zeros_like(lin)
    return Q.QLMIO(bench, servers, features, lin, zeros, cfg)


# ------------------------------------------------------------------- SAC


@dataclasses.dataclass
class SACConfig:
    lr: float = 3e-4
    gamma: float = 0.95
    alpha: float = 0.05  # entropy temperature
    batch: int = 256
    train_interval: int = 5
    tau: float = 0.005
    seed: int = 0


class DiscreteSAC:
    """Discrete soft actor-critic over the plain (no-predictor) state."""

    def __init__(self, n_actions, n_models, n_devices, cfg: SACConfig | None
                 = None, feat_dim: int = 768):
        self.cfg = cfg or SACConfig()
        self.n_actions = n_actions
        key = jax.random.PRNGKey(self.cfg.seed)
        ks = jax.random.split(key, 3)
        spec = qnet_spec(n_actions, n_models, n_devices, feat_dim,
                         use_task_features=False)
        self.pi = init_params(spec, ks[0])
        self.q1 = init_params(spec, ks[1])
        self.q2 = init_params(spec, ks[2])
        self.q1_t = jax.tree.map(jnp.copy, self.q1)
        self.q2_t = jax.tree.map(jnp.copy, self.q2)
        self.opt = {n: {"m": jax.tree.map(jnp.zeros_like, p),
                        "v": jax.tree.map(jnp.zeros_like, p),
                        "t": jnp.zeros((), jnp.int32)}
                    for n, p in [("pi", self.pi), ("q1", self.q1),
                                 ("q2", self.q2)]}
        self.rng = np.random.default_rng(self.cfg.seed)
        self.step_count = 0
        self._update_jit = jax.jit(self._update)
        self._logits = jax.jit(q_values)

    def act(self, state: dict, greedy: bool = False) -> int:
        logits = np.asarray(self._logits(
            self.pi, {k: jnp.asarray(v)[None] for k, v in state.items()}))[0]
        if greedy:
            return int(np.argmax(logits))
        p = np.exp(logits - logits.max())
        p /= p.sum()
        return int(self.rng.choice(self.n_actions, p=p))

    def _adam(self, name, params, g, lr):
        o = self.opt[name]
        t = o["t"] + 1
        m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, o["m"], g)
        v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_,
                         o["v"], g)
        tf = t.astype(jnp.float32)
        params = jax.tree.map(
            lambda p_, m_, v_: p_ - lr * (m_ / (1 - 0.9 ** tf)) /
            (jnp.sqrt(v_ / (1 - 0.999 ** tf)) + 1e-8), params, m, v)
        return params, {"m": m, "v": v, "t": t}

    def _update(self, pi, q1, q2, q1_t, q2_t, opt, batch):
        c = self.cfg

        def split(prefix):
            return {k[len(prefix):]: jnp.asarray(v) for k, v in batch.items()
                    if k.startswith(prefix)}

        s, s2 = split("s_"), split("n_")
        r = jnp.asarray(batch["reward"])
        done = jnp.asarray(batch["done"]).astype(jnp.float32)
        a = jnp.asarray(batch["action"])

        logit2 = q_values(pi, s2)
        logp2 = jax.nn.log_softmax(logit2, -1)
        p2 = jnp.exp(logp2)
        qmin2 = jnp.minimum(q_values(q1_t, s2), q_values(q2_t, s2))
        v2 = (p2 * (qmin2 - c.alpha * logp2)).sum(-1)
        y = jax.lax.stop_gradient(r + c.gamma * (1 - done) * v2)

        def q_loss(qp):
            q = jnp.take_along_axis(q_values(qp, s), a[:, None], 1)[:, 0]
            return ((q - y) ** 2).mean()

        g1 = jax.grad(q_loss)(q1)
        g2 = jax.grad(q_loss)(q2)

        def pi_loss(pp):
            logp = jax.nn.log_softmax(q_values(pp, s), -1)
            p = jnp.exp(logp)
            qmin = jax.lax.stop_gradient(
                jnp.minimum(q_values(q1, s), q_values(q2, s)))
            return (p * (c.alpha * logp - qmin)).sum(-1).mean()

        loss, gp = jax.value_and_grad(pi_loss)(pi)
        return g1, g2, gp, loss

    def train_step(self, batch) -> float:
        g1, g2, gp, loss = self._update_jit(self.pi, self.q1, self.q2,
                                            self.q1_t, self.q2_t, None,
                                            batch)
        self.q1, self.opt["q1"] = self._adam("q1", self.q1, g1, self.cfg.lr)
        self.q2, self.opt["q2"] = self._adam("q2", self.q2, g2, self.cfg.lr)
        self.pi, self.opt["pi"] = self._adam("pi", self.pi, gp, self.cfg.lr)
        t = self.cfg.tau
        self.q1_t = jax.tree.map(lambda tp, ep: t * ep + (1 - t) * tp,
                                 self.q1_t, self.q1)
        self.q2_t = jax.tree.map(lambda tp, ep: t * ep + (1 - t) * tp,
                                 self.q2_t, self.q2)
        return float(loss)

    def soft_update(self):
        pass  # folded into train_step

    def epsilon(self):
        return 0.0

    @property
    def cfg_batch(self):
        return self.cfg.batch


def make_sac(bench, servers, features, cfg: Q.QLMIOConfig | None = None
             ) -> Q.QLMIO:
    """SAC baseline wrapped in the QLMIO harness (plain state)."""
    qcfg = cfg or Q.QLMIOConfig()
    qcfg = dataclasses.replace(qcfg, use_milp=False, use_mgqp=False,
                               use_task_features=False)
    zeros = np.zeros((bench.tasks.n, len(SERVER_CLASSES)), np.float32)
    framework = Q.QLMIO(bench, servers, features, zeros, zeros, qcfg)
    sac = DiscreteSAC(servers.n, int(servers.model_id.max()) + 1,
                      int(servers.device_id.max()) + 1,
                      SACConfig(seed=qcfg.seed))
    # splice the SAC agent in: reuse replay/state machinery
    sac.cfg.replay = framework.agent.cfg.replay
    sac.cfg = dataclasses.replace(
        sac.cfg)  # keep own hyperparams
    framework.agent = _SACAdapter(sac, framework.agent.cfg)
    return framework


class _SACAdapter:
    """Duck-type the D3QNAgent interface for the QLMIO harness."""

    def __init__(self, sac: DiscreteSAC, d3qn_cfg):
        self.sac = sac
        self.cfg = d3qn_cfg
        self.step_count = 0

    def act(self, state, greedy=False):
        return self.sac.act(state, greedy=greedy)

    def train_step(self, batch):
        return self.sac.train_step(batch)

    def soft_update(self):
        pass

    def epsilon(self):
        return 0.0


def evaluate_heuristics(bench, servers, task_ids, users, trials, seed=1234):
    """All-Cloud / Greedy / Random metrics + the paper's reward for them."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, make in [("all_cloud", lambda: all_cloud_policy(servers)),
                       ("greedy", greedy_policy),
                       ("random", lambda: random_policy(rng))]:
        lat, succ, rew = [], [], []
        for _ in range(trials):
            tasks = rng.choice(task_ids, users, replace=False)
            tg = greedy_latencies(bench, servers, tasks)
            from repro.sim.cemllm import Episode
            ep = Episode(bench, servers, tasks, rng)
            pol = make()
            for u in range(users):
                rec = ep.step(pol(ep))
                r_b = 1.0 if rec["success"] else -2.0
                rew.append(1.0 - rec["latency_total"] / max(tg[u], 1e-6) + r_b)
                lat.append(rec["latency_total"])
                succ.append(rec["success"])
        out[name] = {"avg_latency_s": float(np.mean(lat)),
                     "completion_rate": float(np.mean(succ)),
                     "avg_reward": float(np.mean(rew))}
    return out
