"""Dueling Double Deep Q-Network (paper Sec. IV-B, Fig. 4).

The evaluation network mirrors Fig. 4: the QLMIO multimodal extractor
branches (text/image projections + per-server meta embeddings) fuse to a
32-d representation, concatenated with the MILP-predicted latencies, the
estimated queue loads (Eq. 19) and the MGQP success probabilities
(3 x (E+1) scalars), through a 256-256 trunk into dueling value/advantage
heads.  Q = V + A - mean(A)  (the paper's Eq. 22 prints "+ mean"; we follow
the standard dueling estimator and the cited D3QN reference; see README.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.spec import TensorSpec, init_params

META_EMB = 16
FUSED = 32
TRUNK = 256


def _lin(i, o):
    return {"w": TensorSpec((i, o), (None, None), "normal", i ** -0.5),
            "b": TensorSpec((o,), (None,), "zeros"),
            "ln_s": TensorSpec((o,), (None,), "ones"),
            "ln_b": TensorSpec((o,), (None,), "zeros")}


def qnet_spec(n_actions: int, n_models: int, n_devices: int,
              feat_dim: int = 768, use_task_features: bool = True):
    spec = {
        "emb_model": TensorSpec((n_models, META_EMB), (None, None),
                                "normal", 0.02),
        "emb_device": TensorSpec((n_devices, META_EMB), (None, None),
                                 "normal", 0.02),
        "fuse1": _lin((2 * 64 if use_task_features else 0)
                      + n_actions * 2 * META_EMB, 64),
        "fuse2": _lin(64, FUSED),
        "trunk1": _lin(FUSED + 3 * n_actions, TRUNK),
        "trunk2": _lin(TRUNK, TRUNK),
        "value": {"w": TensorSpec((TRUNK, 1), (None, None), "normal",
                                  TRUNK ** -0.5),
                  "b": TensorSpec((1,), (None,), "zeros")},
        "adv": {"w": TensorSpec((TRUNK, n_actions), (None, None), "normal",
                                TRUNK ** -0.5),
                "b": TensorSpec((n_actions,), (None,), "zeros")},
    }
    if use_task_features:
        spec["proj_text"] = _lin(feat_dim, 64)
        spec["proj_img"] = _lin(feat_dim, 64)
    return spec


def _apply_lin(p, x, act=True):
    h = x @ p["w"] + p["b"]
    hf = h.astype(jnp.float32)
    mu, var = hf.mean(-1, keepdims=True), jnp.var(hf, -1, keepdims=True)
    h = (hf - mu) * jax.lax.rsqrt(var + 1e-5) * p["ln_s"] + p["ln_b"]
    return jax.nn.gelu(h) if act else h


def q_values(params, state: dict) -> jnp.ndarray:
    """state: f_text [B,D], f_img [B,D], model_ids [B,A], device_ids [B,A],
    t_hat [B,A], q_load [B,A], b_hat [B,A]  ->  Q [B,A]."""
    B, A = state["model_ids"].shape
    branches = []
    if "proj_text" in params:
        branches.append(_apply_lin(params["proj_text"], state["f_text"]))
        branches.append(_apply_lin(params["proj_img"], state["f_img"]))
    em = params["emb_model"][state["model_ids"]].reshape(B, -1)
    ed = params["emb_device"][state["device_ids"]].reshape(B, -1)
    branches += [em, ed]
    fused = _apply_lin(params["fuse2"],
                       _apply_lin(params["fuse1"],
                                  jnp.concatenate(branches, -1)))
    x = jnp.concatenate([fused, state["t_hat"], state["q_load"],
                         state["b_hat"]], -1)
    h = _apply_lin(params["trunk2"], _apply_lin(params["trunk1"], x))
    v = h @ params["value"]["w"] + params["value"]["b"]  # [B,1]
    a = h @ params["adv"]["w"] + params["adv"]["b"]  # [B,A]
    return v + a - a.mean(-1, keepdims=True)  # Eq. 22 (sign fixed)


class Replay:
    def __init__(self, capacity: int, state_shapes: dict):
        self.capacity = capacity
        self.n = 0
        self.ptr = 0
        self.buf = {k: np.zeros((capacity,) + tuple(s), dt)
                    for k, (s, dt) in state_shapes.items()}

    def add(self, rec: dict):
        for k, v in rec.items():
            self.buf[k][self.ptr] = v
        self.ptr = (self.ptr + 1) % self.capacity
        self.n = min(self.n + 1, self.capacity)

    def sample(self, batch: int, rng: np.random.Generator) -> dict:
        idx = rng.integers(0, self.n, batch)
        return {k: v[idx] for k, v in self.buf.items()}


@dataclasses.dataclass
class D3QNConfig:
    lr: float = 1e-4  # paper Table IV
    gamma: float = 0.95
    batch: int = 256
    train_interval: int = 5  # paper Table IV (S)
    replay: int = 10_000  # paper Table IV (|M|)
    tau: float = 0.005  # paper Table IV
    eps_start: float = 1.0  # paper Table IV
    eps_end: float = 0.05
    eps_decay_steps: int = 30_000
    seed: int = 0


class D3QNAgent:
    """Generic dueling-double-DQN over the Fig. 4 state."""

    def __init__(self, n_actions: int, n_models: int, n_devices: int,
                 cfg: D3QNConfig | None = None, feat_dim: int = 768,
                 use_task_features: bool = True):
        self.cfg = cfg or D3QNConfig()
        self.n_actions = n_actions
        key = jax.random.PRNGKey(self.cfg.seed)
        spec = qnet_spec(n_actions, n_models, n_devices, feat_dim,
                         use_task_features)
        self.params = init_params(spec, key)
        self.target = jax.tree.map(jnp.copy, self.params)
        self.opt = {"m": jax.tree.map(jnp.zeros_like, self.params),
                    "v": jax.tree.map(jnp.zeros_like, self.params),
                    "t": jnp.zeros((), jnp.int32)}
        self.rng = np.random.default_rng(self.cfg.seed)
        self.step_count = 0
        self._q_fn = jax.jit(q_values)
        self._update_fn = jax.jit(self._update)

    # ------------------------------------------------------------- acting
    def epsilon(self) -> float:
        c = self.cfg
        frac = min(1.0, self.step_count / c.eps_decay_steps)
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    def act(self, state: dict, greedy: bool = False) -> int:
        if not greedy and self.rng.random() < self.epsilon():
            return int(self.rng.integers(self.n_actions))
        q = self._q_fn(self.params, {k: jnp.asarray(v)[None]
                                     for k, v in state.items()})
        return int(np.argmax(np.asarray(q)[0]))

    # ------------------------------------------------------------- update
    def _update(self, params, target, opt, batch):
        c = self.cfg

        def split(prefix):
            return {k[len(prefix):]: jnp.asarray(v) for k, v in batch.items()
                    if k.startswith(prefix)}

        s, s2 = split("s_"), split("n_")
        r = jnp.asarray(batch["reward"])
        done = jnp.asarray(batch["done"]).astype(jnp.float32)
        a = jnp.asarray(batch["action"])

        # double DQN target
        q_next_eval = q_values(params, s2)
        a_star = jnp.argmax(q_next_eval, -1)
        q_next_tgt = q_values(target, s2)
        y = r + c.gamma * (1 - done) * jnp.take_along_axis(
            q_next_tgt, a_star[:, None], 1)[:, 0]

        def loss_fn(p):
            q = q_values(p, s)
            q_a = jnp.take_along_axis(q, a[:, None], 1)[:, 0]
            err = q_a - jax.lax.stop_gradient(y)
            return jnp.where(jnp.abs(err) <= 1.0, 0.5 * err * err,
                             jnp.abs(err) - 0.5).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        t = opt["t"] + 1
        m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, opt["m"], g)
        v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_,
                         opt["v"], g)
        tf = t.astype(jnp.float32)
        params = jax.tree.map(
            lambda p_, m_, v_: p_ - c.lr * (m_ / (1 - 0.9 ** tf)) /
            (jnp.sqrt(v_ / (1 - 0.999 ** tf)) + 1e-8), params, m, v)
        return params, {"m": m, "v": v, "t": t}, loss

    def train_step(self, batch) -> float:
        self.params, self.opt, loss = self._update_fn(
            self.params, self.target, self.opt, batch)
        return float(loss)

    def soft_update(self):
        t = self.cfg.tau
        self.target = jax.tree.map(lambda tp, ep: t * ep + (1 - t) * tp,
                                   self.target, self.params)
