"""The paper's primary contribution: QLMIO + MGQP + MILP (+ baselines)."""
from repro.core.d3qn import D3QNAgent, D3QNConfig  # noqa: F401
from repro.core.predictors import Predictor, PredictorConfig  # noqa: F401
from repro.core.qlmio import QLMIO, QLMIOConfig  # noqa: F401
