"""MGQP (generation-quality) and MILP (inference-latency) predictors
(paper Sec. IV-A) with their training loops.

MGQP: extractor -> 2-layer head -> 2-way logits, Focal loss (Eq. 15).
MILP: extractor -> 2-layer head -> scalar latency [s], Huber loss (Eq. 17).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extractor as ex
from repro.nn.spec import TensorSpec, init_params


def head_spec(out_dim: int):
    return {
        "w1": TensorSpec((ex.FUSED_DIM, 32), (None, None), "normal",
                         ex.FUSED_DIM ** -0.5),
        "b1": TensorSpec((32,), (None,), "zeros"),
        "w2": TensorSpec((32, out_dim), (None, None), "normal", 32 ** -0.5),
        "b2": TensorSpec((out_dim,), (None,), "zeros"),
    }


def head_apply(p, f, *, key=None, dropout=0.1, deterministic=True):
    h = jax.nn.gelu(f @ p["w1"] + p["b1"])
    if not deterministic and dropout > 0:
        keep = jax.random.bernoulli(key, 1 - dropout, h.shape)
        h = jnp.where(keep, h / (1 - dropout), 0.0)
    return h @ p["w2"] + p["b2"]


def focal_loss(logits, labels, *, alpha: float, gamma: float = 2.0):
    """Eq. 15 — labels in {0,1}; alpha weights the positive class."""
    logp = jax.nn.log_softmax(logits, -1)
    p_t = jnp.exp(jnp.take_along_axis(logp, labels[:, None], 1))[:, 0]
    log_pt = jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
    a_t = jnp.where(labels == 1, alpha, 1.0 - alpha)
    return -(a_t * (1 - p_t) ** gamma * log_pt).mean()


def huber_loss(pred, target, *, delta: float = 1.0):
    """Eq. 17."""
    r = pred - target
    ar = jnp.abs(r)
    return jnp.where(ar <= delta, 0.5 * r * r,
                     delta * ar - 0.5 * delta * delta).mean()


@dataclasses.dataclass
class PredictorConfig:
    lr: float = 1e-3
    epochs: int = 50
    batch: int = 256
    dropout: float = 0.1
    gamma: float = 2.0  # focal
    delta: float = 1.0  # huber
    seed: int = 0
    log_t: bool = True  # regress log1p(latency_s) for the heavy tail


class Predictor:
    """Shared driver for MGQP (kind='quality') / MILP (kind='latency')."""

    def __init__(self, kind: str, n_models: int, n_devices: int,
                 cfg: PredictorConfig | None = None, feat_dim: int = 768):
        assert kind in ("quality", "latency")
        self.kind = kind
        self.cfg = cfg or PredictorConfig()
        key = jax.random.PRNGKey(self.cfg.seed)
        k1, k2 = jax.random.split(key)
        self.params = {
            "ext": ex.init_extractor(k1, feat_dim, n_models, n_devices),
            "head": init_params(head_spec(2 if kind == "quality" else 1), k2),
        }
        self._alpha = 0.5

    # ------------------------------------------------------------ forward
    def _raw(self, params, batch, key=None, deterministic=True):
        f = ex.extract(params["ext"], batch["f_text"], batch["f_img"],
                       batch["model_id"], batch["device_id"], key=key,
                       dropout=self.cfg.dropout, deterministic=deterministic)
        return head_apply(params["head"], f, key=key,
                          dropout=self.cfg.dropout,
                          deterministic=deterministic)

    def predict(self, batch) -> np.ndarray:
        """quality -> P(success) [B]; latency -> seconds [B]."""
        out = jax.jit(self._raw)(self.params, batch)
        if self.kind == "quality":
            return np.asarray(jax.nn.softmax(out, -1)[:, 1])
        t = np.asarray(out[:, 0])
        return np.expm1(t) if self.cfg.log_t else t

    # ------------------------------------------------------------ training
    def _loss(self, params, batch, key):
        out = self._raw(params, batch, key=key, deterministic=False)
        if self.kind == "quality":
            return focal_loss(out, batch["label"], alpha=self._alpha,
                              gamma=self.cfg.gamma)
        target = batch["latency_s"]
        if self.cfg.log_t:
            target = jnp.log1p(target)
        return huber_loss(out[:, 0], target, delta=self.cfg.delta)

    def fit(self, data: dict, val: dict | None = None, verbose=False
            ) -> "list[dict[str, Any]]":
        """data: arrays f_text [N,768], f_img [N,768], model_id, device_id,
        label / latency_s.  Returns per-epoch history."""
        cfg = self.cfg
        n = len(data["model_id"])
        if self.kind == "quality":
            pos = float((np.asarray(data["label"]) == 1).mean())
            self._alpha = 1.0 - pos  # weight positives by class imbalance

        opt = {"m": jax.tree.map(jnp.zeros_like, self.params),
               "v": jax.tree.map(jnp.zeros_like, self.params),
               "t": jnp.zeros((), jnp.int32)}

        @jax.jit
        def step(params, opt, batch, key):
            loss, g = jax.value_and_grad(self._loss)(params, batch, key)
            t = opt["t"] + 1
            m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, opt["m"], g)
            v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_,
                             opt["v"], g)
            tf = t.astype(jnp.float32)
            params = jax.tree.map(
                lambda p, m_, v_: p - cfg.lr * (m_ / (1 - 0.9 ** tf)) /
                (jnp.sqrt(v_ / (1 - 0.999 ** tf)) + 1e-8), params, m, v)
            return params, {"m": m, "v": v, "t": t}, loss

        rng = np.random.default_rng(cfg.seed)
        key = jax.random.PRNGKey(cfg.seed + 1)
        hist = []
        for epoch in range(cfg.epochs):
            order = rng.permutation(n)
            losses = []
            for s in range(0, n - cfg.batch + 1, cfg.batch):
                idx = order[s:s + cfg.batch]
                batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
                key, sub = jax.random.split(key)
                self.params, opt, loss = step(self.params, opt, batch, sub)
                losses.append(float(loss))
            rec = {"epoch": epoch, "train_loss": float(np.mean(losses))}
            rec.update(self.evaluate(data, prefix="train_"))
            if val is not None:
                rec.update(self.evaluate(val, prefix="val_"))
            hist.append(rec)
            if verbose:
                print(rec, flush=True)
        return hist

    def evaluate(self, data: dict, prefix="") -> dict:
        batch = {k: jnp.asarray(v) for k, v in data.items()}
        if self.kind == "quality":
            p = self.predict(batch)
            pred = (p > 0.5).astype(np.int32)
            lab = np.asarray(data["label"])
            acc = float((pred == lab).mean())
            logits = jax.jit(self._raw)(self.params, batch)
            loss = float(focal_loss(logits, jnp.asarray(lab),
                                    alpha=self._alpha, gamma=self.cfg.gamma))
            return {prefix + "acc": acc, prefix + "loss": loss}
        t = self.predict(batch)
        lat = np.asarray(data["latency_s"])
        mae = float(np.abs(t - lat).mean())
        tt = jnp.log1p(jnp.asarray(lat)) if self.cfg.log_t else jnp.asarray(lat)
        out = jax.jit(self._raw)(self.params, batch)
        loss = float(huber_loss(out[:, 0], tt, delta=self.cfg.delta))
        return {prefix + "mae_s": mae, prefix + "loss": loss}
