"""QLMIO: Quality-Latency Tradeoff-Aware MLLM Inference Offloading
(paper Sec. IV-B, Algorithm 1).

One class covers the full framework and its ablations/baselines:
  * QLMIO            — MILP + MGQP predictions + multimodal task features
  * QLMIO w/o MILP   — use_milp=False   (t_hat branch zeroed)
  * QLMIO w/o MGQP   — use_mgqp=False   (b_hat branch zeroed)
  * QLMIO w/o both   — both off
  * D3QN baseline    — use_task_features=False, both predictors off
  * QoS-Aware RL     — text-only features + linear-regression latency
                       estimates (pass custom pred matrix, use_img=False)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.d3qn import D3QNAgent, D3QNConfig, Replay
from repro.sim.cemllm import Episode, Servers, greedy_latencies
from repro.sim.cost_model import TIMEOUT_S
from repro.sim.miobench import MIOBench

_NORM_T = TIMEOUT_S  # latency normalizer for net inputs


@dataclasses.dataclass
class QLMIOConfig:
    episodes: int = 600  # paper: 12000; scaled for CPU (converges earlier)
    users: int = 30
    use_milp: bool = True
    use_mgqp: bool = True
    use_task_features: bool = True
    use_img: bool = True
    seed: int = 0
    agent: D3QNConfig | None = None


class QLMIO:
    def __init__(self, bench: MIOBench, servers: Servers,
                 features: "tuple[np.ndarray, np.ndarray]",
                 milp_preds: np.ndarray, mgqp_preds: np.ndarray,
                 cfg: QLMIOConfig | None = None):
        """milp_preds / mgqp_preds: [n_tasks, n_server_classes]."""
        self.bench = bench
        self.servers = servers
        self.cfg = cfg or QLMIOConfig()
        self.f_img, self.f_text = features
        self.milp = milp_preds
        self.mgqp = mgqp_preds
        A = servers.n
        feat_dim = self.f_text.shape[1]
        agent_cfg = self.cfg.agent or D3QNConfig(seed=self.cfg.seed)
        self.agent = D3QNAgent(A, n_models=int(servers.model_id.max()) + 1,
                               n_devices=int(servers.device_id.max()) + 1,
                               cfg=agent_cfg, feat_dim=feat_dim,
                               use_task_features=self.cfg.use_task_features)
        shapes = {"action": ((), np.int64), "reward": ((), np.float32),
                  "done": ((), np.float32)}
        for pre in ("s_", "n_"):
            if self.cfg.use_task_features:
                shapes[pre + "f_text"] = ((feat_dim,), np.float32)
                shapes[pre + "f_img"] = ((feat_dim,), np.float32)
            shapes[pre + "model_ids"] = ((A,), np.int64)
            shapes[pre + "device_ids"] = ((A,), np.int64)
            shapes[pre + "t_hat"] = ((A,), np.float32)
            shapes[pre + "q_load"] = ((A,), np.float32)
            shapes[pre + "b_hat"] = ((A,), np.float32)
        self.replay = Replay(agent_cfg.replay, shapes)
        self.rng = np.random.default_rng(self.cfg.seed)

    # ---------------------------------------------------------------- state
    def _state(self, task: int, pred_sum, pred_len) -> dict:
        """Eq. 18 state for the current task."""
        sv = self.servers
        cls = sv.cls
        t_hat = (self.milp[task, cls] / _NORM_T if self.cfg.use_milp
                 else np.zeros(sv.n))
        b_hat = (self.mgqp[task, cls] if self.cfg.use_mgqp
                 else np.zeros(sv.n))
        q_load = np.where(pred_len > 0, pred_sum / np.maximum(pred_len, 1),
                          0.0) / _NORM_T  # Eq. 19
        s = {"model_ids": sv.model_id, "device_ids": sv.device_id,
             "t_hat": t_hat.astype(np.float32),
             "q_load": q_load.astype(np.float32),
             "b_hat": b_hat.astype(np.float32)}
        if self.cfg.use_task_features:
            s["f_text"] = self.f_text[task]
            s["f_img"] = (self.f_img[task] if self.cfg.use_img
                          else np.zeros_like(self.f_img[task]))
        return s

    def _queue_pred_update(self, pred_sum, pred_len, task, action):
        # queue-load estimate uses MILP predictions when available, else the
        # running mean of observed latencies (plain-D3QN baseline behaviour)
        est = (self.milp[task, self.servers.cls[action]]
               if self.cfg.use_milp else 20.0)
        pred_sum[action] += est
        pred_len[action] += 1

    # ---------------------------------------------------------------- train
    def train(self, train_task_ids, verbose: bool = False,
              log_every: int = 20) -> "list[dict]":
        cfg, ag = self.cfg, self.agent
        history = []
        for episode in range(cfg.episodes):
            tasks = self.rng.choice(train_task_ids, cfg.users, replace=False)
            t_greedy = greedy_latencies(self.bench, self.servers, tasks)
            ep = Episode(self.bench, self.servers, tasks, self.rng)
            pred_sum = np.zeros(self.servers.n)
            pred_len = np.zeros(self.servers.n)
            rewards, lats, succ, losses = [], [], [], []
            state = self._state(int(tasks[0]), pred_sum, pred_len)
            for u in range(cfg.users):
                task = ep.current_task
                a = ag.act(state)
                rec = ep.step(a)
                self._queue_pred_update(pred_sum, pred_len, task, a)
                r_b = 1.0 if rec["success"] else -2.0  # Eq. 21
                r = 1.0 - rec["latency_total"] / max(t_greedy[u], 1e-6) + r_b
                done = float(u == cfg.users - 1)
                nxt = (self._state(int(tasks[u + 1]), pred_sum, pred_len)
                       if not done else state)
                item = {"action": a, "reward": r, "done": done}
                item.update({"s_" + k: v for k, v in state.items()})
                item.update({"n_" + k: v for k, v in nxt.items()})
                self.replay.add(item)
                ag.step_count += 1
                if (self.replay.n > ag.cfg.batch
                        and ag.step_count % ag.cfg.train_interval == 0):
                    losses.append(ag.train_step(
                        self.replay.sample(ag.cfg.batch, self.rng)))
                rewards.append(r)
                lats.append(rec["latency_total"])
                succ.append(rec["success"])
                state = nxt
            ag.soft_update()
            history.append({
                "episode": episode,
                "avg_reward": float(np.mean(rewards)),
                "avg_latency_s": float(np.mean(lats)),
                "completion_rate": float(np.mean(succ)),
                "loss": float(np.mean(losses)) if losses else float("nan"),
                "epsilon": ag.epsilon(),
            })
            if verbose and episode % log_every == 0:
                print(history[-1], flush=True)
        return history

    # ----------------------------------------------------------------- eval
    def evaluate(self, task_ids, users: int | None = None, trials: int = 1,
                 rng: np.random.Generator | None = None,
                 failed: np.ndarray | None = None) -> dict:
        users = users or self.cfg.users
        rng = rng or np.random.default_rng(1234)
        agg = {"avg_reward": [], "avg_latency_s": [], "completion_rate": []}
        for _ in range(trials):
            tasks = rng.choice(task_ids, users, replace=False)
            t_greedy = greedy_latencies(self.bench, self.servers, tasks)
            ep = Episode(self.bench, self.servers, tasks, rng, failed=failed)
            pred_sum = np.zeros(self.servers.n)
            pred_len = np.zeros(self.servers.n)
            rewards, lats, succ = [], [], []
            for u in range(users):
                task = ep.current_task
                state = self._state(task, pred_sum, pred_len)
                a = self.agent.act(state, greedy=True)
                rec = ep.step(a)
                self._queue_pred_update(pred_sum, pred_len, task, a)
                r_b = 1.0 if rec["success"] else -2.0
                rewards.append(1.0 - rec["latency_total"]
                               / max(t_greedy[u], 1e-6) + r_b)
                lats.append(rec["latency_total"])
                succ.append(rec["success"])
            agg["avg_reward"].append(np.mean(rewards))
            agg["avg_latency_s"].append(np.mean(lats))
            agg["completion_rate"].append(np.mean(succ))
        return {k: float(np.mean(v)) for k, v in agg.items()}
