"""Universal multimodal feature extractor (paper Sec. IV-A, Fig. 3).

Four branches — frozen ViT [CLS] feature, frozen DistilBERT mean-pooled
feature, model-type embedding, device-type embedding — projected to a common
64-d space (Eqs. 9-12) and fused by a two-layer MLP (Eq. 13).

The frozen encoder outputs are precomputed once per task (they never change),
so training only runs these learnable parts.

This extractor feeds the *offloading predictors* (MGQP/MILP heads, D3QN)
only.  The serving stack has its own real encoder path now: media that
actually travels through the request pipeline is encoded by
``repro/models/mm_encoder.py`` into embedding spans
(``repro/serving/segments.py``) and prefilled by the engine — see the
README's "Multimodal serving" section.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.spec import TensorSpec, init_params

PROJ_DIM = 64
META_DIM = 32
FUSED_DIM = 64


def extractor_spec(feat_dim: int = 768, n_models: int = 8,
                   n_devices: int = 8):
    def lin(i, o):
        return {"w": TensorSpec((i, o), (None, None), "normal", i ** -0.5),
                "b": TensorSpec((o,), (None,), "zeros"),
                "ln_s": TensorSpec((o,), (None,), "ones"),
                "ln_b": TensorSpec((o,), (None,), "zeros")}

    return {
        "proj_text": lin(feat_dim, PROJ_DIM),
        "proj_img": lin(feat_dim, PROJ_DIM),
        "emb_model": TensorSpec((n_models, META_DIM), (None, None),
                                "normal", 0.02),
        "emb_device": TensorSpec((n_devices, META_DIM), (None, None),
                                 "normal", 0.02),
        "fuse1": lin(3 * PROJ_DIM, FUSED_DIM),
        "fuse2": lin(FUSED_DIM, FUSED_DIM),
    }


def _proj(p, x, key, dropout, deterministic):
    h = x @ p["w"] + p["b"]
    hf = h.astype(jnp.float32)
    mu, var = hf.mean(-1, keepdims=True), jnp.var(hf, -1, keepdims=True)
    h = (hf - mu) * jax.lax.rsqrt(var + 1e-5) * p["ln_s"] + p["ln_b"]
    h = jax.nn.gelu(h)
    if not deterministic and dropout > 0:
        keep = jax.random.bernoulli(key, 1 - dropout, h.shape)
        h = jnp.where(keep, h / (1 - dropout), 0.0)
    return h


def extract(params, f_text, f_img, model_id, device_id, *, key=None,
            dropout: float = 0.1, deterministic: bool = True):
    """-> fused feature [B, 64]  (Eq. 13)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ft = _proj(params["proj_text"], f_text, k1, dropout, deterministic)
    fi = _proj(params["proj_img"], f_img, k2, dropout, deterministic)
    fm = params["emb_model"][model_id]
    fd = params["emb_device"][device_id]
    cat = jnp.concatenate([ft, fi, fm, fd], -1)
    h = _proj(params["fuse1"], cat, k3, dropout, deterministic)
    return _proj(params["fuse2"], h, k4, dropout, deterministic)


def init_extractor(key, feat_dim=768, n_models=8, n_devices=8):
    return init_params(extractor_spec(feat_dim, n_models, n_devices), key)
