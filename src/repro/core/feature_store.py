"""Per-task frozen encoder features, computed once and cached.

The frozen ViT/DistilBERT outputs never change, so MGQP/MILP/QLMIO training
only needs the cached 768-d features per task.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.core.encoders import PROFILES, bert_encode, frozen_encoders, vit_encode
from repro.data.taskgen import TaskSet


def compute_features(tasks: TaskSet, profile: str = "fast", batch: int = 128,
                     cache_dir: str | None = "results/cache",
                     seed: int = 0):
    """-> (f_img [N, D], f_text [N, D]) float32."""
    p = PROFILES[profile]
    tag = f"feats_{profile}_{tasks.seed}_{tasks.n}_{seed}.npz"
    path = os.path.join(cache_dir, tag) if cache_dir else None
    if path and os.path.exists(path):
        z = np.load(path)
        return z["f_img"], z["f_text"]
    vit, bert, _ = frozen_encoders(profile, seed)
    vit_fn = jax.jit(lambda pr, im: vit_encode(pr, im, p))
    bert_fn = jax.jit(lambda pr, t, m: bert_encode(pr, t, m, p))
    f_img, f_text = [], []
    for s in range(0, tasks.n, batch):
        idx = np.arange(s, min(s + batch, tasks.n))
        imgs = tasks.images(idx, p.img_size)
        toks, masks = tasks.texts(idx, p.text_len, p.bert_vocab)
        f_img.append(np.asarray(vit_fn(vit, imgs)))
        f_text.append(np.asarray(bert_fn(bert, toks, masks)))
    f_img = np.concatenate(f_img).astype(np.float32)
    f_text = np.concatenate(f_text).astype(np.float32)
    if path:
        os.makedirs(cache_dir, exist_ok=True)
        np.savez_compressed(path, f_img=f_img, f_text=f_text)
    return f_img, f_text
