"""Training driver: any assigned arch, synthetic LM data, fault-tolerant
checkpointing with auto-resume.

CPU-scale example (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1
Kill it mid-run and re-run the same command: it resumes from the last
atomic checkpoint.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.models import build_model
from repro.train.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig


def train(arch: str, *, steps: int, batch: int, seq: int,
          use_reduced: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 20, lr: float = 3e-4, log_every: int = 10,
          param_dtype=jnp.float32):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    data = SyntheticLM(LMDataConfig(cfg.vocab, seq, batch))
    step_fn = jax.jit(model.make_train_step(
        AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5),
                    total_steps=steps)))

    start = 0
    params = opt = None
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        start, tree = load_checkpoint(ckpt_dir)
        params, opt = tree["params"], _to_opt(tree["opt"])
        print(f"[train] resumed from step {start}", flush=True)
    if params is None:
        params = model.init(jax.random.PRNGKey(0), param_dtype)
        opt = model.init_opt(params)

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        b = data.batch(step)
        extra = {}
        if cfg.cross_attention:
            rng = np.random.default_rng(step)
            extra["encoder_frames"] = jnp.asarray(
                rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)),
                jnp.float32)
        params, opt, metrics = step_fn(params, opt,
                                       {**{k: jnp.asarray(v)
                                           for k, v in b.items()}, **extra})
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"[train] step {step} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.0f}s)", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1,
                            {"params": params, "opt": _from_opt(opt)})
    return params, losses


def _from_opt(opt):
    return {"step": opt.step, "m": opt.m, "v": opt.v, "master": opt.master}


def _to_opt(d):
    from repro.train.optimizer import AdamWState
    return AdamWState(jnp.asarray(d["step"]), d["m"], d["v"], d["master"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — needs a real pod")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    _, losses = train(args.arch, steps=args.steps, batch=args.batch,
                      seq=args.seq, use_reduced=not args.full,
                      ckpt_dir=args.ckpt_dir, lr=args.lr)
    print(f"[train] done: first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
