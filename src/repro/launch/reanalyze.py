"""Re-run the HLO analysis over cached compiled HLO (results/hlo/*.hlo.zst)
without recompiling — lets analyzer refinements update results/dryrun.json
consistently.

  PYTHONPATH=src python -m repro.launch.reanalyze --hlo results/hlo \
      --json results/dryrun.json
"""
import argparse
import json
import os

import zstandard

from repro.launch import hlo_analysis


def reanalyze(hlo_dir: str, json_path: str):
    recs = json.load(open(json_path))
    n = 0
    for r in recs:
        if r.get("status") != "ok":
            continue
        tag = (f"{r['arch']}_{r['shape']}_"
               f"{'multi' if r['mesh'] == '2x16x16' else 'single'}")
        p = os.path.join(hlo_dir, tag + ".hlo.zst")
        if not os.path.exists(p):
            continue
        txt = zstandard.ZstdDecompressor().decompress(
            open(p, "rb").read()).decode()
        res = hlo_analysis.analyze_hlo_text(txt)
        roof = hlo_analysis.Roofline(res["flops"], res["hbm_bytes"],
                                     res["collective_bytes"])
        r["roofline"] = roof.as_dict()
        r["collectives"] = res["collectives"]
        r["collective_counts"] = res["collective_counts"]
        n += 1
    json.dump(recs, open(json_path, "w"), indent=1)
    print(f"re-analyzed {n} cells -> {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", default="results/hlo")
    ap.add_argument("--json", default="results/dryrun.json")
    a = ap.parse_args()
    reanalyze(a.hlo, a.json)
