"""Serving driver: a cloud-edge continuum of real (reduced) model engines
behind the QLMIO router, with health tracking, hedging, and fault injection.

  PYTHONPATH=src python -m repro.launch.serve --requests 24 --fail-server 1
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import QLMIORouter, ServerHandle


class EngineServer(ServerHandle):
    """A real ServingEngine wrapped as a continuum server.  'Latency' is the
    engine tick count scaled by a device-speed factor (CPU container — wall
    clock would only measure this host)."""

    def __init__(self, name, arch, speed: float, model_id: int,
                 device_id: int, is_cloud: bool, seed: int = 0, fail=False):
        cfg = reduced(get_config(arch))
        self.cfg = cfg
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        self.engine = ServingEngine(model, params, max_batch=2, max_seq=96)
        self.speed = speed
        self.fail = fail
        self.uid = 0
        super().__init__(name=name, model_id=model_id, device_id=device_id,
                         is_cloud=is_cloud, execute=self._execute)

    def _execute(self, task: int):
        if self.fail:
            return 240.0, False
        rng = np.random.default_rng((task, self.model_id))
        prompt = rng.integers(0, self.cfg.vocab, 16).astype(np.int32)
        self.uid += 1
        req = Request(self.uid, prompt, max_new_tokens=8)
        self.engine.submit(req)
        t0 = self.engine.ticks
        while not req.done:
            self.engine.step()
        ticks = self.engine.ticks - t0
        return ticks / self.speed, True


def build_cluster(fail_server: int | None = None):
    servers = [
        EngineServer("edge-0 (jetson/qwen2-0.5b)", "qwen2-0.5b", 2.0, 0, 0,
                     False, fail=fail_server == 0),
        EngineServer("edge-1 (3090ti/llama3.2-3b)", "llama3.2-3b", 8.0, 1, 1,
                     False, fail=fail_server == 1),
        EngineServer("cloud (pod/chameleon-34b)", "chameleon-34b", 32.0, 2, 2,
                     True, fail=fail_server == 2),
    ]
    return servers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--fail-server", type=int, default=None)
    args = ap.parse_args()

    servers = build_cluster(args.fail_server)
    # simple analytic predictors for the demo (speed-based)
    speeds = np.array([s.speed for s in servers])
    milp = lambda task, s: 8.0 / speeds[s]
    mgqp = lambda task, s: [0.7, 0.85, 0.95][s]
    router = QLMIORouter(list(servers), milp, mgqp)
    t0 = time.time()
    ok = 0
    for task in range(args.requests):
        rec = router.dispatch(task)
        ok += rec["ok"]
        print(f"[serve] task {task} -> {servers[rec['server']].name} "
              f"lat={rec['latency']:.2f} ok={rec['ok']} "
              f"hedged={rec['hedged']}", flush=True)
    per_server = np.bincount([r["server"] for r in router.log],
                             minlength=len(servers))
    print(f"[serve] {ok}/{args.requests} ok in {time.time()-t0:.0f}s; "
          f"dispatch counts {per_server.tolist()}")
    for s in servers:
        st = s.engine.stats()
        if st.get("paged"):
            print(f"[serve] {s.name}: paged KV "
                  f"{st['kv_cache_bytes'] / 1e6:.1f} MB, "
                  f"prefix hits {st['prefix_hits']}, "
                  f"reused {st['prefix_tokens_reused']} tok, "
                  f"computed {st['prefill_tokens_computed']} tok")
    if args.fail_server is not None:
        assert per_server[args.fail_server] <= router.health.fail_threshold, \
            "router failed to drain traffic from the failed server"
        print(f"[serve] failed server {args.fail_server} drained after "
              f"{per_server[args.fail_server]} attempts (fault tolerance OK)")


if __name__ == "__main__":
    main()
