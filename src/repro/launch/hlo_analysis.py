"""Roofline terms from a compiled (SPMD-partitioned) executable.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, ignoring the trip
count — useless for scan-over-layers models.  We therefore parse the
optimized post-partitioning HLO text ourselves and attribute:

  * FLOPs            — every ``dot`` x 2 * prod(result dims) * prod(contracted
                       lhs dims), multiplied by the call multiplicity of its
                       computation (while bodies use ``known_trip_count``).
  * HBM bytes        — per top-level op: operand + result sizes.  Ops inside
                       fused computations are skipped (they live in
                       registers/VMEM); the fusion itself counts its own
                       operands/results.  This approximates true HBM traffic
                       under XLA's fusion decisions.
  * collective bytes — on-wire bytes per collective (all-reduce counts 2x:
                       reduce-scatter + all-gather phases), with loop
                       multiplicity.

All quantities are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLS_RE = re.compile(r"(?:calls=|body=|to_apply=)%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"known_trip_count[^\d]*(\d+)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return m.group(1), dims


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    type_str: str
    rhs: str
    operands: list


def _opcode_of(rhs: str) -> str:
    # rhs looks like: "bf16[8,128]{1,0} dot(%a, %b), attrs" or
    # "(f32[2], f32[3]) tuple(%x, %y)"
    depth = 0
    i = 0
    # skip the type prefix (may contain parens for tuples)
    if rhs.startswith("("):
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rest = rhs[i + 1:].strip()
    else:
        # type is like bf16[1,2]{1,0} — ends at first space
        sp = rhs.find(" ")
        rest = rhs[sp + 1:].strip() if sp > 0 else ""
    m = re.match(r"([\w\-]+)\(", rest)
    return m.group(1) if m else ""


def parse_hlo(text: str):
    """-> ({comp_name: [Op]}, entry_name)"""
    comps: dict = {}
    cur = None
    entry = None
    for line in text.splitlines():
        h = _COMP_HDR.match(line.strip()) if line and not line.startswith(" ") \
            else None
        if h and line.rstrip().endswith("{"):
            cur = h.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            continue
        m = _OP_RE.match(line)
        if m and cur is not None:
            name, rhs = m.group(1), m.group(2)
            opcode = _opcode_of(rhs)
            type_str = rhs.split(f" {opcode}(")[0] if opcode else rhs
            paren = rhs.find(f"{opcode}(") if opcode else -1
            args_str = ""
            if paren >= 0:
                depth = 0
                for i in range(paren + len(opcode), len(rhs)):
                    if rhs[i] == "(":
                        depth += 1
                    elif rhs[i] == ")":
                        depth -= 1
                        if depth == 0:
                            args_str = rhs[paren + len(opcode) + 1:i]
                            break
            operands = _OPERANDS_RE.findall(args_str)
            comps[cur].append(_Op(name, opcode, type_str, rhs, operands))
    return comps, entry


def _multiplicities(comps: dict, entry=None) -> dict:
    """Call multiplicity per computation (ENTRY = 1; while bodies x trip)."""
    if entry is None:
        for name in comps:
            if "main" in name:
                entry = name
    if entry is None:  # fall back: computation that nobody calls
        called = set()
        for ops in comps.values():
            for op in ops:
                called.update(_CALLS_RE.findall(op.rhs))
                called.update(_COND_RE.findall(op.rhs))
        entry = next((n for n in comps if n not in called), None)
    mult = {name: 0.0 for name in comps}
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    # propagate in topological-ish order (iterate until fixpoint; HLO call
    # graphs are DAGs so a few passes suffice)
    for _ in range(30):
        changed = False
        for name, ops in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for op in ops:
                trip = 1.0
                if op.opcode == "while":
                    t = _TRIP_RE.search(op.rhs)
                    trip = float(t.group(1)) if t else 1.0
                targets = _CALLS_RE.findall(op.rhs)
                targets += _COND_RE.findall(op.rhs)
                b = _BRANCHES_RE.search(op.rhs)
                if b:
                    targets += _OPERANDS_RE.findall(b.group(1))
                for t_name in targets:
                    if t_name in mult:
                        new = m * (trip if op.opcode == "while" else 1.0)
                        if new > mult[t_name]:
                            mult[t_name] = new
                            changed = True
        if not changed:
            break
    return mult


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _dot_flops(op: _Op, shapes: dict) -> float:
    _, rdims = _result_dims(op.type_str)
    out = 1.0
    for d in rdims:
        out *= d
    m = _CONTRACT_RE.search(op.rhs)
    contract = 1.0
    if m and op.operands:
        lhs_shape = shapes.get(op.operands[0], [])
        for idx in m.group(1).split(","):
            if idx.strip() and int(idx) < len(lhs_shape):
                contract *= lhs_shape[int(idx)]
    return 2.0 * out * contract


_SKIP_BYTES = {"parameter", "get-tuple-element", "tuple", "constant",
               "bitcast", "bitcast-convert", "reshape", "iota",
               "after-all", "partition-id", "while", "conditional", "call",
               "custom-call", ""}


def analyze_hlo_text(text: str) -> dict:
    comps, entry = parse_hlo(text)
    mult = _multiplicities(comps, entry)
    # symbol table: op name -> result dims (first shape in type)
    shapes: dict = {}
    fused = set()
    for name, ops in comps.items():
        for op in ops:
            _, dims = _result_dims(op.type_str)
            shapes[op.name] = dims
            if op.opcode == "fusion":
                for t in _CALLS_RE.findall(op.rhs):
                    fused.add(t)

    # op name -> total result bytes (tuples summed)
    size_of = {}
    for ops in comps.values():
        for o in ops:
            size_of[o.name] = _shape_bytes(o.type_str)

    # fusion refinements (model TPU semantics, not CPU pessimism):
    #  * a fusion whose root is dynamic-update-slice runs in place: traffic
    #    = 2x the update operand, not the whole buffer
    #  * a fusion parameter consumed ONLY via dynamic-slice reads just the
    #    slice, not the full operand
    fusion_root_dus = {}  # comp name -> update bytes
    fusion_param_bytes = {}  # comp name -> {param_idx: bytes}
    for cname, ops in comps.items():
        params = {}
        for o in ops:
            if o.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", o.rhs)
                if m:
                    params[o.name] = int(m.group(1))
        users: dict = {}
        for o in ops:
            for a in o.operands:
                users.setdefault(a, []).append(o)
        pb = {}
        for pname, pidx in params.items():
            us = users.get(pname, [])
            if us and all(u.opcode == "dynamic-slice" for u in us):
                pb[pidx] = sum(_shape_bytes(u.type_str) for u in us)
        if pb:
            fusion_param_bytes[cname] = pb
        if ops and ops[-1].opcode == "dynamic-update-slice":
            root = ops[-1]
            upd = size_of.get(root.operands[1], 0) \
                if len(root.operands) > 1 else 0
            fusion_root_dus[cname] = 2 * upd

    flops = 0.0
    hbm_bytes = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0 for k in COLLECTIVES}
    for cname, ops in comps.items():
        m = mult.get(cname, 1.0)
        in_fusion = cname in fused
        for op in ops:
            if op.opcode in ("dot", "dot-general"):
                flops += m * _dot_flops(op, shapes)
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                payload = _shape_bytes(op.type_str)
                coll[base] += m * payload * _WIRE_FACTOR[base]
                coll_counts[base] += 1
                hbm_bytes += m * payload
                continue
            if in_fusion or op.opcode in _SKIP_BYTES:
                continue
            if op.opcode == "dynamic-update-slice":
                # in-place: traffic = read+write of the updated region only
                upd = size_of.get(op.operands[1], 0) if len(op.operands) > 1 \
                    else 0
                hbm_bytes += m * 2 * upd
                continue
            if op.opcode == "dynamic-slice":
                hbm_bytes += m * 2 * _shape_bytes(op.type_str)
                continue
            if op.opcode == "fusion":
                callee = next(iter(_CALLS_RE.findall(op.rhs)), None)
                pb = fusion_param_bytes.get(callee, {})
                operand_bytes = sum(
                    pb.get(i, size_of.get(a, 0))
                    for i, a in enumerate(op.operands))
                if callee in fusion_root_dus:  # in-place DUS fusion
                    hbm_bytes += m * (fusion_root_dus[callee] + sum(
                        pb.get(i, 0) for i in range(len(op.operands))))
                else:
                    hbm_bytes += m * (_shape_bytes(op.type_str)
                                      + operand_bytes)
                continue
            operand_bytes = sum(size_of.get(a, 0) for a in op.operands)
            hbm_bytes += m * (_shape_bytes(op.type_str) + operand_bytes)
    total_coll = sum(coll.values())
    return {"flops": flops, "hbm_bytes": hbm_bytes,
            "collective_bytes": total_coll,
            "collectives": coll, "collective_counts": coll_counts}


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_total(self) -> float:  # no-overlap upper bound
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def analyze(compiled) -> "tuple[Roofline, dict]":
    res = analyze_hlo_text(compiled.as_text())
    roof = Roofline(res["flops"], res["hbm_bytes"], res["collective_bytes"])
    return roof, res


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:  # not supported on this backend
        return {}
    out = {}
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    return out
