import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build ShapeDtypeStruct stand-ins (zero allocation), lower the
appropriate entry point (train_step / prefill / serve_step) under explicit
NamedShardings, compile, and record:
  * memory_analysis()    — proves the cell fits per-device HBM
  * cost_analysis()      — FLOPs / bytes for the roofline
  * parsed collective bytes from the optimized HLO (repro.launch.hlo_analysis)

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.distributed.sharding import abstract_opt_state, make_plan
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import build_model


def _metrics_shardings(mesh, metrics_keys=("loss", "grad_norm", "lr")):
    return {k: NamedSharding(mesh, P()) for k in metrics_keys}


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               rules_override: dict | None = None, remat: bool = True,
               extra_tag: str = "", cfg_overrides: dict | None = None,
               seq_shard: bool = False):
    """Returns (lowered, meta) for one cell (not yet compiled).

    Hillclimb knobs: ``cfg_overrides`` (e.g. scan_chunk), ``seq_shard``
    (context parallelism: activations' sequence dim sharded over `model`),
    ``rules_override`` (logical-axis remapping).
    """
    import dataclasses as _dc
    cfg = get_config(arch_id)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    plan = make_plan(cfg, mesh, rules_override=rules_override)
    abs_params = model.abstract(jnp.bfloat16)
    params_sh = plan.params(model.spec)
    batch_abs = model.input_specs(shape)
    batch_sh = plan.batch(batch_abs)
    if seq_shard:  # context parallelism: tokens [B, S] -> (batch_axes, model)
        def _seq(leaf, sh):
            if leaf.ndim == 2 and leaf.shape[1] % mesh.shape["model"] == 0:
                return NamedSharding(mesh, P(*sh.spec[:1], "model"))
            return sh
        batch_sh = jax.tree.map(_seq, batch_abs, batch_sh)

    with mesh:
        if shape.kind == "train":
            opt_abs = abstract_opt_state(abs_params)
            opt_sh = plan.opt_state(model.spec)
            step = model.make_train_step()
            fn = jax.jit(step,
                         in_shardings=(params_sh, opt_sh, batch_sh),
                         out_shardings=(params_sh, opt_sh,
                                        _metrics_shardings(mesh)),
                         donate_argnums=(0, 1))
            lowered = fn.lower(abs_params, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            fn = jax.jit(model.prefill, in_shardings=(params_sh, batch_sh))
            lowered = fn.lower(abs_params, batch_abs)
        else:  # decode
            cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
            cache_sh = plan.cache(cfg, cache_abs)
            fn = jax.jit(model.serve_step,
                         in_shardings=(params_sh, cache_sh, batch_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(abs_params, cache_abs, batch_abs)
    meta = {"arch": arch_id, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind, "tag": extra_tag}
    return lowered, meta


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             rules_override: dict | None = None, verbose: bool = True,
             cfg_overrides: dict | None = None, seq_shard: bool = False,
             tag: str = "") -> dict:
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch_id, shape_name, multi_pod=multi_pod,
                                   rules_override=rules_override,
                                   cfg_overrides=cfg_overrides,
                                   seq_shard=seq_shard, extra_tag=tag)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        hlo_dir = os.environ.get("DRYRUN_SAVE_HLO")
        if hlo_dir:  # cache compiled HLO so analysis can be re-run offline
            import zstandard
            os.makedirs(hlo_dir, exist_ok=True)
            tag2 = f"{arch_id}_{shape_name}_{'multi' if multi_pod else 'single'}"
            with open(os.path.join(hlo_dir, tag2 + ".hlo.zst"), "wb") as f:
                f.write(zstandard.ZstdCompressor(level=3).compress(
                    compiled.as_text().encode()))
        roof, res = hlo_analysis.analyze(compiled)
        mem = hlo_analysis.memory_analysis_dict(compiled)
        n_chips = 512 if multi_pod else 256
        rec = {**meta, "status": "ok",
               "t_lower_s": round(t_lower, 1),
               "t_compile_s": round(t_compile, 1),
               "n_chips": n_chips,
               "roofline": roof.as_dict(),
               "collectives": res["collectives"],
               "collective_counts": res["collective_counts"],
               "memory": mem}
        if verbose:
            print(f"[dryrun] {arch_id} x {shape_name} x {rec['mesh']}: OK "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
                  f"bottleneck={roof.bottleneck})", flush=True)
            if mem:
                print(f"  memory_analysis: {mem}", flush=True)
            print(f"  cost: flops/dev={roof.flops_per_device:.3e} "
                  f"bytes/dev={roof.bytes_per_device:.3e} "
                  f"coll/dev={roof.collective_bytes_per_device:.3e}",
                  flush=True)
        return rec
    except Exception as e:  # a failure here is a bug in our sharding
        if verbose:
            traceback.print_exc()
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok" or r.get("status") == "skipped"}
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in done:
                    print(f"[dryrun] {key} cached, skipping", flush=True)
                    continue
                rec = run_cell(arch, shape, multi_pod=mp)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {args.out}", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
