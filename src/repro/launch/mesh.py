"""Production meshes (TPU v5e target).

Functions, not module-level constants — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one 256-chip v5e pod; 2x16x16 = two pods over DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_edge_mesh(n_chips: int = 4):
    """Small mesh standing in for an edge-class server slice."""
    return jax.make_mesh((1, n_chips), ("data", "model"))


# v5e hardware constants (per chip) — used by the roofline and the analytic
# cost model in repro/sim/cost_model.py
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
