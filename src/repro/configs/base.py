"""Architecture + shape configuration system (``--arch <id> --shape <name>``)."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- attention flavour ---
    attn_pattern: str = "full"  # full | local_global
    window: int = 0  # sliding window for local layers
    global_every: int = 0  # e.g. 6 -> layers 5, 11, ... are global
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0  # gemma3 global layers; 0 -> use rope_theta
    tie_embeddings: bool = False
    norm: str = "rmsnorm"  # rmsnorm | rmsnorm_zero | layernorm
    act: str = "silu_glu"  # silu_glu | gelu
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    post_norms: bool = False  # gemma3 post-block norms
    logit_softcap: float = 0.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_ff: int = 0  # per-expert hidden
    n_shared_experts: int = 0
    shared_ff: int = 0
    norm_topk: bool = False
    capacity_factor: float = 1.25
    # sharding constraint axes for the [E, C, d] dispatch/combine tensors
    # (capacity dim). None = let GSPMD choose (CPU tests / single device).
    moe_dispatch_axes: tuple | None = None
    moe_scan_chunks: int = 0  # >0: scan tokens through MoE in chunks
    xlstm_gather_qkv: bool = False  # replicate conv output before q/k/v
    # --- SSM / hybrid / xlstm ---
    block_kind: str = "attn"  # attn | mamba_hybrid | xlstm
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 0  # zamba2: shared attention block cadence
    mlstm_per_slstm: int = 0  # xlstm group layout, e.g. 7
    proj_factor: float = 2.0  # xlstm up-projection
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame embeddings (frontend stub)
    cross_attention: bool = False
    # --- tensor parallelism (set by distributed/tp.py local configs) ---
    # Mesh axis the forward pass reduces partial results over.  Empty =
    # single-device semantics (no collectives anywhere in the model).
    tp_axis: str = ""
    # Which components this *local* config holds a 1/tp shard of:
    # subset of {"heads", "kv_heads", "mlp", "experts", "expert_ff",
    # "shared_ff"}.  Drives where the model inserts all-gathers
    # (output-column-parallel wo / down projections, expert parallelism)
    # when running inside a shard_map body.  All collectives are pure
    # data movement, so sharded results are bit-identical to unsharded.
    tp_shards: Tuple[str, ...] = ()
    # --- numerics / tiling ---
    act_dtype: str = "bfloat16"  # activation dtype (norms/softmax in fp32)
    scan_chunk: int = 256  # SSD / mLSTM chunkwise block length
    decode_repeat_kv: bool = False  # legacy GQA decode (perf baseline only)
    # --- capabilities ---
    supports_long_context: bool = False  # run long_500k?
    max_seq: int = 32768  # rope table length; raised per shape when needed
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return int(self.ssm_expand * self.d_model)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: 500k context assumes sub-quadratic "
            "attention/SSM (see README.md, Design notes)"
        )
    return True, ""


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        head_dim=16 if cfg.head_dim else 0,
        max_seq=256,
    )
    if cfg.block_kind == "mamba_hybrid":
        base.update(n_layers=4, shared_attn_every=2, ssm_headdim=16, ssm_state=16)
    if cfg.block_kind == "xlstm":
        base.update(n_layers=4, mlstm_per_slstm=3 if cfg.mlstm_per_slstm else 0)
    if cfg.n_experts:
        base.update(n_experts=8, top_k=min(cfg.top_k, 4), moe_ff=32,
                    shared_ff=64 if cfg.shared_ff else 0)
    if cfg.encoder_layers:
        base.update(encoder_layers=2, encoder_seq=32)
    if cfg.attn_pattern == "local_global":
        base.update(window=32, global_every=2)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **base)
