"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, reduced, shape_applicable

ARCH_IDS = [
    "zamba2-2.7b",
    "qwen2-0.5b",
    "codeqwen1.5-7b",
    "llama3.2-3b",
    "gemma3-1b",
    "qwen2-moe-a2.7b",
    "granite-moe-1b-a400m",
    "chameleon-34b",
    "whisper-large-v3",
    "xlstm-1.3b",
]


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCH_IDS and arch_id != "qlmio":
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS + ['qlmio']}")
    mod = importlib.import_module(_module_name(arch_id))
    return mod.CONFIG


__all__ = [
    "ARCH_IDS",
    "ArchConfig",
    "SHAPES",
    "ShapeConfig",
    "get_config",
    "reduced",
    "shape_applicable",
]
