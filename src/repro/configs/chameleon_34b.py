"""chameleon-34b [vlm] — early-fusion VQ image tokens in a 65536 vocab
(frontend stub: image patches arrive pre-quantized as token ids), qk-norm.
[arXiv:2405.09818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    rope_theta=1e4,
    source="arXiv:2405.09818; unverified",
)
