"""xlstm-1.3b [ssm] — 48 blocks, mLSTM (matrix memory, chunkwise-parallel)
: sLSTM (recurrent) at 7:1, d_ff=0 (gated projections inside blocks).
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_kind="xlstm",
    mlstm_per_slstm=7,    # 6 groups of (7 mLSTM + 1 sLSTM)
    proj_factor=2.0,
    conv_width=4,
    supports_long_context=True,
    source="arXiv:2405.04517; unverified",
)
