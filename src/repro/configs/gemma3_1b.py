"""gemma3-1b [dense] — 5:1 local:global sliding attention, 262k vocab.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    attn_pattern="local_global",
    window=512,
    global_every=6,          # layers 5, 11, 17, 23 are global
    qk_norm=True,
    rope_theta=1e4,          # local layers
    rope_theta_global=1e6,   # global layers
    tie_embeddings=True,
    norm="rmsnorm_zero",
    act="gelu_glu",
    embed_scale=True,
    post_norms=True,
    supports_long_context=True,  # sliding window bounds KV for 5/6 layers
    source="hf:google/gemma-3-1b-pt; unverified",
)
