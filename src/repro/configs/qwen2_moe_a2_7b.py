"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared-expert mlp,
per-expert d_ff=1408. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,            # per assigned table (= per-expert hidden)
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    n_experts=60,
    top_k=4,
    moe_ff=1408,
    n_shared_experts=4,
    shared_ff=5632,       # 4 shared experts fused: 4*1408
    norm_topk=True,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
