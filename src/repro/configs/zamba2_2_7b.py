"""zamba2-2.7b [hybrid] — 54 Mamba2 layers + one shared GQA attention block
applied every 6 layers (weight reuse), ssm_state=64. [arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,           # shared attention block MLP hidden
    vocab=32000,
    block_kind="mamba_hybrid",
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    conv_width=4,
    shared_attn_every=6,
    rope_theta=1e4,
    supports_long_context=True,
    source="arXiv:2411.15242; hf",
)
