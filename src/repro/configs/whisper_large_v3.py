"""whisper-large-v3 [audio] — encoder-decoder; conv/mel frontend is a STUB:
input_specs() provides precomputed 1280-d frame embeddings (1500 frames).
Assigned decoder seq lens are stress shapes beyond the 448-token production
max (documented in README.md, Design notes). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    encoder_layers=32,
    encoder_seq=1500,
    cross_attention=True,
    source="arXiv:2212.04356; unverified",
)
