"""Continuum replay harness: episodes executed on live ServingEngines.

Covers the ISSUE-3 tentpole: backend parity (engine vs. cost model),
router observation of real engine queue depth, replay determinism, the
engine's virtual-clock hook, and the run_until_drained relative-deadline
regression.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.cluster import Cluster, EngineBackend, build_continuum
from repro.serving.engine import Request, ServingEngine
from repro.serving.request import ContinuumRequest
from repro.serving.router import QLMIORouter
from repro.sim.cemllm import Episode, make_servers_from_spec, run_policy
from repro.sim.miobench import generate

SPEC = [(2, 1), (1, 1)]  # 1 cloud (llama3.2-3b) + 1 gpu edge (qwen2)


@pytest.fixture(scope="module")
def world():
    bench = generate(seed=0, n_tasks=60)
    servers = make_servers_from_spec(SPEC, bench)
    handles = build_continuum(SPEC, seed=0, max_batch=2, max_seq=96)
    return bench, servers, Cluster(handles)


def _greedy(ep):
    return int(np.argmin(ep.queue_s))


def _drained(cluster):
    cluster.drain()
    cluster.reset()
    return cluster


def test_backend_parity_decisions(world):
    """A deterministic policy takes identical decisions under the
    cost-model backend and the engine backend (dispatch-time observations
    match), while the engine backend's finalized records hold measured
    latencies from real token generation."""
    bench, servers, cluster = world
    _drained(cluster)
    tasks = np.arange(12)
    ep1 = Episode(bench, servers, tasks, np.random.default_rng(0))
    recs1 = [ep1.step(_greedy(ep1)) for _ in range(len(tasks))]
    ep1.finalize()

    be = EngineBackend(cluster, bench, servers, arrival_dt=0.02)
    ep2 = Episode(bench, servers, tasks, np.random.default_rng(0),
                  backend=be)
    recs2 = [ep2.step(_greedy(ep2)) for _ in range(len(tasks))]
    assert all(r["pending"] for r in recs2)  # unresolved until finalize
    ep2.finalize()

    assert [r["server"] for r in recs1] == [r["server"] for r in recs2]
    np.testing.assert_allclose(ep1.queue_s, ep2.queue_s)
    assert not any(r["pending"] for r in recs2)
    for r in recs2:
        assert r["latency_total"] > 0 and "ttft_s" in r
        assert r["ttft_s"] <= r["latency_total"] + 1e-9
    # the engines really generated tokens for every dispatched task
    n_tok = sum(len(req.output) for h in cluster.handles
                for req in h.engine.finished)
    assert n_tok >= 2 * len(tasks)


def test_router_sees_real_queue_depth(world):
    """Loading one engine with queued work must surface in its ``load``
    probe and penalize it in the router's ``_effective_latency``."""
    bench, servers, cluster = world
    _drained(cluster)
    h = cluster.handles[0]
    for i in range(4):
        cluster.submit(ContinuumRequest(
            tokens=np.arange(1, 9) % h.cfg.vocab, max_new_tokens=4,
            task=i, server=0))
    ld = h.load()
    assert ld["queue_depth"] == 4
    assert ld["inflight_prefill_tokens"] == 4 * 8
    assert ld["backlog_s"] > 0

    router = QLMIORouter(list(cluster.handles), lambda t, s: 1.0,
                         lambda t, s: 0.9)
    assert router.observed_load()[0] == pytest.approx(ld["backlog_s"])
    t_eff = router._effective_latency(0)
    assert t_eff[0] > t_eff[1]  # loaded engine penalized, idle one not
    assert router.route(0) == 1
    _drained(cluster)


def test_replay_determinism(world):
    """Same seed, same trace, same policy => bit-identical measured
    records across replays (virtual clock, no wall time anywhere)."""
    bench, servers, cluster = world
    tasks = np.arange(20, 32)
    outs = []
    for _ in range(2):
        _drained(cluster)
        be = EngineBackend(cluster, bench, servers, arrival_dt=0.01)
        res = run_policy(_greedy, bench, servers, tasks,
                         np.random.default_rng(1), backend=be)
        outs.append((res, cluster.collect()))
    assert outs[0] == outs[1]


def test_qlmio_beats_all_cloud_on_engines(world):
    """Offloading over live engines: spreading by predicted latency+queue
    beats sending everything to the single saturated cloud engine."""
    bench, servers, cluster = world
    tasks = np.arange(40, 56)

    def run(policy):
        _drained(cluster)
        be = EngineBackend(cluster, bench, servers, arrival_dt=0.005)
        return run_policy(policy, bench, servers, tasks,
                          np.random.default_rng(1), backend=be)

    cloud = int(np.argmax(servers.is_cloud))
    all_cloud = run(lambda ep: cloud)
    spread = run(_greedy)
    assert spread["avg_latency_s"] < all_cloud["avg_latency_s"]


def test_failed_server_times_out_and_cluster_stays_reusable(world):
    """Failure injection: a dead server's requests never complete — they
    must surface as timeouts and drain() must still leave the cluster
    reset()-able for the next replay (regression: leftover queued work on
    the failed handle made reset() raise)."""
    bench, servers, cluster = world
    _drained(cluster)
    h = cluster.handles[1]
    h.fail = True
    try:
        cluster.submit(ContinuumRequest(
            tokens=np.arange(1, 9) % h.cfg.vocab, max_new_tokens=4,
            task=0, server=1))
        cluster.drain()
        rec, = cluster.collect()
        assert rec["timeout"] and not rec["success"]
        cluster.reset()  # raised RuntimeError pre-fix
    finally:
        h.fail = False


def test_engine_virtual_clock_and_relative_drain_deadline():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    clock = {"t": 0.0}
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        clock=lambda: clock["t"])
    rng = np.random.default_rng(0)
    req = Request(0, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                  max_new_tokens=4)
    eng.submit(req)
    while not req.done:
        eng.step()
        clock["t"] += 0.5  # half a virtual second per tick
    # latency_stats reports virtual-clock seconds, not host wall time
    stats = eng.latency_stats()
    assert stats["e2e_p50_s"] == pytest.approx(req.e2e_s())
    assert req.e2e_s() >= 1.0  # 4 tokens at 0.5 virtual s per tick
    # prefill completion and the decode step share a tick, so the first
    # inter-token gap may be 0; later gaps are exactly one virtual tick
    assert req.itl_s()[-1] == pytest.approx(0.5)
    assert sum(req.itl_s()) == pytest.approx(req.e2e_s())

    # regression: run_until_drained's tick guard must be relative to the
    # ticks already accumulated by external stepping, not the global count
    for i in range(2):
        r = Request(1 + i, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=16)
        eng.submit(r)
        while not r.done:
            eng.step()
            clock["t"] += 0.5
    assert eng.ticks > 12
    eng.finished.clear()  # only the late request matters below
    late = Request(9, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                   max_new_tokens=4)
    eng.submit(late)
    done = eng.run_until_drained(max_ticks=12)  # raised pre-fix
    assert [r.uid for r in done] == [9]
