"""End-to-end system tests: the training driver (resume included), the
synthetic data pipeline, and a real dry-run cell in a subprocess (512
placeholder devices must not leak into this test process)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_synthetic_lm_host_sharding():
    from repro.data.lm_data import LMDataConfig, SyntheticLM
    full = SyntheticLM(LMDataConfig(1000, 16, 8, seed=3)).batch(0)
    parts = [SyntheticLM(LMDataConfig(1000, 16, 8, seed=3, host_id=h,
                                      host_count=4)).batch(0)
             for h in range(4)]
    assert all(p["tokens"].shape == (2, 16) for p in parts)
    assert full["tokens"].shape == (8, 16)
    # same-step batches are deterministic per host
    again = SyntheticLM(LMDataConfig(1000, 16, 8, seed=3, host_id=1,
                                     host_count=4)).batch(0)
    np.testing.assert_array_equal(parts[1]["tokens"], again["tokens"])


def test_train_driver_losses_finite_and_resume(tmp_path):
    from repro.launch.train import train
    ck = str(tmp_path / "ck")
    _, losses = train("qwen2-0.5b", steps=6, batch=2, seq=32, ckpt_dir=ck,
                      ckpt_every=3, log_every=100)
    assert len(losses) == 6 and all(np.isfinite(losses))
    _, losses2 = train("qwen2-0.5b", steps=8, batch=2, seq=32, ckpt_dir=ck,
                       ckpt_every=3, log_every=100)
    assert len(losses2) == 2  # resumed from step 6


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real (arch x shape x mesh) cell: lower + compile on the 16x16
    production mesh with 512 host devices, in a clean subprocess."""
    code = (
        "import sys; sys.path.insert(0, %r)\n"
        "from repro.launch.dryrun import run_cell\n"
        "import json\n"
        "rec = run_cell('qwen2-0.5b', 'decode_32k', multi_pod=False,"
        " verbose=False)\n"
        "print('RESULT ' + json.dumps(rec['status']))\n" % SRC
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560)
    assert "RESULT \"ok\"" in out.stdout, out.stdout + out.stderr


def test_dryrun_results_complete():
    """The committed dry-run sweep must cover all 40 cells x 2 meshes with
    zero errors (long_500k skips are the documented full-attention ones)."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("run `python -m repro.launch.dryrun --all --mesh both`")
    recs = json.load(open(path))
    from repro.configs import ARCH_IDS, SHAPES
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    assert len(seen) == len(ARCH_IDS) * len(SHAPES) * 2
    errors = [r for r in recs if r["status"] == "error"]
    assert not errors, errors
    skips = {r["arch"] for r in recs if r["status"] == "skipped"}
    assert skips <= {"qwen2-0.5b", "codeqwen1.5-7b", "llama3.2-3b",
                     "qwen2-moe-a2.7b", "granite-moe-1b-a400m",
                     "chameleon-34b", "whisper-large-v3"}
