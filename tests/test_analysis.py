"""HLO analyzer (trip-count-aware) and FLOP-accounting units."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.hlo_analysis import analyze_hlo_text
from repro.models.counting import active_matmul_params, model_flops


def test_analyzer_counts_scan_trip_counts():
    def f(a, b):
        def step(c, _):
            return jnp.tanh(c @ b), None
        c, _ = jax.lax.scan(step, a, None, length=10)
        return c

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(f).lower(a, a).compile().as_text()
    res = analyze_hlo_text(txt)
    assert res["flops"] == pytest.approx(10 * 2 * 256 ** 3, rel=0.01)


def test_analyzer_collectives_empty_on_single_device():
    f = jax.jit(lambda a: a @ a)
    txt = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)) \
        .compile().as_text()
    res = analyze_hlo_text(txt)
    assert res["collective_bytes"] == 0
    assert res["flops"] == pytest.approx(2 * 64 ** 3, rel=0.01)


def test_moe_active_params_below_total():
    cfg = get_config("qwen2-moe-a2.7b")
    active = active_matmul_params(cfg)
    # all-expert param count (approx): routed experts full
    full = active + 3 * cfg.d_model * cfg.moe_ff * \
        (cfg.n_experts - cfg.top_k) * cfg.n_layers
    assert active < full
    # a2.7b: ~2-4B active matmul params (incl. big-vocab head)
    assert 1.5e9 < active < 5e9


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_model_flops_positive_and_ordered(arch_id):
    cfg = get_config(arch_id)
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_prefill = model_flops(cfg, SHAPES["prefill_32k"])
    f_decode = model_flops(cfg, SHAPES["decode_32k"])
    assert f_train > 0 and f_prefill > 0 and f_decode > 0
    assert f_decode < f_prefill  # 128 tokens vs 1M tokens
    # train does fwd+bwd on 1M tokens vs prefill fwd on 1M tokens
    assert f_train > f_prefill / 2
