"""Serving engine (continuous batching) + router (health/hedging/elastic)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import HealthTracker, QLMIORouter, ServerHandle


@pytest.fixture(scope="module")
def engine():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServingEngine(model, params, max_batch=2, max_seq=64), cfg


def test_engine_batched_generation(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=5) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 4
    for r in done:
        assert len(r.output) == 5
        assert all(0 <= t < cfg.vocab for t in r.output)


def test_engine_continuous_batching_frees_slots(engine):
    eng, cfg = engine
    rng = np.random.default_rng(1)
    short = Request(10, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=2)
    long_ = Request(11, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=10)
    queued = Request(12, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                     max_new_tokens=2)
    eng.submit(short)
    eng.submit(long_)
    eng.submit(queued)  # must start as soon as `short` finishes
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {10, 11, 12}
    assert len(long_.output) == 10 and len(queued.output) == 2


def test_engine_determinism(engine):
    eng, cfg = engine
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab
    outs = []
    for _ in range(2):
        r = Request(0, prompt, max_new_tokens=4)
        eng.submit(r)
        eng.run_until_drained()
        outs.append(tuple(r.output))
    assert outs[0] == outs[1]


# -------------------------------------------------------------------- router


def _mk_server(name, lat, ok=True, fail=False):
    def ex(task):
        if fail:
            return 120.0, False
        return lat, ok

    return ServerHandle(name, 0, 0, False, ex)


def test_health_tracker_marks_dead():
    h = HealthTracker(2, fail_threshold=2, cooldown=100.0)
    h.record(0, 120.0, False, now=0.0)
    h.record(0, 120.0, False, now=1.0)
    assert not h.healthy(2.0)[0]
    assert h.healthy(2.0)[1]
    assert h.healthy(200.0)[0]  # cooldown expired


def test_router_drains_failed_server():
    servers = [_mk_server("bad", 1.0, fail=True), _mk_server("ok", 2.0)]
    router = QLMIORouter(servers, lambda t, s: [1.0, 2.0][s],
                         lambda t, s: 0.9)
    hits_bad = 0
    for t in range(12):
        rec = router.dispatch(t)
        hits_bad += rec["server"] == 0
    assert hits_bad <= router.health.fail_threshold + 1


def test_router_hedges_stragglers():
    # server 0 predicted fast but actually 10x slower -> hedge to server 1
    servers = [_mk_server("slow", 50.0), _mk_server("backup", 1.0)]
    router = QLMIORouter(servers, lambda t, s: [0.5, 5.0][s],
                         lambda t, s: 0.9, hedge_factor=2.0)
    rec = router.dispatch(0)
    assert rec["hedged"] and rec["server"] == 1


def test_router_queue_drains_with_time():
    """Regression: queue_s must shrink as wall-clock advances, not grow
    without bound (long runs used to predict every server saturated)."""
    servers = [_mk_server("a", 0.05), _mk_server("b", 0.05)]
    router = QLMIORouter(servers, lambda t, s: 0.05, lambda t, s: 0.9)
    for t in range(200):
        router.dispatch(t)
    # 0.05 s of work per dispatch vs 0.1 s elapsed: queues stay ~empty
    assert router.queue_s.max() <= 0.1
    # and the predicted total latency stays close to the true latency
    rec = router.dispatch(999)
    assert rec["latency"] < 1.0


def test_router_prefers_server_holding_prefix():
    """Prefix-cache affinity: with identical raw latency estimates, the
    server expected to hold the conversation's KV prefix wins."""
    servers = [_mk_server("cold", 6.0), _mk_server("warm", 6.0)]
    router = QLMIORouter(
        servers, lambda t, s: 6.0, lambda t, s: 0.9,
        prefix_hit_pred=lambda t, s: 0.9 if s == 1 else 0.0,
        prefill_pred=lambda t, s: 5.0)
    assert router.route(0) == 1
    # without the predictor the tie breaks to the first server
    base = QLMIORouter(servers, lambda t, s: 6.0, lambda t, s: 0.9)
    assert base.route(0) == 0


def test_router_all_unhealthy_falls_back_to_soonest_recovering():
    """Regression: with every server in cooldown the -inf scores made
    np.argmax silently dispatch to server 0; the router must pick the
    soonest-recovering server instead."""
    servers = [_mk_server("a", 1.0), _mk_server("b", 1.0)]
    router = QLMIORouter(servers, lambda t, s: 1.0, lambda t, s: 0.9)
    router.health.dead_until[:] = [500.0, 100.0]  # both in cooldown
    router.now = 0.0
    assert router.route(0) == 1  # b recovers first, not argmax's server 0
    router.health.dead_until[:] = [80.0, 300.0]
    assert router.route(0) == 0


def test_router_hedge_charges_losing_server():
    """Regression: hedged dispatch never charged the losing server's work
    to its queue_s — both servers executed the task, so both backlogs
    must grow."""
    # hedge wins: the original (slow) server still did 50 s of work
    servers = [_mk_server("slow", 50.0), _mk_server("backup", 1.0)]
    router = QLMIORouter(servers, lambda t, s: [0.5, 5.0][s],
                         lambda t, s: 0.9, hedge_factor=2.0)
    rec = router.dispatch(0)
    assert rec["hedged"] and rec["server"] == 1
    assert router.queue_s[0] >= 50.0  # loser charged
    assert router.queue_s[1] >= 1.0  # winner charged as before
    # hedge loses: the backup still did its work
    servers = [_mk_server("jittery", 30.0), _mk_server("busy", 45.0)]
    router = QLMIORouter(servers, lambda t, s: [0.5, 5.0][s],
                         lambda t, s: 0.9, hedge_factor=2.0)
    rec = router.dispatch(0)
    assert not rec["hedged"] and rec["server"] == 0
    assert router.queue_s[0] >= 30.0
    assert router.queue_s[1] >= 45.0  # losing hedge charged


def test_router_elastic_scaling():
    servers = [_mk_server("a", 5.0)]
    router = QLMIORouter(servers, lambda t, s: 5.0, lambda t, s: 0.9)
    router.dispatch(0)
    router.add_server(_mk_server("b", 0.5))
    assert len(router.queue_s) == 2
    # new fast empty server should win
    rec = router.dispatch(1)
    assert rec["server"] == 1
    router.remove_server(0)
    assert len(router.servers) == 1 and len(router.queue_s) == 1
