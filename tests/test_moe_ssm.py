"""MoE dispatch and SSM/xLSTM chunkwise-vs-sequential equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import moe as M
from repro.models import mamba2 as m2
from repro.models import xlstm as xl
from repro.nn.spec import init_params

RNG = np.random.default_rng(3)


def _moe_params(d=16, E=8, ff=32, shared=0):
    spec = M.moe_spec(1, d, E, ff, shared)
    p = init_params(spec, jax.random.PRNGKey(0), jnp.float32)
    return jax.tree.map(lambda a: a[0], p)


def test_moe_matches_dense_reference():
    p = _moe_params(shared=24)
    x = jnp.asarray(RNG.normal(size=(66, 16)), jnp.float32)
    y1 = M.moe_apply(p, x, top_k=4, norm_topk=True, capacity_factor=100.0)
    y2 = M.moe_reference(p, x, top_k=4, norm_topk=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


def test_moe_capacity_drop_bounded():
    """With cf=1.0 the output differs from the no-drop oracle only on
    dropped tokens, never on kept ones — and stays finite."""
    p = _moe_params()
    x = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)
    y = M.moe_apply(p, x, top_k=2, norm_topk=False, capacity_factor=1.0)
    assert np.isfinite(np.asarray(y)).all()


@settings(max_examples=15, deadline=None)
@given(T=st.integers(8, 80), top_k=st.integers(1, 4))
def test_moe_gate_weights_sum(T, top_k):
    """Property: with norm_topk, combined gates sum to 1 per kept token;
    outputs are bounded by the max expert output magnitude."""
    p = _moe_params()
    rng = np.random.default_rng(T * 10 + top_k)
    x = jnp.asarray(rng.normal(size=(T, 16)), jnp.float32)
    y1 = M.moe_apply(p, x, top_k=top_k, norm_topk=True, capacity_factor=50.0)
    y2 = M.moe_reference(p, x, top_k=top_k, norm_topk=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------- mamba2


@pytest.mark.parametrize("chunk", [16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    b, S, h, p, n = 2, 64, 2, 8, 4
    x = jnp.asarray(RNG.normal(size=(b, S, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, S, h)), jnp.float32)
    a_neg = -jnp.asarray(RNG.uniform(0.1, 1.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, S, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, S, n)), jnp.float32)
    y1, s1 = m2.ssd_chunked(x, dt, a_neg, B, C, chunk=chunk)
    y2, s2 = m2.ssd_reference(x, dt, a_neg, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-4, rtol=1e-4)


def test_ssd_decode_continues_prefill():
    """State from chunked prefill + decode steps == longer sequential run."""
    b, S, h, p, n = 1, 32, 2, 8, 4
    x = jnp.asarray(RNG.normal(size=(b, S + 4, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, S + 4, h)), jnp.float32)
    a_neg = -jnp.asarray(RNG.uniform(0.1, 1.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, S + 4, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, S + 4, n)), jnp.float32)
    _, state = m2.ssd_chunked(x[:, :S], dt[:, :S], a_neg, B[:, :S], C[:, :S],
                              chunk=16)
    ys = []
    for t in range(S, S + 4):
        y, state = m2.ssd_decode_step(state, x[:, t], dt[:, t], a_neg,
                                      B[:, t], C[:, t])
        ys.append(y)
    y_ref, _ = m2.ssd_reference(x, dt, a_neg, B, C)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref[:, S:]), atol=1e-4, rtol=1e-4)


def test_causal_conv_step_matches_full():
    B, S, Ch, W = 2, 20, 6, 4
    x = jnp.asarray(RNG.normal(size=(B, S, Ch)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(W, Ch)), jnp.float32)
    bias = jnp.asarray(RNG.normal(size=(Ch,)), jnp.float32)
    full = m2.causal_conv(x, w, bias)
    state = jnp.zeros((B, W - 1, Ch))
    outs = []
    for t in range(S):
        y, state = m2.causal_conv_step(state, x[:, t], w, bias)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------ xLSTM


@pytest.mark.parametrize("chunk", [8, 32])
def test_mlstm_chunkwise_matches_sequential(chunk):
    b, S, h, dk = 2, 64, 2, 8
    q = jnp.asarray(RNG.normal(size=(b, S, h, dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, S, h, dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, S, h, dk)), jnp.float32)
    ilog = jnp.asarray(RNG.normal(size=(b, S, h)), jnp.float32)
    flog = jnp.asarray(-np.abs(RNG.normal(size=(b, S, h))), jnp.float32)
    h1, s1 = xl.mlstm_chunkwise(q, k, v, ilog, flog, chunk=chunk)
    h2, s2 = xl.mlstm_reference(q, k, v, ilog, flog)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-4, rtol=2e-4)
    for a, b_ in zip(s1, s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-4, rtol=2e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.sampled_from([16, 32, 48]), chunk=st.sampled_from([8, 16]))
def test_mlstm_stability_extreme_gates(S, chunk):
    """Property: max-stabilization keeps everything finite under extreme
    gate pre-activations."""
    rng = np.random.default_rng(S + chunk)
    b, h, dk = 1, 2, 8
    q = jnp.asarray(rng.normal(size=(b, S, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, S, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, S, h, dk)), jnp.float32)
    ilog = jnp.asarray(rng.normal(size=(b, S, h)) * 20, jnp.float32)
    flog = jnp.asarray(-np.abs(rng.normal(size=(b, S, h))) * 20, jnp.float32)
    h1, _ = xl.mlstm_chunkwise(q, k, v, ilog, flog, chunk=chunk)
    assert np.isfinite(np.asarray(h1)).all()
