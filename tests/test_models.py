"""Per-arch smoke tests (reduced configs, CPU) + decode/prefill consistency.

Smoke: one forward/train step per assigned architecture, asserting output
shapes and finiteness.  Consistency: serve_step(token S) must match a full
prefill over S+1 tokens (fp32 activations — validates all cache plumbing).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import build_model


def _batch(cfg, B, S, rng, with_labels=True):
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if with_labels:
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)
    if cfg.cross_attention:
        b["encoder_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = reduced(get_config(arch_id))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, 2, 64, rng)
    opt = m.init_opt(params)
    step = jax.jit(m.make_train_step())
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually move
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_prefill_decode(arch_id):
    cfg = reduced(get_config(arch_id))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1), jnp.float32)
    rng = np.random.default_rng(1)
    B, S = 2, 32
    batch = _batch(cfg, B, S, rng, with_labels=False)
    logits, cache = jax.jit(m.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache2 = jax.jit(m.serve_step)(
        params, cache, {"tokens": jnp.zeros((B,), jnp.int32),
                        "pos": jnp.full((B,), S - 1, jnp.int32)})
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache structure round-trips
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_prefill(arch_id):
    """fp32: one-step decode == prefill over S+1 tokens (cache correctness)."""
    cfg = dataclasses.replace(reduced(get_config(arch_id)),
                              act_dtype="float32", capacity_factor=16.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2), jnp.float32)
    rng = np.random.default_rng(2)
    B, S = 2, 33
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    extra = {}
    if cfg.cross_attention:
        extra["encoder_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    full_logits, _ = jax.jit(m.prefill)(params, {"tokens": toks, **extra})
    _, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :S], **extra})
    cache = dict(cache)
    for kk in ("k", "v"):
        if kk in cache:
            pad = [(0, 0)] * cache[kk].ndim
            pad[-3] = (0, 1)
            cache[kk] = jnp.pad(cache[kk], pad)
    if "pos_map" in cache:
        cache["pos_map"] = jnp.pad(cache["pos_map"], ((0, 0), (0, 1)),
                                   constant_values=-1)
    step_logits, _ = jax.jit(m.serve_step)(
        params, cache, {"tokens": toks[:, S],
                        "pos": jnp.full((B,), S, jnp.int32)})
    a = np.asarray(full_logits, np.float32)
    b = np.asarray(step_logits, np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-4, f"{arch_id}: decode/prefill mismatch {err:.3e}"


def test_gemma3_local_global_pattern():
    """Sliding-window layers must not attend beyond the window."""
    cfg = reduced(get_config("gemma3-1b"))
    assert cfg.attn_pattern == "local_global" and cfg.window
    from repro.models.lm import static_layer_windows
    flags = static_layer_windows(cfg)
    assert sum(flags) == cfg.n_layers // cfg.global_every
