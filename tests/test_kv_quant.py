"""Int8-quantized KV cache: quantizer bounds, fused-dequant kernel parity
vs the jnp oracles, engine-level greedy-token agreement vs bf16, scale
bookkeeping under CoW / eviction / prefix hits, byte-budget admission
accounting, and the decode-loop overhead satellites (cache buffer
donation, on-device argmax)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ops, ref
from repro.kernels.quant import dequantize_kv, quantize_kv
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import kv_token_bytes
from repro.sim import cost_model as cm


def _rng(seed=0):
    return np.random.default_rng(seed)


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _serve(model, params, prompts, *, max_new_tokens=5, **kw):
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, **kw)
    reqs = [Request(i, p, max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, [tuple(r.output) for r in reqs]


# -------------------------------------------------------------- quantizer


def test_quantize_roundtrip_bound():
    x = jnp.asarray(_rng(1).normal(size=(3, 5, 4, 32)) * 7.0, jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == x.shape[:-1]
    back = dequantize_kv(q, s)
    # symmetric rounding: error <= scale/2 = absmax/254 per row
    bound = jnp.max(jnp.abs(x), -1, keepdims=True) / 254.0 + 1e-6
    assert bool(jnp.all(jnp.abs(back - x) <= bound))


def test_quantize_zero_rows_safe():
    x = jnp.zeros((2, 4, 16))
    q, s = quantize_kv(x)
    assert bool(jnp.all(q == 0)) and bool(jnp.all(s == 1.0))
    assert bool(jnp.all(dequantize_kv(q, s) == 0.0))


# -------------------------------------------------- fused-dequant kernels


@pytest.mark.parametrize("B,S,H,Hkv,D,bs,window", [
    (2, 96, 8, 2, 64, 16, 0),
    (1, 64, 4, 4, 32, 8, 24),
])
def test_paged_decode_quant_kernel_parity(B, S, H, Hkv, D, bs, window):
    rng = _rng(7)
    NB = S // bs
    P = 1 + B * NB
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    bt = jnp.asarray(np.arange(1, 1 + B * NB).reshape(B, NB), jnp.int32)
    pos = jnp.asarray(rng.integers(S // 2, S, B), jnp.int32)
    out = ops.paged_decode_quant(q, k8, v8, ks, vs, bt, pos, window=window)
    want = ref.paged_decode_quant_ref(q, k8, v8, ks, vs, bt, pos,
                                      window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-3, rtol=5e-3)
    # and the dequant noise vs the full-precision pool stays int8-sized
    full = ref.paged_decode_ref(q, k, v, bt, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(full, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_paged_decode_quant_masks_unallocated():
    """-1 table entries (clamped to the null page) must not leak the null
    page's garbage values or scales into the output."""
    rng = _rng(3)
    B, H, Hkv, D, bs = 1, 4, 2, 32, 8
    P = 4
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    # poison the null page with huge scales
    ks = ks.at[0].set(1e6)
    vs = vs.at[0].set(1e6)
    bt = jnp.asarray([[1, -1, -1]], jnp.int32)
    pos = jnp.asarray([bs - 1], jnp.int32)
    out = ops.paged_decode_quant(q, k8, v8, ks, vs, bt, pos)
    want = ref.paged_decode_quant_ref(q, k8, v8, ks, vs, bt, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-3, rtol=5e-3)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


@pytest.mark.parametrize("B,S,H,Hkv,D,window", [
    (2, 96, 8, 2, 64, 0),
    (1, 70, 8, 1, 64, 0),  # padding path: scales padded alongside K/V
    (2, 128, 4, 4, 32, 24),
])
def test_flash_decode_quant_kernel_parity(B, S, H, Hkv, D, window):
    rng = _rng(11)
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.bfloat16)
    kc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    k8, ks = quantize_kv(kc)
    v8, vs = quantize_kv(vc)
    cpos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = jnp.asarray(rng.integers(S // 2, S, B), jnp.int32)
    out = ops.flash_decode_quant(q, k8, v8, ks, vs, cpos, pos,
                                 window=window, block_k=32)
    want = ref.flash_decode_quant_ref(q, k8, v8, ks, vs, cpos, pos,
                                      window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-3, rtol=5e-3)


# ------------------------------------------------------- engine: int8 path


def test_abstract_paged_cache_int8_layout(qwen):
    cfg, model, _ = qwen
    abstract = model.abstract_paged_cache(8, 4, kv_dtype="int8")
    assert abstract["k_pages"].dtype == jnp.int8
    assert abstract["v_pages"].dtype == jnp.int8
    shape = (cfg.n_layers, 8, 4, cfg.n_kv_heads)
    assert abstract["k_scales"].shape == shape
    assert abstract["k_scales"].dtype == jnp.float32
    with pytest.raises(ValueError):
        model.abstract_paged_cache(8, 4, kv_dtype="fp4")


def test_engine_int8_greedy_agreement(qwen):
    """Short greedy traces must agree between the int8 and bf16 engines:
    int8 rounding perturbs logits well below the argmax gaps of this
    pinned workload (chunked + monolithic prefill paths both)."""
    cfg, model, params = qwen
    rng = _rng(3)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (7, 19, 33, 12)]
    _, out_bf = _serve(model, params, prompts)
    _, out_i8 = _serve(model, params, prompts, kv_dtype="int8")
    assert out_i8 == out_bf
    _, out_i8_mono = _serve(model, params, prompts, kv_dtype="int8",
                            prefill_chunk=0)
    assert out_i8_mono == out_bf


def test_engine_int8_halves_cache_bytes(qwen):
    cfg, model, params = qwen
    e_bf = ServingEngine(model, params, max_batch=2, max_seq=64)
    e_i8 = ServingEngine(model, params, max_batch=2, max_seq=64,
                         kv_dtype="int8")
    want = (kv_token_bytes(cfg.n_layers, cfg.n_kv_heads, cfg.hd, "bf16")
            / kv_token_bytes(cfg.n_layers, cfg.n_kv_heads, cfg.hd, "int8"))
    assert e_bf.kv_cache_bytes() / e_i8.kv_cache_bytes() == \
        pytest.approx(want)
    assert e_i8.stats()["kv_dtype"] == "int8"


def test_int8_needs_paged_backend(qwen):
    _, model, params = qwen
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(model, params, paged=False, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        ServingEngine(model, params, kv_dtype="fp8")


def test_int8_prefix_hit_token_identical(qwen):
    """A warm prefix-cache hit must reproduce the cold run exactly: the
    chunked path reads every cache row back dequantized (write-then-
    quantize), so hit pages hold bit-identical values to a cold scatter."""
    cfg, model, params = qwen
    prompt = _rng(5).integers(0, cfg.vocab, 33).astype(np.int32)
    eng, (cold,) = _serve(model, params, [prompt], kv_dtype="int8")
    warm = Request(99, prompt, max_new_tokens=5)
    eng.submit(warm)
    eng.run_until_drained()
    assert tuple(warm.output) == cold
    assert eng.pool.hits > 0


def test_int8_cow_carries_scales(qwen):
    """A fully-cached prompt re-admission copies its final page (copy-on-
    write) — values *and* scale rows must move together or the recomputed
    last token dequantizes garbage."""
    cfg, model, params = qwen
    prompt = _rng(9).integers(0, cfg.vocab, 16).astype(np.int32)
    eng, (cold,) = _serve(model, params, [prompt], kv_dtype="int8",
                          prefill_chunk=0, bucket_prompts=False,
                          page_size=8)
    assert eng.pool.cow_copies == 0
    warm = Request(99, prompt, max_new_tokens=5)
    eng.submit(warm)
    eng.run_until_drained()
    assert eng.pool.cow_copies >= 1  # unaligned reuse split a shared page
    assert tuple(warm.output) == cold


def test_int8_eviction_then_recompute(qwen):
    """After the LRU evicts a parked prefix, resubmitting its prompt must
    recompute cleanly into recycled pages (stale scales overwritten)."""
    cfg, model, params = qwen
    rng = _rng(13)
    prompt = rng.integers(0, cfg.vocab, 17).astype(np.int32)
    eng = ServingEngine(model, params, max_batch=1, max_seq=32,
                        kv_dtype="int8", page_size=8, num_pages=9)
    first = Request(0, prompt, max_new_tokens=4)
    eng.submit(first)
    eng.run_until_drained()
    # churn the pool with distinct prompts until the original is evicted
    for i in range(1, 5):
        eng.submit(Request(i, rng.integers(0, cfg.vocab, 17)
                           .astype(np.int32), max_new_tokens=4))
    eng.run_until_drained()
    assert eng.pool.evictions > 0
    again = Request(50, prompt, max_new_tokens=4)
    eng.submit(again)
    eng.run_until_drained()
    assert tuple(again.output) == tuple(first.output)


def test_kv_budget_doubles_page_count(qwen):
    """The admission-control dividend: a fixed device byte budget buys
    ~2x the pages under int8 (2*Dh/(Dh+4) exactly)."""
    cfg, model, params = qwen
    budget = 1 << 20
    e_bf = ServingEngine(model, params, max_seq=64, kv_budget_bytes=budget)
    e_i8 = ServingEngine(model, params, max_seq=64, kv_budget_bytes=budget,
                         kv_dtype="int8")
    assert e_bf.pool.num_pages == max(2, 1 + budget // e_bf.page_bytes())
    assert e_i8.pool.num_pages == max(2, 1 + budget // e_i8.page_bytes())
    want = e_bf.page_bytes() / e_i8.page_bytes()
    assert e_i8.pool.num_pages / e_bf.pool.num_pages == \
        pytest.approx(want, rel=0.05)
    assert want > 1.4  # reduced head dim; 1.94x at Dh=128


# --------------------------------------- decode-loop overhead satellites


def _donation_supported():
    probe = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.zeros((8,), jnp.float32)
    probe(x)
    return x.is_deleted()


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_decode_step_donates_cache(qwen, kv_dtype):
    """The per-tick jitted decode step must not pay a full KV-cache copy:
    the cache pytree is donated, so the pre-tick buffers are consumed
    (live-buffer check) and the step stays a single XLA trace."""
    if not _donation_supported():
        pytest.skip("backend does not support buffer donation")
    cfg, model, params = qwen
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        kv_dtype=kv_dtype)
    eng.submit(Request(0, _rng(2).integers(0, cfg.vocab, 9)
                       .astype(np.int32), max_new_tokens=6))
    while not any(s is not None for s in eng.slots):
        eng.step()  # finish prefill; decode starts next tick
    before = dict(eng.cache)
    eng.step()
    deleted = {name: leaf.is_deleted() for name, leaf in before.items()}
    assert all(deleted.values()), f"copied (not donated): {deleted}"
    assert eng.jit_cache_sizes().get("_step") == 1
    eng.run_until_drained()


def test_chunked_prefill_donates_cache(qwen):
    if not _donation_supported():
        pytest.skip("backend does not support buffer donation")
    cfg, model, params = qwen
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        prefill_chunk=16, prefill_budget=16)
    eng.submit(Request(0, _rng(4).integers(0, cfg.vocab, 40)
                       .astype(np.int32), max_new_tokens=2))
    before = dict(eng.cache)
    eng.step()  # first prefill chunk runs inside this tick
    assert any(t is not None for t in eng.prefill_tasks)
    assert all(leaf.is_deleted() for leaf in before.values())
    eng.run_until_drained()


def test_on_device_argmax_matches_logits_path(qwen):
    """Default decode returns [B] token ids argmaxed on device; the
    return_logits escape hatch must produce identical tokens (and expose
    the full [B, vocab] logits to the host)."""
    cfg, model, params = qwen
    rng = _rng(6)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 23)]
    _, out_ids = _serve(model, params, prompts)
    _, out_logits = _serve(model, params, prompts, return_logits=True)
    assert out_ids == out_logits


def test_step_returns_token_ids_shape(qwen):
    """The decode-step transfer is [B] int32, not [B, vocab] floats."""
    cfg, model, params = qwen
    eng = ServingEngine(model, params, max_batch=2, max_seq=64)
    eng.submit(Request(0, _rng(8).integers(0, cfg.vocab, 5)
                       .astype(np.int32), max_new_tokens=4))
    while not any(s is not None for s in eng.slots):
        eng.step()
    out, cache = eng._step(eng.params, eng.cache,
                           _rebuild_batch(eng))
    eng.cache = cache
    assert out.shape == (eng.max_batch,) and out.dtype == jnp.int32
    eng.run_until_drained()


def _rebuild_batch(eng):
    """Minimal decode batch for the active slots (mirrors engine.step)."""
    tokens = np.zeros(eng.max_batch, np.int32)
    pos = np.zeros(eng.max_batch, np.int64)
    tables = np.full_like(eng.tables, -1)
    for i, r in enumerate(eng.slots):
        if r is not None:
            tokens[i] = r.output[-1]
            pos[i] = eng.pos[i]
            tables[i] = eng.tables[i]
    return {"tokens": jnp.asarray(tokens),
            "pos": jnp.asarray(pos, jnp.int32),
            "block_tables": jnp.asarray(tables)}


# ------------------------------------------------- cost model: bytes chain


def test_cost_model_kv_bytes_chain():
    """bytes/token -> decode_s -> concurrency: int8 roughly halves the
    per-token KV stream, speeds context-heavy decode, and ~doubles the
    sequences a device's HBM budget can hold resident."""
    mdl = cm.MODELS["qwen3vl-8b"]
    dev = cm.DEVICES["jetson_orin_nano"]
    b16 = cm.kv_bytes_per_token(mdl, "bf16")
    i8 = cm.kv_bytes_per_token(mdl, "int8")
    L, hkv, dh = mdl.kv_layout
    assert b16 == 2.0 * L * hkv * dh * 2
    assert b16 / i8 == pytest.approx(2 * dh / (dh + 4))
    # context-free decode_s reproduces the legacy weights-only term
    legacy = 10 * mdl.n_active * mdl.bytes_per_param / (dev.mem_bw * cm._EFF)
    assert cm.decode_s(dev, mdl, 10) == pytest.approx(legacy)
    # with context, int8 decodes strictly faster
    assert cm.decode_s(dev, mdl, 10, context_tokens=4096, kv_dtype="int8") \
        < cm.decode_s(dev, mdl, 10, context_tokens=4096, kv_dtype="bf16")
    # and fits ~2x the sequences in the same KV budget (on a device the
    # weights actually fit; a too-small device reports 0 concurrency)
    big = cm.DEVICES["rtx5090"]
    c16 = cm.kv_concurrency(big, mdl, 4096, "bf16")
    c8 = cm.kv_concurrency(big, mdl, 4096, "int8")
    assert c16 >= 1 and c8 >= 1.8 * c16
    assert cm.kv_concurrency(dev, mdl, 4096) == 0  # 8 GB HBM < 8 GB weights
    # latency_s default stays the calibrated legacy aggregate
    base = cm.latency_s(dev, mdl, 64, 0.5)
    assert cm.latency_s(dev, mdl, 64, 0.5, kv_dtype="bf16") > base
    assert cm.latency_s(dev, mdl, 64, 0.5, kv_dtype="int8") < \
        cm.latency_s(dev, mdl, 64, 0.5, kv_dtype="bf16")


def test_cluster_edge_tiers_default_int8():
    from repro.serving.cluster import build_continuum
    handles = build_continuum([(0, 1), (2, 1)], max_seq=48)
    edge, cloud = handles
    assert not edge.is_cloud and edge.kv_dtype == "int8"
    assert cloud.is_cloud and cloud.kv_dtype == "bf16"
    assert edge.engine.kv_dtype == "int8"
    # the tick cost prices the precision: same profile on the same device
    # would tick slower at bf16 (more KV bytes streamed per token)
    from repro.serving.cluster import EngineHandle
    edge_bf = EngineHandle("edge-bf16", "qwen2-0.5b", edge.device,
                           edge.profile, kv_dtype="bf16", max_seq=48)
    assert edge.decode_tick_s < edge_bf.decode_tick_s
    # recurrent-family edge servers (dense cache) must fall back to bf16
    # instead of crashing on the paged-only int8 default
    xl = EngineHandle("edge-xlstm", "xlstm-1.3b", edge.device,
                      edge.profile, max_seq=48)
    assert xl.kv_dtype == "bf16" and not xl.engine.paged
