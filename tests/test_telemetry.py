"""Continuum telemetry layer (ISSUE-6 tentpole).

Covers the metrics registry primitives, Chrome-trace schema + lifecycle
span ordering under the virtual clock, zero-cost-when-disabled on the
decode hot path, dispatch-audit join correctness, the steady-state
recompile guard (warmed engines re-traced nothing across a mixed
replay), per-tier latency rollups, and the trace_report CLI.
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.cluster import Cluster, build_continuum
from repro.serving.engine import Request, ServingEngine
from repro.serving.request import ContinuumRequest
from repro.serving.telemetry import (
    MetricsRegistry,
    Telemetry,
    latency_summary,
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

SPEC = [(2, 1), (1, 1)]  # 1 cloud + 1 gpu edge


@pytest.fixture(scope="module")
def traced_world():
    """Small continuum with tracing on + one mixed replay already run."""
    tm = Telemetry(trace=True)
    handles = build_continuum(SPEC, seed=0, max_batch=2, max_seq=96,
                              telemetry=tm)
    cluster = Cluster(handles)
    _mixed_replay(cluster)
    return tm, cluster


def _mixed_replay(cluster, n_tasks: int = 6):
    """Submit a small spread of requests across both engines with audited
    predictions, drain, and collect — returns the measured records."""
    tm = cluster.telemetry
    rng = np.random.default_rng(0)
    t = 0.0
    for i in range(n_tasks):
        s = i % len(cluster.handles)
        h = cluster.handles[s]
        toks = rng.integers(1, h.cfg.vocab, 6 + 4 * (i % 3)).astype(np.int32)
        predicted, terms = h.predict_e2e_s(len(toks), 4)
        uid = cluster.submit(ContinuumRequest(
            tokens=toks, max_new_tokens=4, arrival_s=t, task=i, server=s,
            predicted_s=float(predicted)))
        if tm is not None:
            tm.record_dispatch(task=i, server=s, t=t, predicted_s=predicted,
                               uid=uid, terms=terms)
        t += 0.05
        cluster.advance_to(t)
    cluster.drain()
    return cluster.collect()


# ---------------------------------------------------------------- registry


def test_registry_primitives():
    m = MetricsRegistry()
    c = m.counter("hits")
    c.inc()
    c.inc(3)
    g = m.gauge("depth")
    g.set(7.5)
    h = m.histogram("lat")
    h.extend([1.0, 2.0, 3.0, 4.0])
    m.view("twice_hits", lambda: 2 * c.value)
    snap = m.snapshot()
    assert snap["hits"] == 4
    assert snap["depth"] == 7.5
    assert snap["twice_hits"] == 8
    assert snap["lat"]["count"] == 4
    assert snap["lat"]["p50"] == pytest.approx(2.5)
    # same name returns the same instrument, not a fresh one
    assert m.counter("hits") is c
    # reset zeroes stored instruments but keeps live views
    m.reset()
    assert c.value == 0 and h.count == 0
    assert m.snapshot()["twice_hits"] == 0


def test_latency_summary_shape():
    out = latency_summary([1.0, 2.0], [0.1, 0.2, 0.3], [2.0, 4.0])
    assert out["n_requests"] == 2
    assert out["ttft_p50_s"] == pytest.approx(1.5)
    assert out["e2e_mean_s"] == pytest.approx(3.0)
    empty = latency_summary([], [], [])
    assert empty["n_requests"] == 0 and empty["e2e_p95_s"] == 0.0


# ------------------------------------------------------------ trace schema


def test_trace_schema_and_lifecycle_ordering(traced_world, tmp_path):
    tm, cluster = traced_world
    trace = tm.to_json()
    events = trace["traceEvents"]
    assert events, "tracing was enabled but no events were recorded"
    # Chrome trace-event schema: every event carries ph/name/ts/pid/tid
    for ev in events:
        assert ev["ph"] in ("X", "i", "C", "M")
        assert "name" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    # process metadata names both engines
    names = {ev["args"]["name"] for ev in events
             if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert {h.name for h in cluster.handles} <= names

    # per-request lifecycle: uplink -> queue -> prefill -> decode ->
    # downlink, each span starting no earlier than the previous one
    order = ["uplink", "queue", "prefill", "decode", "downlink"]
    by_req: dict = {}
    for ev in events:
        if ev["ph"] == "X" and ev["name"] in order:
            by_req.setdefault((ev["pid"], ev["tid"]), {})[ev["name"]] = ev
    assert by_req, "no lifecycle spans recorded"
    for key, stages in by_req.items():
        assert set(stages) == set(order), f"request {key} missing stages"
        seq = [stages[n] for n in order]
        for a, b in zip(seq, seq[1:]):
            assert a["ts"] <= b["ts"], f"{a['name']} starts after {b['name']}"
            # spans chain: each stage begins where the previous one ended
            assert a["ts"] + a["dur"] <= b["ts"] + 1, \
                f"{a['name']} overlaps into {b['name']}"

    # engine ticks carry real virtual durations and are monotone per pid
    ticks: dict = {}
    for ev in events:
        if ev["ph"] == "X" and ev["name"] == "tick":
            ticks.setdefault(ev["pid"], []).append(ev["ts"])
    assert ticks
    for ts in ticks.values():
        assert ts == sorted(ts)

    # the export round-trips as plain JSON (Perfetto-loadable)
    path = tmp_path / "trace.json"
    tm.export(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["displayTimeUnit"] == "ms"
    assert len(loaded["traceEvents"]) == len(events)


def test_trace_report_cli(traced_world, tmp_path):
    from scripts.trace_report import main, report

    tm, _ = traced_world
    path = tmp_path / "trace.json"
    tm.export(str(path))
    out = report(json.loads(path.read_text()))
    assert "per-stage latency decomposition" in out
    assert "lifecycle/decode" in out and "transfer/uplink" in out
    assert "per-engine utilization" in out
    assert "cost-model calibration" in out
    assert main([str(path), "--top", "3"]) == 0


# ------------------------------------------------------- disabled-mode off


def test_disabled_telemetry_records_no_events():
    """Telemetry(trace=False) keeps the audit but allocates zero trace
    events; telemetry=None leaves the engine's tracer hook unset."""
    tm = Telemetry(trace=False)
    handles = build_continuum(SPEC[:1], seed=0, max_batch=2, max_seq=96,
                              telemetry=tm)
    cluster = Cluster(handles)
    recs = _mixed_replay(cluster, n_tasks=2)
    assert len(recs) == 2 and all(r["success"] for r in recs)
    assert tm.tracer.events == []          # no spans, ever
    assert tm.prediction_error()["n"] == 2  # ... but the audit still joins

    # hot-path guard: with no telemetry at all the engine keeps no tracer
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    eng = ServingEngine(model, model.init(jax.random.PRNGKey(0)),
                        max_batch=2, max_seq=64)
    assert eng._tr is None
    req = Request(0, np.arange(1, 9).astype(np.int32), max_new_tokens=4)
    eng.submit(req)
    eng.run_until_drained()
    assert req.done and eng._tr is None


# ------------------------------------------------------------------- audit


def test_audit_join_and_prediction_error():
    tm = Telemetry(trace=False)
    u1 = tm.record_dispatch(task=1, server=0, t=0.0, predicted_s=2.0,
                            terms={"queue": 0.5, "decode": 1.5})
    u2 = tm.record_dispatch(task=2, server=1, t=0.1, predicted_s=1.0)
    u3 = tm.record_dispatch(task=3, server=0, t=0.2, predicted_s=5.0)
    tm.join_measured(u1, 1.0)            # +100% error
    tm.join_measured(u2, 2.0)            # -50% error
    tm.join_measured(u3, 9.0, completed=False)  # timeout: excluded
    recs = {r.uid: r for r in tm.audit_records()}
    assert recs[u1].terms["decode"] == 1.5
    assert recs[u1].measured_e2e_s == 1.0
    assert not recs[u3].completed
    err = tm.prediction_error()
    assert err["n"] == 2
    assert err["mean_abs_pct_err"] == pytest.approx(75.0)
    assert err["mean_signed_pct_err"] == pytest.approx(25.0)
    tm.reset()
    assert tm.prediction_error()["n"] == 0 and tm.audit_records() == []


def test_cluster_joins_measured_e2e(traced_world):
    """Every audited dispatch from the replay got its measured e2e joined
    at collect() and the prediction-error metric is well-formed."""
    tm, _ = traced_world
    recs = tm.audit_records()
    assert recs and all(r.completed and r.measured_e2e_s is not None
                        for r in recs)
    err = tm.prediction_error()
    assert err["n"] == len(recs)
    assert err["mean_abs_pct_err"] >= 0.0
    assert err["p95_abs_pct_err"] >= err["p50_abs_pct_err"] >= 0.0


# ------------------------------------------------- stats + tier rollups


def test_stats_are_registry_views(traced_world):
    tm, cluster = traced_world
    eng = cluster.handles[0].engine
    stats = eng.stats()
    for key in ("prefill_tokens_computed", "requests_finished",
                "xla_trace_events", "ticks"):
        assert key in stats
    # back-compat attribute accessors mirror the registry counters
    assert eng.prefill_tokens_computed == stats["prefill_tokens_computed"]
    ls = cluster.latency_stats()
    assert "tiers" in ls
    for tier in ("edge", "cloud"):
        assert ls["tiers"][tier]["n_requests"] >= 1
    # the tier rollup merges raw per-engine histograms: total matches
    total = sum(ls[h.name]["n_requests"] for h in cluster.handles)
    assert sum(t["n_requests"] for t in ls["tiers"].values()) == total


# -------------------------------------------------- recompile-guard test


def test_steady_state_no_recompiles(traced_world):
    """A warmed engine replaying a same-shaped mixed workload must trigger
    zero new XLA traces: the recompile-event counter stays 0 across the
    second replay and the jit cache sizes do not grow."""
    tm, cluster = traced_world
    cluster.reset()  # zeroes metrics; XLA caches + _traced persist
    sizes_before = [h.engine.jit_cache_sizes() for h in cluster.handles]
    recs = _mixed_replay(cluster)
    assert all(r["success"] for r in recs)
    for h, before in zip(cluster.handles, sizes_before):
        assert h.engine.metrics.snapshot()["xla_trace_events"] == 0, \
            f"{h.name} re-traced in steady state"
        assert h.engine.jit_cache_sizes() == before
