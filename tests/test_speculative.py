"""Speculative decoding: paged multi-token-verify kernel parity vs the
jnp oracles (padding, windows, null-page poisoning), greedy bit-identity
of speculation on vs off across {bf16,int8} x {chunked,monolithic}
prefill, rejected-draft KV rollback page accounting, the acceptance-
discounted cost-model math, and the router's fourth dispatch shape
(draft-on-A/verify-on-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ops, ref
from repro.kernels.quant import quantize_kv
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import QLMIORouter, ServerHandle
from repro.sim import cost_model as cm


def _rng(seed=0):
    return np.random.default_rng(seed)


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


# ------------------------------------------------ kernel-vs-oracle parity


def _pool(rng, B, S, Hkv, D, bs):
    NB = S // bs
    P = 1 + B * NB
    k = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    bt = jnp.asarray(np.arange(1, 1 + B * NB).reshape(B, NB), jnp.int32)
    return k, v, bt


@pytest.mark.parametrize("B,S,H,Hkv,D,bs,T,window", [
    (2, 96, 8, 2, 64, 16, 4, 0),
    (1, 64, 4, 4, 32, 8, 3, 24),   # sliding window crosses page edges
    (2, 72, 8, 1, 64, 8, 5, 0),    # MQA + non-power-of-two T (padding)
])
def test_paged_verify_kernel_parity(B, S, H, Hkv, D, bs, T, window):
    rng = _rng(7)
    k, v, bt = _pool(rng, B, S, Hkv, D, bs)
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.bfloat16)
    pos = jnp.asarray(rng.integers(S // 2, S - T, B), jnp.int32)
    out = ops.paged_verify(q, k, v, bt, pos, window=window)
    want = ref.paged_verify_ref(q, k, v, bt, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-3, rtol=5e-3)


def test_paged_verify_rows_match_sequential_decode():
    """Row t of one verify pass must equal a single-token paged decode at
    position pos+t over the same pool — the property that makes the
    emitted prefix bit-identical to sequential decoding."""
    rng = _rng(5)
    B, S, H, Hkv, D, bs, T = 2, 64, 4, 2, 32, 8, 4
    k, v, bt = _pool(rng, B, S, Hkv, D, bs)
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.bfloat16)
    pos = jnp.asarray([17, 40], jnp.int32)
    out = ref.paged_verify_ref(q, k, v, bt, pos)
    for t in range(T):
        step = ref.paged_decode_ref(q[:, t], k, v, bt, pos + t)
        np.testing.assert_allclose(np.asarray(out[:, t], np.float32),
                                   np.asarray(step, np.float32),
                                   atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("B,S,H,Hkv,D,bs,T,window", [
    (2, 96, 8, 2, 64, 16, 4, 0),
    (1, 64, 4, 4, 32, 8, 3, 24),
])
def test_paged_verify_quant_kernel_parity(B, S, H, Hkv, D, bs, T, window):
    rng = _rng(11)
    k, v, bt = _pool(rng, B, S, Hkv, D, bs)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.bfloat16)
    pos = jnp.asarray(rng.integers(S // 2, S - T, B), jnp.int32)
    out = ops.paged_verify_quant(q, k8, v8, ks, vs, bt, pos, window=window)
    want = ref.paged_verify_quant_ref(q, k8, v8, ks, vs, bt, pos,
                                      window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-3, rtol=5e-3)
    # dequant noise vs the full-precision pool stays int8-sized
    full = ref.paged_verify_ref(q, k, v, bt, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(full, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_paged_verify_quant_masks_unallocated():
    """-1 table entries (clamped to the null page) must not leak the null
    page's garbage values or scales into any verify row."""
    rng = _rng(3)
    B, H, Hkv, D, bs, T = 1, 4, 2, 32, 8, 3
    P = 4
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.bfloat16)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    ks = ks.at[0].set(1e6)  # poison the null page with huge scales
    vs = vs.at[0].set(1e6)
    bt = jnp.asarray([[1, 2, -1]], jnp.int32)
    pos = jnp.asarray([2 * bs - T], jnp.int32)  # last row ends block 1
    out = ops.paged_verify_quant(q, k8, v8, ks, vs, bt, pos)
    want = ref.paged_verify_quant_ref(q, k8, v8, ks, vs, bt, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-3, rtol=5e-3)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


# ----------------------------------------- engine: greedy bit-identity


def _serve(model, params, prompts, *, max_new_tokens=12, **kw):
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, **kw)
    reqs = [Request(i, np.asarray(p, np.int32),
                    max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, [tuple(r.output) for r in reqs]


_PROMPTS = [[1, 2, 3, 4, 5, 6, 7], [9, 8, 7, 6, 5]]


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("prefill_chunk", [0, 8])
def test_spec_greedy_bit_identity(qwen, kv_dtype, prefill_chunk):
    """Speculation must never change the emitted greedy stream: the
    verify pass accepts exactly the prefix sequential decode would have
    produced, across both KV precisions and both prefill paths."""
    cfg, model, params = qwen
    kw = dict(kv_dtype=kv_dtype, prefill_chunk=prefill_chunk)
    _, base = _serve(model, params, _PROMPTS, **kw)
    eng, spec = _serve(model, params, _PROMPTS, draft_config=cfg,
                       draft_seed=123, spec_k=3, **kw)
    assert spec == base
    st = eng.stats()
    assert st["speculative"] and st["spec_k"] == 3
    assert st["spec_tokens_drafted"] > 0
    assert st["spec_tokens_accepted"] + st["spec_tokens_wasted"] == \
        st["spec_tokens_drafted"]


def test_spec_acceptance_telemetry(qwen):
    """The live acceptance gauge the router's fourth-shape pricing reads
    is exactly accepted / drafted.  (Even a self-draft — same seed-0
    init — stays well below 1.0 on this reduced random-weight model:
    near-uniform logits let float-reduction order flip the argmax
    between the dense draft pass and the paged verify.)"""
    cfg, model, params = qwen
    eng, spec = _serve(model, params, _PROMPTS, draft_config=cfg,
                       draft_seed=0, spec_k=3)
    _, base = _serve(model, params, _PROMPTS)
    assert spec == base
    st = eng.stats()
    assert st["spec_tokens_drafted"] > 0
    assert eng.acceptance_rate() == pytest.approx(
        st["spec_tokens_accepted"] / st["spec_tokens_drafted"])
    assert 0.0 < eng.acceptance_rate() <= 1.0


def test_spec_rollback_releases_pages(qwen):
    """Rejected drafts leave scattered K/V beyond the accepted position;
    rollback is positional (stale rows masked by qpos, overwritten next
    tick) and must not leak pages: the pool drains to zero and refcounts
    stay consistent for warm prefix reuse afterwards."""
    cfg, model, params = qwen
    eng, outs = _serve(model, params, _PROMPTS, draft_config=cfg,
                       draft_seed=123, spec_k=3)
    assert eng.stats()["spec_tokens_wasted"] > 0  # drafts really rejected
    # drained pool: no live references; every page is either free or
    # parked (ref 0) behind the prefix registry
    assert eng.pool.pages_in_use() == 0
    assert eng.pool.num_free() == eng.pool.num_pages - 1
    # a fresh resubmission of the same prompt must still replay exactly
    warm = Request(99, np.asarray(_PROMPTS[0], np.int32),
                   max_new_tokens=12)
    eng.submit(warm)
    eng.run_until_drained()
    assert tuple(warm.output) == outs[0]


def test_spec_needs_paged_backend(qwen):
    cfg, model, params = qwen
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(model, params, paged=False, draft_config=cfg)
    with pytest.raises(ValueError, match="spec_k"):
        ServingEngine(model, params, draft_config=cfg, spec_k=0)


# ------------------------------------------------- cost model: speculation


def test_expected_accepted_identities():
    assert cm.expected_accepted(3, 0.0) == 1.0  # bonus token only
    assert cm.expected_accepted(2, 0.5) == pytest.approx(1.75)
    # a -> 1 saturates at k + 1 tokens per tick (clipped below 1.0)
    assert cm.expected_accepted(4, 1.0) == pytest.approx(5.0, rel=1e-3)
    # monotone in both k and a
    assert cm.expected_accepted(4, 0.6) > cm.expected_accepted(2, 0.6)
    assert cm.expected_accepted(3, 0.8) > cm.expected_accepted(3, 0.4)


def test_verify_streams_memory_once():
    """The verify pass prices like ONE decode step plus FLOPs: weights
    and KV context stream once for all k+1 rows, so verify_s(k) is far
    below k sequential decode steps and barely above verify_s(1)."""
    dev = cm.DEVICES["rtx3090ti"]
    mdl = cm.MODELS["qwen3vl-8b"]
    v1 = float(cm.verify_s(dev, mdl, 1, context_tokens=4096))
    v8 = float(cm.verify_s(dev, mdl, 8, context_tokens=4096))
    seq8 = 8 * float(cm.decode_s(dev, mdl, 1, context_tokens=4096))
    assert v8 < 2 * v1  # memory term dominates and is paid once
    assert v8 < 0.5 * seq8


def test_speculative_tick_decomposition():
    dev = cm.DEVICES["rtx3090ti"]
    edge = cm.DEVICES["jetson_orin_nano"]
    mdl = cm.MODELS["qwen3vl-8b"]
    drf = cm.MODELS["qwen3vl-2b"]
    k, ctx = 3, 48
    tick = float(cm.speculative_tick_s(dev, mdl, drf, k,
                                       context_tokens=ctx))
    want = (k * float(cm.draft_s(dev, drf, 1.0, ctx))
            + float(cm.verify_s(dev, mdl, k + 1, ctx)))
    assert tick == pytest.approx(want)
    # pricing the draft steps on a slow edge device raises the tick
    edge_tick = float(cm.speculative_tick_s(dev, mdl, drf, k,
                                            context_tokens=ctx,
                                            draft_device=edge))
    assert edge_tick > tick
    assert edge_tick == pytest.approx(
        k * float(cm.draft_s(edge, drf, 1.0, ctx))
        + float(cm.verify_s(dev, mdl, k + 1, ctx)))


def test_speculative_itl_acceptance_discount():
    """Effective ITL = tick / expected_accepted: above-breakeven
    acceptance beats plain decode, zero acceptance is strictly worse —
    the signal the router's fourth-shape pricing keys on."""
    dev = cm.DEVICES["rtx3090ti"]
    mdl = cm.MODELS["qwen3vl-8b"]
    drf = cm.MODELS["qwen3vl-2b"]
    k, ctx = 2, 48
    plain = float(cm.decode_s(dev, mdl, 1, context_tokens=ctx))
    tick = float(cm.speculative_tick_s(dev, mdl, drf, k,
                                       context_tokens=ctx))
    itl = lambda a: float(cm.speculative_itl_s(dev, mdl, drf, k, a,
                                               context_tokens=ctx))
    assert itl(0.6) < plain < itl(0.0) == pytest.approx(tick)
    assert itl(0.9) < itl(0.6)  # monotone in acceptance


# ---------------------------------------------- router: fourth shape


def _stub_router(latencies, spec, **kw):
    servers = [ServerHandle(name=f"s{i}", model_id=0, device_id=0,
                            is_cloud=False,
                            execute=lambda t, v=v: (v, True))
               for i, v in enumerate(latencies)]
    return QLMIORouter(servers, milp_pred=lambda t, s: latencies[s],
                       mgqp_pred=lambda t, s: 0.9,
                       spec_pred=spec, **kw)


def test_router_plan_prefers_spec_shape():
    """plan() picks draft-on-A/verify-on-B when the speculative pair
    beats every pure shape, and reports the draft server the cluster
    submit needs (prefill_server stays None — it is not disaggregation)."""
    r = _stub_router([10.0, 10.0], spec=lambda t, sa, sv: 2.0
                     if sa != sv else None)
    p = r.plan(0)
    assert p["draft_server"] is not None
    assert p["draft_server"] != p["server"]
    assert p["prefill_server"] is None
    assert p["predicted_s"] == pytest.approx(2.0)


def test_router_plan_colocated_speculation():
    """A == B prices colocated cloud speculation: draft_server equals the
    verify server in the winning shape."""
    r = _stub_router([10.0, 10.0], spec=lambda t, sa, sv: 3.0
                     if sa == sv == 1 else None)
    p = r.plan(0)
    assert (p["server"], p["draft_server"]) == (1, 1)


def test_router_plan_spec_fallback_to_pure():
    """Without spec_pred — or when every pair declines (None) or prices
    above plain decode — plan() degrades to the pure shape."""
    r = _stub_router([1.0, 5.0], spec=None)
    assert r.plan(0)["draft_server"] is None
    r2 = _stub_router([1.0, 5.0], spec=lambda t, sa, sv: None)
    assert r2.plan(0)["draft_server"] is None
    r3 = _stub_router([1.0, 5.0], spec=lambda t, sa, sv: 50.0)
    p3 = r3.plan(0)
    assert (p3["server"], p3["draft_server"]) == (0, None)


def test_router_plan_spec_skips_unhealthy():
    """A dead draft or verify server appears in no speculative pair."""
    r = _stub_router([1.0, 5.0], spec=lambda t, sa, sv: 0.1)
    r.health.dead_until[0] = 100.0
    p = r.plan(0)
    assert p["server"] == 1
    assert p["draft_server"] in (None, 1)  # never the dead server 0
