"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D,causal,window", [
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 128, 128, 4, 4, 32, True, 48),
    (2, 64, 192, 2, 1, 64, True, 0),   # cross-chunk GQA
    (2, 96, 160, 2, 2, 64, False, 0),  # encoder / cross-attention
    (1, 100, 100, 4, 2, 32, True, 0),  # non-divisible by block
])
def test_flash_attention(B, Sq, Sk, H, Hkv, D, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Sk, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Sk, Hkv, D)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D,window", [
    (2, 96, 8, 2, 64, 0),
    (2, 128, 4, 4, 32, 24),
    (1, 70, 8, 1, 64, 0),  # padding path
])
def test_flash_decode(B, S, H, Hkv, D, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dtype)
    kc = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    vc = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    cpos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = jnp.asarray(RNG.integers(S // 2, S, B), jnp.int32)
    out = ops.flash_decode(q, kc, vc, cpos, pos, window=window, block_k=32)
    want = ref.flash_decode_ref(q, kc, vc, cpos, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("b,S,h,p,n", [(2, 64, 4, 16, 8), (1, 128, 2, 32, 16)])
def test_ssd_scan(b, S, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, S, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, S, h)), jnp.float32)
    a_neg = -jnp.asarray(RNG.uniform(0.1, 1.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, S, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, S, n)), jnp.float32)
    out = ops.ssd_scan(x, dt, a_neg, B, C, chunk=chunk)
    want = ref.ssd_scan_ref(x, dt, a_neg, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,K,N", [(4, 48, 96, 40), (8, 16, 64, 128),
                                     (2, 130, 70, 90)])
def test_grouped_matmul(E, C, K, N, dtype):
    x = jnp.asarray(RNG.normal(size=(E, C, K)), dtype)
    w = jnp.asarray(RNG.normal(size=(E, K, N)), dtype)
    out = ops.grouped_matmul(x, w, block_c=32, block_n=32, block_k=32)
    want = ref.grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-3,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("zero_centered", [False, True])
@pytest.mark.parametrize("shape", [(3, 50, 96), (7, 128), (260, 64)])
def test_rmsnorm(shape, zero_centered, dtype):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    s = jnp.asarray(RNG.normal(size=shape[-1:]), jnp.float32)
    out = ops.rmsnorm(x, s, zero_centered=zero_centered, block_t=16)
    want = ref.rmsnorm_ref(x, s, zero_centered=zero_centered)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
