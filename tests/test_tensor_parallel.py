"""Tensor-parallel sharded serving (distributed/tp.py): bit-identical
greedy decode under shard_map at TP 1/2/4 across {dense, MoE} x
{bf16, int8} x {chunked, monolithic} prefill x {spec on, off}, the
replicated-attention and expert-ff fallback layouts, cross-mesh
migration (TP=4 -> TP=1), and the ShardingPlan pspec rules the layouts
are built from (heads vs KV-sequence fallback, paged-pool leaves,
recurrent states, ZeRO-1 placement, never-pad)."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.distributed.sharding import _leaf_pspec, make_plan
from repro.distributed.tp import ShardedServing, serving_mesh
from repro.models import build_model
from repro.nn.spec import TensorSpec
from repro.serving.engine import Request, ServingEngine

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def llama():
    cfg = reduced(get_config("llama3.2-3b"))  # dense, GQA
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe():
    cfg = reduced(get_config("qwen2-moe-a2.7b"))  # MoE + shared expert
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


_PROMPTS = [[1, 2, 3, 4, 5, 6, 7, 8, 9], [9, 8, 7, 6, 5]]


def _serve(model, params, *, tp=0, max_new_tokens=8, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    if tp:
        kw["mesh"] = serving_mesh(tp)
    eng = ServingEngine(model, params, **kw)
    reqs = [Request(i, np.asarray(p, np.int32), max_new_tokens=max_new_tokens)
            for i, p in enumerate(_PROMPTS)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, [tuple(r.output) for r in reqs]


# ------------------------------------------------------------ layouts


@needs_mesh
def test_tp_shards_layout(llama, moe):
    lcfg, lmodel, _ = llama
    mcfg, mmodel, _ = moe
    # dense GQA: heads + kv heads + dense mlp all divide
    assert ShardedServing(lmodel, serving_mesh(2)).tp_shards == (
        "heads", "kv_heads", "mlp")
    # MoE: experts divide -> expert parallelism, dense-mlp rule unused
    sh = ShardedServing(mmodel, serving_mesh(2)).tp_shards
    assert "experts" in sh and "expert_ff" not in sh
    # TP=1 mesh runs the plain model (no collectives at all)
    s1 = ShardedServing(lmodel, serving_mesh(1))
    assert s1.tp_shards == () and s1.local_model is lmodel
    # d_model not divisible (tp=3): nothing output-column-shards
    s3 = ShardedServing(lmodel, serving_mesh(3))
    assert lcfg.d_model % 3 != 0 and s3.tp_shards == ()
    # kv heads not divisible: attention stays replicated, mlp still shards
    mqa = build_model(dataclasses.replace(lcfg, n_kv_heads=1))
    assert ShardedServing(mqa, serving_mesh(2)).tp_shards == ("mlp",)
    # experts not divisible but every expert's ff is: expert-ff fallback
    e6 = build_model(dataclasses.replace(mcfg, n_experts=6))
    sh = ShardedServing(e6, serving_mesh(4)).tp_shards
    assert "expert_ff" in sh and "experts" not in sh
    if mcfg.shared_ff:
        assert "shared_ff" in sh


@needs_mesh
def test_param_pspecs_output_column(llama, moe):
    """Projections closing a sharded dim hold full contraction rows and
    1/tp output columns; openings stay column-parallel; vocab replicated."""
    _, lmodel, _ = llama
    sv = ShardedServing(lmodel, serving_mesh(2))
    ps = sv.param_pspecs
    layer = ps["layers"]
    assert layer["attn"]["wo"] == P(None, None, "model")
    assert layer["attn"]["wq"] == P(None, None, "model")
    assert layer["mlp"]["w_down"] == P(None, None, "model") or \
        layer["mlp"].get("w2") == P(None, None, "model")
    assert ps["embed"]["table"] == P(None, None)  # replicated logits

    _, mmodel, _ = moe
    me = ShardedServing(mmodel, serving_mesh(2))
    moe_ps = me.param_pspecs["layers"]["moe"]
    # expert parallelism: every expert leaf sharded on E, incl. w_down
    assert moe_ps["w_down"] == P(None, "model", None, None)
    mcfg = mmodel.cfg
    ff = ShardedServing(build_model(dataclasses.replace(mcfg, n_experts=6)),
                        serving_mesh(4))
    ffl = ff.param_pspecs["layers"]["moe"]
    # expert-ff fallback: gate/up on f, down on its d output columns
    assert ffl["w_gate"] == P(None, None, None, "model")
    assert ffl["w_down"] == P(None, None, None, "model")
    if mcfg.shared_ff:
        assert ffl["shared_down"] == P(None, None, "model")


# ------------------------------------------- bit-identical token streams


@needs_mesh
@pytest.mark.parametrize("kv_dtype,tp", [
    ("bf16", 1), ("bf16", 2), ("bf16", 4), ("int8", 2), ("int8", 4)])
def test_tp_token_identity_dense(llama, kv_dtype, tp):
    _, model, params = llama
    _, base = _serve(model, params, kv_dtype=kv_dtype)
    _, got = _serve(model, params, tp=tp, kv_dtype=kv_dtype)
    assert got == base


@needs_mesh
@pytest.mark.parametrize("kv_dtype,tp", [("bf16", 2), ("bf16", 4),
                                         ("int8", 2)])
def test_tp_token_identity_moe(moe, kv_dtype, tp):
    _, model, params = moe
    _, base = _serve(model, params, kv_dtype=kv_dtype)
    _, got = _serve(model, params, tp=tp, kv_dtype=kv_dtype)
    assert got == base


@needs_mesh
@pytest.mark.parametrize("chunk", [0, 8])
def test_tp_token_identity_prefill_paths(llama, chunk):
    """Monolithic (chunk=0) and chunked prefill both bit-match."""
    _, model, params = llama
    _, base = _serve(model, params, prefill_chunk=chunk)
    _, got = _serve(model, params, tp=2, prefill_chunk=chunk)
    assert got == base


@needs_mesh
def test_tp_token_identity_speculative(llama):
    """Self-draft speculation on a TP=2 mesh (sharded verify kernel path)
    still emits exactly the unsharded spec-off stream."""
    cfg, model, params = llama
    _, base = _serve(model, params)
    eng, got = _serve(model, params, tp=2, draft_config=cfg,
                      draft_seed=123, spec_k=3)
    assert got == base
    st = eng.stats()
    assert st["speculative"] and st["spec_tokens_drafted"] > 0


@needs_mesh
def test_tp_token_identity_replicated_attention(llama):
    """kv heads not divisible -> attention/pool replicated, mlp sharded;
    decode must still bit-match."""
    cfg, _, _ = llama
    mqa_cfg = dataclasses.replace(cfg, n_kv_heads=1)
    model = build_model(mqa_cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, base = _serve(model, params)
    eng, got = _serve(model, params, tp=2)
    assert got == base
    assert not eng._tp.kv_sharded


@needs_mesh
def test_tp_token_identity_expert_ff_fallback(moe):
    """E % tp != 0: every expert's ff dim (and the shared expert) shards
    instead — the make_plan fallback, exercised end to end."""
    cfg, _, _ = moe
    e6_cfg = dataclasses.replace(cfg, n_experts=6)
    model = build_model(e6_cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, base = _serve(model, params)
    eng, got = _serve(model, params, tp=4)
    assert got == base
    assert "expert_ff" in eng._tp.tp_shards


# ------------------------------------------------- cross-mesh migration


@needs_mesh
def test_cross_mesh_migration_tp4_to_tp1(llama):
    """Prefill + partial decode on a TP=4 mesh, evacuate, resume on an
    unsharded engine: the snapshot gathers to host and re-shards into the
    destination layout, so the stream is bit-identical end to end."""
    cfg, model, params = llama
    prompt = np.random.default_rng(0).integers(
        1, cfg.vocab, 23).astype(np.int64)

    B = ServingEngine(model, params, max_batch=2, max_seq=64, page_size=8)
    base_req = Request(0, prompt.copy(), max_new_tokens=10)
    B.submit(base_req)
    B.run_until_drained()
    base = tuple(base_req.output)
    B.reset_prefix_cache()

    A = ServingEngine(model, params, max_batch=2, max_seq=64, page_size=8,
                      mesh=serving_mesh(4))
    req = Request(1, prompt.copy(), max_new_tokens=10)
    A.submit(req)
    for _ in range(10_000):
        slot = A.slot_of_request(1)
        if slot is not None and len(req.output) >= 4:
            break
        A.step()
    assert tuple(req.output) == base[:len(req.output)]
    A.evacuate(1)
    B.submit(req)
    B.run_until_drained()
    assert tuple(req.output) == base


# -------------------------------------------------- ShardingPlan rules


def _mesh2():
    dev = jax.devices()
    if len(dev) >= 2:
        arr = np.asarray(dev[:2]).reshape(2, 1)
    else:  # degenerate 1x1 mesh still exercises the rule logic
        arr = np.asarray(dev[:1]).reshape(1, 1)
    return Mesh(arr, ("model", "data"))


def test_plan_heads_vs_seq_fallback(llama):
    cfg, _, _ = llama
    mesh = _mesh2()
    sz = mesh.shape["model"]
    plan = make_plan(cfg, mesh)
    L, B, S, Dh = cfg.n_layers, 2, 32, cfg.hd

    kv = np.zeros((L, B, S, cfg.n_kv_heads, Dh), np.float32)
    cache = plan.cache(cfg, {"k": kv, "v": kv})
    if cfg.n_kv_heads % sz == 0:
        assert cache["k"].spec == P(None, ("data",), None, "model", None)
    # MQA: kv-head axis can't shard -> KV-sequence fallback on S
    mqa = dataclasses.replace(cfg, n_kv_heads=1)
    kv1 = np.zeros((L, B, S, 1, Dh), np.float32)
    c1 = plan.cache(mqa, {"k": kv1})["k"].spec
    assert c1[3] is None and c1[2] == ("model",)


def test_plan_paged_pool_leaves(llama):
    cfg, _, _ = llama
    mesh = _mesh2()
    sz = mesh.shape["model"]
    plan = make_plan(cfg, mesh)
    L, pages, bs, Hkv = cfg.n_layers, 6, 8, cfg.n_kv_heads
    pool = {"k_pages": np.zeros((L, pages, bs, Hkv, cfg.hd), np.float32),
            "k_scales": np.zeros((L, pages, bs, Hkv), np.float32)}
    out = plan.cache(cfg, pool)
    if Hkv % sz == 0:
        # kv heads shard; the page axis must never shard (host-side CoW,
        # scatters and snapshot export all index it)
        assert out["k_pages"].spec == P(None, None, None, "model", None)
        assert out["k_scales"].spec == P(None, None, None, "model")
    # Hkv=1 pool: falls back to the in-page sequence axis
    p1 = {"k_pages": np.zeros((L, pages, bs, 1, cfg.hd), np.float32)}
    spec1 = plan.cache(cfg, p1)["k_pages"].spec
    assert spec1[1] is None and spec1[3] is None
    if bs % sz == 0:
        assert spec1[2] == "model"


def test_plan_recurrent_state_leaves(llama):
    cfg, _, _ = llama
    mesh = _mesh2()
    sz = mesh.shape["model"]
    plan = make_plan(cfg, mesh)
    # conv state [L, taps, B, d]: batch at its named index, widest
    # divisible trailing dim on model
    leaf = np.zeros((cfg.n_layers, 4, 2, 64), np.float32)
    spec = plan.cache(cfg, {"conv": leaf})["conv"].spec
    if 2 % mesh.shape["data"] == 0:
        assert spec[2] == ("data",)
    assert spec[3] == ("model" if 64 % sz == 0 else None)


def test_plan_zero1_opt_state(llama):
    cfg, _, model_ = llama
    mesh = _mesh2()
    plan = make_plan(cfg, mesh)
    spec = {"w": TensorSpec((8, 64), ("embed", "mlp"), "normal"),
            "b": TensorSpec((64,), ("mlp",), "zeros")}
    opt = plan.opt_state(spec)
    # moments reuse the param pspec plus `data` on the first free dim
    wspec = opt.m["w"].spec
    assert wspec[1] == "model"  # mlp rule
    assert wspec[0] == ("data",)  # ZeRO-1 slot on the free embed dim
    assert opt.m["w"] is opt.v["w"] is not None
    # scalar step stays replicated
    assert opt.step.spec == P()


def test_plan_never_pads():
    mesh = _mesh2()
    sz = mesh.shape["model"]
    rules = {"mlp": "model", None: None}
    # any dim the axis does not divide stays unsharded, never padded
    odd = TensorSpec((sz * 3 + 1,), ("mlp",), "zeros")
    assert _leaf_pspec(odd, rules, mesh) == P(None)
    even = TensorSpec((sz * 4,), ("mlp",), "zeros")
    assert _leaf_pspec(even, rules, mesh) == P("model" if sz > 1 else None)


def test_plan_expert_fallback_divisibility(moe):
    """make_plan's expert fallback: E % model != 0 shards each expert's
    ff dim through the mlp rule — but only when that dim divides too."""
    cfg, _, _ = moe
    mesh = _mesh2()
    sz = mesh.shape["model"]
    if sz == 1:
        pytest.skip("needs a >1 model axis")
    e_bad = dataclasses.replace(cfg, n_experts=sz + 1)
    plan = make_plan(e_bad, mesh)
    assert plan.rules["experts"] is None
    assert (plan.rules["mlp"] == "model") == (
        e_bad.moe_ff % sz == 0 and
        (not e_bad.shared_ff or e_bad.shared_ff % sz == 0))
    # expert ff does not divide either: the mlp rule must drop too
    ff_bad = dataclasses.replace(cfg, n_experts=sz + 1, moe_ff=sz * 3 + 1)
    assert make_plan(ff_bad, mesh).rules["mlp"] is None


# ------------------------------------------------ cost model / continuum


def test_cost_model_tp_terms():
    """tp=1 is a bitwise no-op on every calibrated baseline; tp>1 divides
    the streamed bytes / FLOPs and adds the ici collective term."""
    from repro.sim import cost_model as cm
    dev, prof = cm.DEVICES["rtx5090"], cm.MODELS["qwen3vl-8b"]
    base_d = cm.decode_s(dev, prof, 64.0, context_tokens=512,
                         kv_dtype="int8")
    assert cm.decode_s(dev, prof, 64.0, context_tokens=512,
                       kv_dtype="int8", tp=1) == base_d
    d2 = cm.decode_s(dev, prof, 64.0, context_tokens=512,
                     kv_dtype="int8", tp=2)
    d4 = cm.decode_s(dev, prof, 64.0, context_tokens=512,
                     kv_dtype="int8", tp=4)
    assert d4 < d2 < base_d

    base_p = cm.prefill_s(dev, prof, 256.0)
    assert cm.prefill_s(dev, prof, 256.0, tp=1) == base_p
    assert cm.prefill_s(dev, prof, 256.0, tp=4) < base_p

    base_v = cm.verify_s(dev, prof, 4, context_tokens=512)
    assert cm.verify_s(dev, prof, 4, context_tokens=512, tp=1) == base_v
    assert cm.verify_s(dev, prof, 4, context_tokens=512, tp=4) < base_v

    assert cm.tp_collective_s(dev, prof, 64.0, 1) == 0.0
    # collectives grow with width; the compute/bytes split shrinks —
    # so sufficiently narrow interconnects eventually stop paying off
    c2 = cm.tp_collective_s(dev, prof, 64.0, 2)
    c8 = cm.tp_collective_s(dev, prof, 64.0, 8)
    assert 0.0 < c2 < c8
    slow = dataclasses.replace(dev, ici_bw=1e6)
    assert cm.decode_s(slow, prof, 64.0, tp=8) > cm.decode_s(
        slow, prof, 64.0)


def test_continuum_tp_knob():
    """build_continuum(tp=N) shards only the cloud class; the TP handle's
    tick costs shrink, which is exactly what the router prices."""
    from repro.serving.cluster import build_continuum
    spec = [(0, 1), (2, 1)]
    flat = build_continuum(spec, backend="sim", max_batch=2, max_seq=96)
    tp4 = build_continuum(spec, backend="sim", max_batch=2, max_seq=96,
                          tp=4)
    # edge tier untouched (bitwise — the tp=1 path is the verbatim
    # single-device expression)
    assert tp4[0].tp == 1
    assert tp4[0].decode_tick_s == flat[0].decode_tick_s
    assert tp4[0].prefill_tok_s == flat[0].prefill_tok_s
    # cloud tier: both phases get faster, by less than the ideal 4x
    assert tp4[1].tp == 4
    assert tp4[1].decode_tick_s < flat[1].decode_tick_s
    assert tp4[1].prefill_tok_s < flat[1].prefill_tok_s
    assert tp4[1].decode_tick_s > flat[1].decode_tick_s / 4
    # dict form shards a chosen class
    per = build_continuum(spec, backend="sim", max_batch=2, max_seq=96,
                          tp={0: 2})
    assert per[0].tp == 2 and per[1].tp == 1


@needs_mesh
def test_continuum_live_tp_engine(llama):
    """Live backend: the tp knob hands the engine a real host mesh."""
    from repro.serving.cluster import build_continuum
    handles = build_continuum([(0, 1)], backend="live", max_batch=2,
                              max_seq=64, tp={0: 2})
    h = handles[0]
    assert h.engine.mesh is not None and h.engine._tp.tp == 2
    assert h.decode_tick_s > 0
