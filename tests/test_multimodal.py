"""Modality-aware request path: embedding-span prefill parity with the
token path, prefix-cache hits on repeated media segments, the mm encoder's
keep-top-k compression, and the split-point offloading decision."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models import lm
from repro.models.mm_encoder import (MMEncoderConfig, encode_audio,
                                     encode_image, init_mm_encoder,
                                     keep_top_k)
from repro.serving import segments as sg
from repro.serving.engine import Request, ServingEngine
from repro.sim import cost_model as cm


def _rng(seed=5):
    return np.random.default_rng(seed)


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _token_embeds(cfg, params, toks):
    """Host copy of the token-table rows a text span would embed to."""
    return np.asarray(lm.embed_tokens(cfg, params, jnp.asarray(toks)),
                      np.float32)


# ------------------------------------------------------------ segments


def test_key_ids_and_digests():
    toks = np.array([3, 7, 11], np.int32)
    feats = _rng().normal(size=(4, 8)).astype(np.float32)
    segs = [sg.EmbedSegment(feats), sg.TextSegment(toks)]
    ids = sg.key_ids(segs)
    assert ids.dtype == np.int64 and len(ids) == 7
    assert (ids[:4] < 0).all()  # media never aliases a vocab id
    assert np.array_equal(ids[4:], toks)
    # content-determined: same features -> same ids; different -> disjoint
    ids2 = sg.key_ids([sg.EmbedSegment(feats.copy()), sg.TextSegment(toks)])
    assert np.array_equal(ids, ids2)
    other = sg.key_ids([sg.EmbedSegment(feats + 1.0)])
    assert not np.intersect1d(ids[:4], other).size
    dense, mask = sg.dense_features(segs, 8)
    assert mask.tolist() == [True] * 4 + [False] * 3
    np.testing.assert_array_equal(dense[:4], feats)
    with pytest.raises(ValueError):
        sg.dense_features(segs, 16)  # d_model mismatch


# ------------------------------------------------- token/embeds parity


def test_embed_prefill_parity_monolithic(qwen):
    """Same tokens through the embeds entry -> bit-identical logits."""
    cfg, model, params = qwen
    toks = _rng(7).integers(0, cfg.vocab, 12).astype(np.int32)
    logits_t, _ = model.prefill(params, {"tokens": jnp.asarray(toks)[None]})
    emb = _token_embeds(cfg, params, toks)
    logits_e, _ = model.prefill(params, {
        "tokens": jnp.asarray(toks)[None],
        "embeds": jnp.asarray(emb)[None],
        "embed_mask": jnp.ones((1, len(toks)), bool)})
    assert jnp.array_equal(logits_t, logits_e)


@pytest.mark.parametrize("paged", [False, True])
def test_embed_span_engine_parity(qwen, paged):
    """A request whose leading span is injected as *embeddings of the same
    tokens* must generate exactly what the plain token request generates —
    through the engine's bucketed + chunked prefill on both backends."""
    cfg, model, params = qwen
    toks = _rng(3).integers(0, cfg.vocab, 20).astype(np.int32)
    kw = dict(max_batch=2, max_seq=64, paged=paged, prefill_chunk=8)
    if paged:
        kw["page_size"] = 4

    eng_t = ServingEngine(model, params, **kw)
    req_t = Request(0, toks.copy(), max_new_tokens=4)
    eng_t.submit(req_t)
    eng_t.run_until_drained()

    emb = _token_embeds(cfg, params, toks[:9])
    segs = [sg.EmbedSegment(emb, modality="image"),
            sg.TextSegment(toks[9:])]
    eng_e = ServingEngine(model, params, **kw)
    req_e = Request(1, segments=segs, max_new_tokens=4)
    eng_e.submit(req_e)
    eng_e.run_until_drained()
    assert req_e.output == req_t.output


def test_non_attention_family_rejects_embed_spans():
    cfg = reduced(get_config("zamba2-2.7b"))
    model = build_model(cfg)
    assert not model.supports_embed_spans
    with pytest.raises(ValueError, match="embedding-span"):
        model.prefill(None, {"tokens": jnp.zeros((1, 4), jnp.int32),
                             "embeds": jnp.zeros((1, 4, cfg.d_model)),
                             "embed_mask": jnp.zeros((1, 4), bool)})


def test_engine_rejects_mismatched_feature_dim(qwen):
    cfg, model, params = qwen
    eng = ServingEngine(model, params, max_batch=1, max_seq=64)
    bad = [sg.EmbedSegment(np.zeros((3, cfg.d_model + 1), np.float32))]
    with pytest.raises(ValueError, match="d_model"):
        eng.submit(Request(0, segments=bad))


# ------------------------------------------------- prefix cache on media


def test_prefix_cache_hit_repeated_image_segment(qwen):
    """Two requests carrying the same image share its KV pages; a
    different image misses."""
    cfg, model, params = qwen
    rng = _rng(9)
    img = rng.normal(size=(8, cfg.d_model)).astype(np.float32)
    tail1 = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    tail2 = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        page_size=4, prefill_chunk=8)
    eng.submit(Request(0, segments=[sg.EmbedSegment(img),
                                    sg.TextSegment(tail1)],
                       max_new_tokens=3))
    eng.run_until_drained()
    assert eng.prefix_tokens_reused == 0
    eng.submit(Request(1, segments=[sg.EmbedSegment(img.copy()),
                                    sg.TextSegment(tail2)],
                       max_new_tokens=3))
    eng.run_until_drained()
    # the image spans two full pages; both are served from the trie
    assert eng.prefix_tokens_reused == 8
    assert eng.pool.hits >= 2
    hits_before = eng.pool.hits
    other = rng.normal(size=(8, cfg.d_model)).astype(np.float32)
    eng.submit(Request(2, segments=[sg.EmbedSegment(other),
                                    sg.TextSegment(tail1)],
                       max_new_tokens=3))
    eng.run_until_drained()
    assert eng.pool.hits == hits_before  # different image: no reuse


# ------------------------------------------------------------ mm encoder


def test_mm_encoder_shapes_and_keep_top_k():
    cfg = MMEncoderConfig(d_model=32, img_size=32, patch=8, audio_dim=8,
                          n_layers=1, n_heads=2, d_ff=64, keep_ratio=0.5)
    params = init_mm_encoder(cfg, jax.random.PRNGKey(1))
    rng = _rng(2)
    img = jnp.asarray(rng.random((2, 32, 32, 3)), jnp.float32)
    f = encode_image(cfg, params, img)
    assert f.shape == (2, 8, 32)  # 16 patches, keep 8
    assert jnp.array_equal(f, encode_image(cfg, params, img))  # determinism
    au = jnp.asarray(rng.random((1, 10, 8)), jnp.float32)
    assert encode_audio(cfg, params, au).shape == (1, 5, 32)
    # keep_top_k keeps the highest-norm rows in original order
    x = jnp.asarray([[[1.0, 0], [9, 0], [0, 0.5], [0, 4]]])
    kept = keep_top_k(x, 2)
    np.testing.assert_array_equal(np.asarray(kept),
                                  [[[9.0, 0], [0, 4]]])


# ------------------------------------------------------- split decision


def test_split_point_decision_regression():
    """Slow uplink -> edge-encode wins (features are smaller than media);
    fast uplink + weak edge device -> raw-ship wins (the server encodes
    much faster than the source)."""
    spec = cm.media_spec("image", keep_ratio=1 / 3)
    assert spec.feature_bytes < spec.raw_bytes  # else nothing to trade
    edge_dev = cm.DeviceProfile("src", 3e12, 30e9, 12.5e6, 0.004)
    cloud = cm.DeviceProfile("cloud", 300e12, 1.5e12, 1e6, 0.03)  # thin WAN
    lan = cm.DeviceProfile("lan", 120e12, 800e9, 50e6, 0.004)  # fat LAN
    choice, _ = cm.best_split(spec, edge_dev, cloud)
    assert choice == "edge"
    choice, _ = cm.best_split(spec, edge_dev, lan)
    assert choice == "raw"
    # costs are consistent with the forced-choice table
    costs = cm.split_point_s(spec, edge_dev, cloud)
    assert costs["edge"] == cm.best_split(spec, edge_dev, cloud)[1]
    assert costs["raw"] > costs["edge"]


def test_router_media_pred_shifts_routing():
    """The per-modality media term is folded into the router's latency
    scores: a server behind a thin link loses a task whose media is
    expensive to ship there, and routing is unchanged for media-free
    predictions."""
    from repro.serving.router import QLMIORouter, ServerHandle

    handles = [ServerHandle(f"s{i}", 0, 0, i == 0, execute=lambda t: (1, 1))
               for i in range(2)]
    milp = lambda task, s: 1.0  # latency-equal servers
    mgqp = lambda task, s: 0.9
    spec = cm.media_spec("image", keep_ratio=1 / 3)
    src = cm.DeviceProfile("src", 3e12, 30e9, 12.5e6, 0.004)
    devs = [cm.DeviceProfile("thin", 300e12, 1.5e12, 0.2e6, 0.03),
            cm.DeviceProfile("fat", 120e12, 800e9, 50e6, 0.004)]
    media = lambda task, s: cm.best_split(spec, src, devs[s])[1]
    assert media(0, 0) > media(0, 1) + 0.5  # thin link is markedly worse

    r = QLMIORouter(handles, milp, mgqp, media_pred=media)
    assert r.route(0) == 1
    r0 = QLMIORouter(handles, milp, mgqp)  # no media term: tie -> argmax 0
    assert r0.route(0) == 0
    # the media term lands additively in the effective latency
    np.testing.assert_allclose(
        r._effective_latency(0), [1.0 + media(0, 0), 1.0 + media(0, 1)])


def test_uplink_helper_shared_with_cluster():
    """The analytic model and the live EngineHandle price the link through
    the same helper (no more separately-maintained formulas)."""
    from repro.serving.cluster import EngineHandle
    dev = cm.DEVICES["rtx3090ti"]
    h = EngineHandle("edge-0", "qwen2-0.5b", dev, cm.MODELS["qwen3vl-8b"],
                     payload_bytes=300e3)
    assert h.uplink_s() == pytest.approx(
        float(cm.uplink_s(150e3, dev)))
    assert h.uplink_s() + h.downlink_s() == pytest.approx(
        300e3 / dev.net_bw + dev.rtt)
    # the handle answers the split-point question from the cost model
    spec = cm.media_spec("image", keep_ratio=1 / 3)
    src = cm.DeviceProfile("src", 3e12, 30e9, 12.5e6, 0.004)
    choice, extra = h.split_point(spec, src)
    assert (choice, extra) == cm.best_split(spec, src, dev)
    assert h.split_delay_s(spec, src, choice) == extra
