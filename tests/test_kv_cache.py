"""Paged KV-cache subsystem: pool invariants, prefix trie, CoW/LRU,
paged-decode kernel parity, and end-to-end paged-vs-dense engine equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ops, ref
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import (BlockPool, BlockTable, NULL_PAGE,
                                    OutOfPagesError)

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------- block pool


def test_pool_alloc_free_refcount():
    pool = BlockPool(num_pages=6, block_size=4)
    assert pool.num_free() == 5  # page 0 reserved as null page
    pages = [pool.alloc() for _ in range(5)]
    assert NULL_PAGE not in pages
    assert len(set(pages)) == 5 and pool.num_free() == 0
    with pytest.raises(OutOfPagesError):
        pool.alloc()
    pool.release(pages[2])
    assert pool.num_free() == 1
    p = pool.alloc()
    assert p == pages[2]  # recycled
    pool.release(p)
    with pytest.raises(ValueError):
        pool.release(p)  # double free


def test_pool_shared_refcounts():
    pool = BlockPool(num_pages=4, block_size=4)
    p = pool.alloc()
    pool.retain(p)
    assert pool.ref[p] == 2
    pool.release(p)
    assert pool.ref[p] == 1 and pool.num_free() == 2  # still held
    pool.release(p)
    assert pool.num_free() == 3


def test_block_table_capacity_and_free():
    pool = BlockPool(num_pages=8, block_size=4)
    table = BlockTable(pool)
    table.ensure_capacity(10)  # 3 pages of 4
    assert len(table.pages) == 3
    assert table.slot_of(9) == (table.pages[2], 1)
    used = pool.pages_in_use()
    table.free()
    assert pool.pages_in_use() == used - 3 and table.pages == []


# -------------------------------------------------------------- prefix trie


def test_prefix_lookup_hit_and_partial():
    pool = BlockPool(num_pages=12, block_size=4)
    toks = np.arange(10)  # 2 full blocks + partial
    pages = [pool.alloc() for _ in range(2)]
    pool.register_prefix(toks, pages)
    hit, n = pool.lookup_prefix(toks)
    assert hit == pages and n == 8
    for p in hit:
        assert pool.ref[p] == 2
    # diverging second block: only the first block hits
    other = toks.copy()
    other[5] += 1
    hit2, n2 = pool.lookup_prefix(other)
    assert hit2 == pages[:1] and n2 == 4
    # completely different prompt: miss
    hit3, n3 = pool.lookup_prefix(np.arange(100, 108))
    assert hit3 == [] and n3 == 0


def test_prefix_lru_eviction_drops_trie_entry():
    pool = BlockPool(num_pages=3, block_size=2)  # 2 usable pages
    toks = np.arange(4)
    pages = [pool.alloc() for _ in range(2)]
    pool.register_prefix(toks, pages)
    for p in pages:
        pool.release(p)  # ref 0 -> parked in LRU, still hittable
    hit, n = pool.lookup_prefix(toks)
    assert n == 4
    for p in hit:
        pool.release(p)
    # exhaust the pool: both cached pages must be evicted (LRU first)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == set(pages) and pool.evictions == 2
    hit, n = pool.lookup_prefix(toks)
    assert n == 0  # trie entries dropped with the pages


def test_peek_prefix_has_no_side_effects():
    """Admission-control peeks must not count hits or take references
    (queued requests re-check every tick while waiting for capacity)."""
    pool = BlockPool(num_pages=8, block_size=4)
    toks = np.arange(8)
    pages = [pool.alloc(), pool.alloc()]
    pool.register_prefix(toks, pages)
    for _ in range(5):
        assert pool.peek_prefix(toks) == pages
    assert pool.hits == 0 and pool.misses == 0
    assert all(pool.ref[p] == 1 for p in pages)
    assert pool.peek_prefix(np.arange(100, 104)) == []


def test_cow_on_shared_or_registered_page():
    pool = BlockPool(num_pages=6, block_size=4)
    p = pool.alloc()
    # sole unregistered owner: write in place
    same, copied = pool.ensure_writable(p)
    assert same == p and not copied
    # registered prefix page: must copy even with ref 1
    pool.register_prefix(np.arange(4), [p])
    new, copied = pool.ensure_writable(p)
    assert copied and new != p and pool.cow_copies == 1
    pool.release(p)  # caller releases the original after copying


# ------------------------------------------------------------ kernel parity


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,D,bs,window", [
    (2, 8, 2, 64, 16, 0),    # GQA
    (2, 4, 4, 32, 8, 24),    # MHA + sliding window
    (1, 8, 1, 64, 32, 0),    # MQA
])
def test_paged_decode_kernel_parity(B, H, Hkv, D, bs, window, dtype):
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)
    NB, P = 5, 1 + 2 * B * 5
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dtype)
    kp = jnp.asarray(RNG.normal(size=(P, bs, Hkv, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(P, bs, Hkv, D)), dtype)
    lens = RNG.integers(bs, NB * bs, B)
    bt = np.full((B, NB), -1, np.int32)
    perm = RNG.permutation(np.arange(1, P))
    used = 0
    for b, n in enumerate(lens):
        nb = -(-int(n) // bs)
        bt[b, :nb] = perm[used:used + nb]
        used += nb
    pos = jnp.asarray(lens - 1, jnp.int32)
    bt = jnp.asarray(bt)
    out = ops.paged_decode(q, kp, vp, bt, pos, window=window)
    want = ref.paged_decode_ref(q, kp, vp, bt, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_paged_matches_dense_flash_decode():
    """Gathering pages into a dense cache reproduces flash_decode exactly."""
    B, H, Hkv, D, bs, NB = 2, 4, 2, 32, 8, 4
    P = 1 + B * NB
    q = jnp.asarray(RNG.normal(size=(B, H, D)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(P, bs, Hkv, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(P, bs, Hkv, D)), jnp.float32)
    bt = np.arange(1, 1 + B * NB, dtype=np.int32).reshape(B, NB)
    lens = np.array([NB * bs, NB * bs - 3])
    pos = jnp.asarray(lens - 1, jnp.int32)
    kc = np.asarray(kp)[bt].reshape(B, NB * bs, Hkv, D)
    vc = np.asarray(vp)[bt].reshape(B, NB * bs, Hkv, D)
    cpos = np.broadcast_to(np.arange(NB * bs), (B, NB * bs)).astype(np.int32)
    paged = ops.paged_decode(q, kp, vp, jnp.asarray(bt), pos)
    dense = ref.flash_decode_ref(q, jnp.asarray(kc), jnp.asarray(vc),
                                 jnp.asarray(cpos), pos)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------ engine parity


def _mk(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma3-1b"])
def test_engine_paged_matches_dense(arch):
    """Token-identical outputs on a mixed prompt-length stream, both for
    full attention (qwen2) and local:global windows (gemma3)."""
    cfg, model, params = _mk(arch)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (6, 21, 33, 9, 16)]
    outs = {}
    for paged in (False, True):
        eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                            paged=paged, page_size=8)
        reqs = [Request(i, p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        outs[paged] = {r.uid: tuple(r.output) for r in reqs}
    assert outs[True] == outs[False]


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma3-1b"])
def test_engine_prefix_cache_savings(arch):
    """Shared-prefix workload: later requests skip prefix recomputation and
    still produce the exact dense-engine outputs.  gemma3 exercises the
    sliding-window local:global layers across the prefix/suffix boundary
    of the suffix-only prefill."""
    cfg, model, params = _mk(arch)
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab, 4).astype(np.int32)])
               for _ in range(4)]
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        paged=True, page_size=8)
    reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    total_prompt = sum(len(p) for p in prompts)
    assert eng.prefix_tokens_reused >= 3 * 24  # requests 2-4 reuse 3 blocks
    assert eng.prefill_tokens_computed < total_prompt
    assert eng.pool.hits > 0
    dense = ServingEngine(model, params, max_batch=2, max_seq=64,
                          paged=False)
    dreqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
    for r in dreqs:
        dense.submit(r)
    dense.run_until_drained()
    assert [tuple(r.output) for r in reqs] == \
        [tuple(r.output) for r in dreqs]


def test_engine_cow_on_fully_cached_prompt():
    """An identical repeated prompt exercises the copy-on-write path (last
    prompt token recomputed into a shared page) and matches exactly."""
    cfg, model, params = _mk("qwen2-0.5b")
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)  # 2 full blocks
    eng = ServingEngine(model, params, max_batch=1, max_seq=64,
                        paged=True, page_size=8)
    reqs = [Request(i, prompt.copy(), max_new_tokens=3) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert eng.pool.cow_copies >= 1
    assert tuple(reqs[0].output) == tuple(reqs[1].output)


def test_engine_pages_released_and_reused():
    cfg, model, params = _mk("qwen2-0.5b")
    rng = np.random.default_rng(6)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        paged=True, page_size=8, prefix_caching=False)
    for i in range(6):
        eng.submit(Request(i, rng.integers(0, cfg.vocab, 20).astype(np.int32),
                           max_new_tokens=3))
    eng.run_until_drained()
    assert eng.pool.pages_in_use() == 0  # everything returned to the pool
    assert all(t is None for t in eng.block_tables)


def test_engine_admission_counts_lru_hit_pages():
    """Regression: a prefix hit whose pages are parked in the LRU shrinks
    the allocatable supply when retained; admission must count that or a
    later decode-growth alloc of another active slot crashes mid-stream."""
    cfg, model, params = _mk("qwen2-0.5b")
    rng = np.random.default_rng(8)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        paged=True, page_size=8, num_pages=1 + 7)
    warm = rng.integers(0, cfg.vocab, 32).astype(np.int32)  # 4 full blocks
    eng.submit(Request(0, warm, max_new_tokens=1))
    eng.run_until_drained()  # prefix now parked in the LRU
    # A holds 1 page and will grow by 3; B's prefix hit retains 4 LRU pages
    eng.submit(Request(1, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                       max_new_tokens=24))
    eng.submit(Request(2, np.concatenate(
        [warm, rng.integers(0, cfg.vocab, 6).astype(np.int32)]),
        max_new_tokens=12))
    done = eng.run_until_drained()  # crashed with OutOfPagesError before
    assert {r.uid for r in done} == {1, 2}


def test_engine_paged_rejects_non_attn_family():
    cfg = reduced(get_config("zamba2-2.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServingEngine(model, params, paged=True)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64)
    assert not eng.paged  # auto-falls back to dense
