"""Streaming serving front end + typed submission + event-heap clock.

Covers the ISSUE-8 tentpole: per-token stream output is bit-identical
and in-order vs. drain-based collection (bf16/int8 KV, chunked and
monolithic prefill, across a mid-stream migration), the saxml-style
admission batching knobs on the virtual clock, the frozen
``ContinuumRequest`` submission path (typed submit, legacy-kwarg shim
with ``DeprecationWarning``, router plan annotation), the O(active)
event-heap property (fleet size does not change the charged step count),
and the arrival-process generators feeding the scale-out benchmark.
"""
import math
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.taskgen import (
    diurnal_arrivals,
    poisson_arrivals,
    session_ids,
)
from repro.models import build_model
from repro.serving.cluster import Cluster, SimEngine, build_continuum
from repro.serving.engine import ServingEngine
from repro.serving.request import ContinuumRequest, StreamEvent
from repro.serving.router import QLMIORouter


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return ServingEngine(model, params, **kw)


def _prompt(cfg, n=23, seed=0):
    return np.random.default_rng(seed).integers(1, cfg.vocab, n).astype(
        np.int64)


def _check_stream_shape(events, uid, n_tokens):
    """Per-request stream invariants: contiguous 0-based indices, first /
    final markers exactly once, emission times non-decreasing."""
    evs = [e for e in events if e.uid == uid]
    assert [e.index for e in evs] == list(range(n_tokens))
    assert [e.first for e in evs] == [True] + [False] * (n_tokens - 1)
    assert [e.final for e in evs] == [False] * (n_tokens - 1) + [True]
    ts = [e.t_emit for e in evs]
    assert all(a <= b + 1e-12 for a, b in zip(ts, ts[1:]))
    return evs


# ------------------------------------------- engine-level stream output


@pytest.mark.parametrize("kv_dtype,chunk", [
    ("bf16", 8), ("bf16", 0), ("int8", 8), ("int8", 0)])
def test_stream_bit_identity_vs_drain(qwen, kv_dtype, chunk):
    """The streamed token sequence is exactly the drained one — streaming
    changes *when* tokens are delivered, never *what* is generated."""
    cfg, model, params = qwen
    prompt = _prompt(cfg, seed=3)
    kw = dict(kv_dtype=kv_dtype, prefill_chunk=chunk)

    eng = _engine(model, params, **kw)
    eng.submit(ContinuumRequest(tokens=prompt, max_new_tokens=10))
    base = eng.run_until_drained()[0]

    events = []
    eng2 = _engine(model, params, **kw)
    req = eng2.submit(ContinuumRequest(tokens=prompt, max_new_tokens=10,
                                       stream=events.append))
    done = eng2.run_until_drained()[0]
    assert done.output == base.output
    evs = _check_stream_shape(events, req.uid, len(base.output))
    assert [e.token for e in evs] == list(base.output)
    assert eng2.metrics.counter("stream_tokens").value == len(base.output)
    # drain-only engine streamed nothing
    assert eng.metrics.counter("stream_tokens").value == 0


def test_stream_events_arrive_during_decode(qwen):
    """Tokens are emitted per engine step, not in a burst at drain: after
    each step the stream holds exactly the tokens decoded so far."""
    cfg, model, params = qwen
    eng = _engine(model, params)
    events = []
    req = eng.submit(ContinuumRequest(tokens=_prompt(cfg), max_new_tokens=8,
                                      stream=events.append))
    seen = []
    for _ in range(10_000):
        eng.step()
        assert [e.token for e in events] == list(req.output)
        seen.append(len(events))
        if req.done:
            break
    assert req.done and len(events) == 8
    assert len(set(seen)) > 2  # grew incrementally across steps


def test_stream_multi_token_spec_ticks(qwen):
    """Regression: a speculative engine emits 1..spec_k+1 tokens per
    tick, and every accepted token must still surface as its own
    in-order ``StreamEvent`` (contiguous indices, single first/final) —
    not one event per tick."""
    cfg, model, params = qwen
    prompt = _prompt(cfg, seed=3)

    eng = _engine(model, params)
    eng.submit(ContinuumRequest(tokens=prompt, max_new_tokens=10))
    base = eng.run_until_drained()[0]

    events = []
    spec = _engine(model, params, draft_config=cfg, draft_seed=0,
                   spec_k=3)
    req = spec.submit(ContinuumRequest(tokens=prompt, max_new_tokens=10,
                                       stream=events.append))
    grew = []
    for _ in range(10_000):
        n0 = len(events)
        spec.step()
        grew.append(len(events) - n0)
        assert [e.token for e in events] == list(req.output)
        if req.done:
            break
    assert req.done
    assert req.output == base.output  # speculation never alters tokens
    evs = _check_stream_shape(events, req.uid, len(base.output))
    assert [e.token for e in evs] == list(base.output)
    # some tick really accepted >1 draft: a single step emitted >1 event
    assert spec.stats()["spec_tokens_accepted"] > 0
    assert max(grew) >= 2
    assert spec.metrics.counter("stream_tokens").value == len(evs)


# ----------------------------------------- admission batching knobs


def _vclock_engine(model, params, **kw):
    vt = [0.0]
    eng = _engine(model, params, clock=lambda: vt[0], **kw)
    return eng, vt


def test_batching_wait_holds_partial_group(qwen):
    """With ``sorted_batch_sizes=[2]`` a lone queued request is held —
    admission fires only once it has waited out ``batching_wait_secs`` on
    the (virtual) engine clock."""
    cfg, model, params = qwen
    eng, vt = _vclock_engine(model, params, sorted_batch_sizes=[2],
                             batching_wait_secs=0.5)
    req = eng.submit(ContinuumRequest(tokens=_prompt(cfg),
                                      max_new_tokens=4))
    for _ in range(5):
        eng.step()  # knob-held: no prefill may start
    assert eng.slot_of_request(req.uid) is None and len(req.output) == 0
    assert eng._admission_held
    vt[0] = 0.6  # the wait elapses on the virtual clock
    eng.step()
    assert (eng.slot_of_request(req.uid) is not None
            or len(req.output) > 0)
    while not req.done:
        eng.step()
    assert len(req.output) == 4


def test_full_bucket_admits_immediately(qwen):
    """A queue that covers a bucket is admitted at once — the wait knob
    only delays *partial* groups."""
    cfg, model, params = qwen
    eng, _ = _vclock_engine(model, params, sorted_batch_sizes=[2],
                            batching_wait_secs=1e9)
    r1 = eng.submit(ContinuumRequest(tokens=_prompt(cfg, seed=1),
                                     max_new_tokens=4))
    r2 = eng.submit(ContinuumRequest(tokens=_prompt(cfg, seed=2),
                                     max_new_tokens=4))
    eng.step()
    assert not eng._admission_held
    assert r1.group is not None and r1.group == r2.group
    while not (r1.done and r2.done):
        eng.step()
    assert eng._group_left == {}  # finished groups release their slot


def test_max_live_batches_caps_admission(qwen):
    """``max_live_batches=1``: a second group is not formed until the
    first finishes, even with free decode slots."""
    cfg, model, params = qwen
    eng, _ = _vclock_engine(model, params, max_batch=4,
                            sorted_batch_sizes=[1],
                            max_live_batches=1)
    r1 = eng.submit(ContinuumRequest(tokens=_prompt(cfg, seed=1),
                                     max_new_tokens=6))
    r2 = eng.submit(ContinuumRequest(tokens=_prompt(cfg, seed=2),
                                     max_new_tokens=2))
    eng.step()
    assert eng.slot_of_request(r1.uid) is not None
    assert eng.slot_of_request(r2.uid) is None  # held by the batch cap
    while not r1.done:
        eng.step()
        if not r1.done:
            assert eng.slot_of_request(r2.uid) is None
    while not r2.done:
        eng.step()
    assert len(r2.output) == 2


# ------------------------------------------------ cluster-level streaming


@pytest.fixture(scope="module")
def twin_cluster():
    """Two KV-compatible cloud-class handles sharing weights, so a
    mid-stream migration can be checked for bit-identity."""
    handles = build_continuum([(2, 2)], arch="qwen2-0.5b", param_seed=0,
                              max_seq=64, page_size=8)
    return Cluster(handles, timeout_s=60.0)


def _drain_run(cl, prompt, **kw):
    cl.reset()
    uid = cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=8,
                                     task=0, server=0, **kw))
    cl.drain()
    rec = cl.collect()[0]
    return uid, tuple(cl.records[uid]["req"].output), rec


def test_cluster_stream_iterator_matches_drain(twin_cluster):
    """``stream=True`` + ``Cluster.stream()``: same tokens in emission
    order, ``t_user`` stamped with the streamed chunk's downlink, and the
    record priced by the chunk (cheaper tail than the full downlink)."""
    cl = twin_cluster
    prompt = _prompt(cl.handles[0].cfg, seed=7)
    uid0, base, rec0 = _drain_run(cl, prompt)

    cl.reset()
    uid = cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=8,
                                     task=0, server=0, stream=True))
    events = list(cl.stream(until=60.0))
    rec = [r for r in cl.collect() if r["uid"] == uid][0]

    evs = _check_stream_shape(events, uid, len(base))
    assert tuple(e.token for e in evs) == base
    h = cl.handles[0]
    for e in evs:
        assert e.t_user == pytest.approx(e.t_emit + h.stream_chunk_s)
    assert rec["streamed"] and not rec0.get("streamed")
    # the streamed tail pays one chunk instead of the full downlink
    assert h.stream_chunk_s < h.downlink_s()
    assert rec["e2e_s"] == pytest.approx(
        rec0["e2e_s"] - h.downlink_s() + h.stream_chunk_s)
    assert rec["ttft_s"] == pytest.approx(
        rec0["ttft_s"] - h.downlink_s() + h.stream_chunk_s)


def test_cluster_stream_callback_inline(twin_cluster):
    """A stream *callback* is delivered inline during ``advance_to`` and
    never surfaces in the buffered iterator."""
    cl = twin_cluster
    prompt = _prompt(cl.handles[0].cfg, seed=8)
    events = []
    cl.reset()
    uid = cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=6,
                                     task=0, server=0,
                                     stream=events.append))
    assert list(cl.stream(until=60.0)) == []  # buffer stays empty
    evs = _check_stream_shape(events, uid, 6)
    assert all(isinstance(e, StreamEvent) and e.t_user is not None
               for e in evs)


def test_midstream_migration_streams_contiguously(twin_cluster):
    """A planned prefill-on-0/decode-on-1 handoff mid-stream keeps the
    stream bit-identical and contiguous; post-migration chunks are priced
    by the *destination* handle."""
    cl = twin_cluster
    prompt = _prompt(cl.handles[0].cfg, seed=9)
    _, base, _ = _drain_run(cl, prompt)

    events = []
    cl.reset()
    uid = cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=8,
                                     task=0, server=0, decode_server=1,
                                     stream=events.append))
    cl.drain()
    rec = [r for r in cl.collect() if r["uid"] == uid][0]
    assert cl.records[uid]["server"] == 1  # the handoff really fired
    assert not rec["timeout"]
    evs = _check_stream_shape(events, uid, len(base))
    assert tuple(e.token for e in evs) == base
    h1 = cl.handles[1]
    assert evs[-1].t_user == pytest.approx(
        evs[-1].t_emit + h1.stream_chunk_s)


def test_streamed_ttft_beats_drain_ttft(twin_cluster):
    """Measured TTFT of a streamed request is strictly earlier than the
    drain-collected one whenever a chunk is cheaper than the payload."""
    cl = twin_cluster
    prompt = _prompt(cl.handles[0].cfg, seed=10)
    _, _, rec0 = _drain_run(cl, prompt)
    cl.reset()
    uid = cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=8,
                                     task=0, server=0, stream=True))
    list(cl.stream(until=60.0))
    rec = [r for r in cl.collect() if r["uid"] == uid][0]
    assert rec["ttft_s"] < rec0["ttft_s"]


# --------------------------------------------- typed submission surface


def test_continuum_request_frozen_roundtrip():
    import dataclasses
    creq = ContinuumRequest(tokens=np.arange(4), max_new_tokens=5, task=3)
    with pytest.raises(dataclasses.FrozenInstanceError):
        creq.server = 1
    planned = creq.with_plan(server=2, decode_server=None,
                             predicted_s=0.25, utility=1.5)
    assert planned is not creq and creq.server is None
    assert (planned.server, planned.predicted_s, planned.utility) \
        == (2, 0.25, 1.5)
    assert planned.max_new_tokens == 5 and planned.task == 3


def test_legacy_submit_kwargs_warn(twin_cluster):
    cl = twin_cluster
    prompt = _prompt(cl.handles[0].cfg, seed=12)
    cl.reset()
    with pytest.warns(DeprecationWarning, match="ContinuumRequest"):
        cl.submit(0, task=0, tokens=prompt, max_new_tokens=2)
    # the typed form is warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=2,
                                   task=0, server=0))
    cl.drain()
    assert len(cl.collect()) == 2


def test_submit_requires_plan(twin_cluster):
    cl = twin_cluster
    cl.reset()
    with pytest.raises(ValueError, match="server is unset"):
        cl.submit(ContinuumRequest(tokens=np.arange(1, 5),
                                   max_new_tokens=2))


def test_router_plan_annotates_request(twin_cluster):
    """``QLMIORouter.plan`` on a typed request returns an annotated copy:
    dispatch target + predicted seconds + utility, original untouched."""
    cl = twin_cluster
    router = QLMIORouter(list(cl.handles), lambda t, s: 1.0,
                         lambda t, s: 0.9)
    creq = ContinuumRequest(tokens=np.arange(1, 9), max_new_tokens=4,
                            task=0)
    planned = router.plan(creq)
    assert isinstance(planned, ContinuumRequest)
    assert creq.server is None and creq.predicted_s is None
    assert planned.server in (0, 1)
    assert planned.predicted_s is not None
    assert math.isfinite(planned.predicted_s)
    assert planned.utility is not None
    # the annotated request is directly submittable
    cl.reset()
    uid = cl.submit(planned)
    cl.drain()
    rec = [r for r in cl.collect() if r["uid"] == uid][0]
    assert rec["server"] == planned.server
    assert rec["predicted_s"] == pytest.approx(planned.predicted_s)


# ------------------------------------------------- O(active) event heap


def _sim_fleet(n_edge):
    handles = build_continuum([(0, n_edge), (2, 2)], backend="sim",
                              max_batch=2, max_seq=64)
    return Cluster(handles)


def _replay_probe(cl, n=40):
    rng = np.random.default_rng(5)
    for k in range(n):
        cl.submit(ContinuumRequest(
            tokens=rng.integers(1, 100, 12).astype(np.int32),
            max_new_tokens=4, arrival_s=0.05 * k, task=k,
            server=int(k % 2)))  # only engines 0 and 1 ever see work
    cl.drain()
    recs = cl.collect()
    assert len(recs) == n and not any(r["timeout"] for r in recs)
    return recs, cl.handle_steps, cl.heap_pops


def test_oactive_steps_independent_of_fleet_size():
    """The event heap charges work only for engines with events: the same
    trace over the same two engines costs the same handle steps on a
    4-engine and a 64-engine fleet, and identical measured records."""
    small, s_steps, s_pops = _replay_probe(_sim_fleet(2))
    large, l_steps, l_pops = _replay_probe(_sim_fleet(62))
    assert s_steps == l_steps > 0
    key = ["uid", "server", "e2e_s", "ttft_s", "n_tokens"]
    assert ([{k: r[k] for k in key} for r in small]
            == [{k: r[k] for k in key} for r in large])
    # heap traffic stays linear in events, not fleet size
    assert l_pops <= s_pops + 2 * 64


def test_sim_engine_matches_metric_names():
    """SimEngine is a stats-compatible stand-in: the counter/latency keys
    the benchmarks read exist under the same names."""
    eng = SimEngine(vocab=100, max_batch=2, max_seq=32)
    eng.submit(ContinuumRequest(tokens=np.arange(1, 10),
                                max_new_tokens=4))
    eng.run_until_drained()
    st = eng.stats()
    assert st["sim"] is True
    for k in ("requests_submitted", "requests_finished", "decode_tokens",
              "prefill_tokens_computed", "prefix_tokens_reused"):
        assert k in st, k  # same flat registry keys as ServingEngine
    lat = eng.latency_stats()
    assert lat["n_requests"] == 1
    assert lat["ttft_p50_s"] >= 0 and lat["e2e_p95_s"] > 0


# ------------------------------------------------- arrival processes


def test_poisson_arrivals_rate_and_monotonicity():
    t = poisson_arrivals(20_000, rate_per_s=50.0, seed=1)
    assert len(t) == 20_000
    assert np.all(np.diff(t) > 0)
    assert float(np.diff(t).mean()) == pytest.approx(1 / 50.0, rel=0.05)
    # deterministic per seed
    np.testing.assert_array_equal(t, poisson_arrivals(20_000, 50.0, seed=1))
    assert not np.array_equal(t, poisson_arrivals(20_000, 50.0, seed=2))


def test_diurnal_arrivals_modulate_rate():
    period = 60.0
    t = diurnal_arrivals(40_000, rate_per_s=40.0, period_s=period, seed=3)
    assert np.all(np.diff(t) > 0)
    phase = (t % period) / period
    # thinning concentrates arrivals at the peak of the sinusoid: the
    # busiest phase quartile must clearly out-draw the quietest
    counts = np.histogram(phase, bins=4)[0]
    assert counts.max() > 1.5 * counts.min()


def test_session_ids_shape():
    s = session_ids(5_000, n_sessions=37, seed=4)
    assert s.shape == (5_000,)
    assert s.min() >= 0 and s.max() < 37
    # concentration skews traffic: some sessions are much hotter
    counts = np.bincount(s, minlength=37)
    assert counts.max() > 3 * max(counts.min(), 1)
