"""Bucketed + chunked prefill scheduler: compile-count regression, chunked
vs. whole-prompt token identity on both cache backends, EOS/budget honored
at admission, prompt-length validation, and the cost-model chunking term."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine, bucket_length
from repro.sim import cost_model as cm


def _rng(seed=11):
    # per-test generators: prompt draws must not depend on test order
    # (argmax outputs are compared across differently-shaped computation
    # graphs, so tests pin seeds whose logits are not near-ties)
    return np.random.default_rng(seed)


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _serve(model, params, prompts, *, max_new_tokens=4, **kw):
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, **kw)
    reqs = [Request(i, p, max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, [tuple(r.output) for r in reqs]


# ----------------------------------------------------------------- buckets


def test_bucket_length():
    assert bucket_length(1) == 16  # minimum
    assert bucket_length(16) == 16
    assert bucket_length(17) == 32
    assert bucket_length(33) == 64
    assert bucket_length(60, maximum=64) == 64  # clamped
    assert bucket_length(5, minimum=4) == 8
    with pytest.raises(ValueError):
        bucket_length(0)
    with pytest.raises(ValueError):
        bucket_length(100, maximum=64)  # caller must validate upstream


def test_chunked_prefill_tokens_cost_model():
    # monolithic bucketing: pure power-of-two step function
    assert cm.bucketed_tokens(1) == 16 and cm.bucketed_tokens(17) == 32
    np.testing.assert_allclose(cm.chunked_prefill_tokens([5, 40], 0),
                               [16.0, 64.0])
    # chunked: full chunks + bucketed remainder
    assert cm.chunked_prefill_tokens(64, 16) == 64  # exact chunks, no pad
    assert cm.chunked_prefill_tokens(70, 16) == 64 + 16  # remainder 6 -> 16
    assert cm.chunked_prefill_tokens(95, 16) == 80 + 16  # remainder 15 -> 16
    # the chunked engine never computes fewer positions than the prompt
    t = np.arange(1, 200)
    assert (cm.chunked_prefill_tokens(t, 16) >= t).all()
    # and the latency estimate reflects it (step function >= smooth line)
    dev, mdl = cm.DEVICES["rtx5090"], cm.MODELS["qwen3vl-8b"]
    assert cm.prefill_s(dev, mdl, 70, prefill_chunk=16) > \
        cm.prefill_s(dev, mdl, 70)


# ----------------------------------------------- compile-count regression


@pytest.mark.parametrize("paged", [False, True])
def test_prefill_trace_count_bounded(qwen, paged):
    """8 requests with 8 distinct prompt lengths must not trace 8 prefill
    variants: traces are bounded by the bucket count (here: one chunk
    bucket), where the legacy path compiled once per length."""
    cfg, model, params = qwen
    lens = [3, 7, 12, 19, 26, 38, 47, 60]
    rng = _rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]
    eng, _ = _serve(model, params, prompts, paged=paged, prefill_chunk=16)
    buckets = {bucket_length(min(n, 16), maximum=16) for n in lens}
    assert eng.prefill_trace_count() <= len(buckets) < len(lens)
    # ground truth from jax when available: actual XLA traces of the
    # chunked prefill entry point stay within the bucket count
    sizes = eng.jit_cache_sizes()
    if "_prefill_chunk" in sizes:
        assert sizes["_prefill_chunk"] <= len(buckets)
    assert sizes.get("_prefill", 0) == 0  # monolithic path never used


def test_bucketed_monolithic_trace_count(qwen):
    cfg, model, params = qwen
    lens = [3, 7, 12, 19, 26, 38, 47, 60]
    rng = _rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]
    eng, _ = _serve(model, params, prompts, paged=True, prefill_chunk=0)
    buckets = {bucket_length(n, maximum=64) for n in lens}  # {16, 32, 64}
    assert eng.prefill_trace_count() <= len(buckets) < len(lens)


# ------------------------------------------------------- token identity


@pytest.mark.parametrize("paged", [False, True])
def test_chunked_matches_whole_prompt(qwen, paged):
    """Chunked prefill must be token-identical to whole-prompt prefill —
    and to the pre-change exact-shape path — on both cache backends."""
    cfg, model, params = qwen
    lens = (4, 9, 17, 26, 40, 61)
    rng = _rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]
    _, chunked = _serve(model, params, prompts, paged=paged,
                        prefill_chunk=8)
    _, whole = _serve(model, params, prompts, paged=paged, prefill_chunk=0)
    _, legacy = _serve(model, params, prompts, paged=paged,
                       prefill_chunk=0, bucket_prompts=False)
    assert chunked == whole == legacy


def test_chunked_prefix_cache_identity(qwen):
    """Chunked prefill over a prefix-cache hit (the chunk path starts past
    the reused pages) stays identical to the cold path."""
    cfg, model, params = qwen
    rng = _rng(4)
    shared = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab, 5).astype(np.int32)])
               for _ in range(3)]
    eng, warm = _serve(model, params, prompts, paged=True, page_size=8,
                       prefill_chunk=8)
    # the request admitted while the first was mid-prefill only hits the
    # blocks registered so far; the later one reuses the full 24 tokens
    assert eng.prefix_tokens_reused >= 24
    _, cold = _serve(model, params, prompts, paged=True, page_size=8,
                     prefill_chunk=8, prefix_caching=False)
    assert warm == cold


# ------------------------------------------------- admission-time EOS/budget


@pytest.mark.parametrize("paged", [False, True])
def test_max_new_tokens_one_finishes_at_admission(qwen, paged):
    """A max_new_tokens=1 request must emit exactly one token (the prefill
    sample) instead of decoding past its budget."""
    cfg, model, params = qwen
    prompt = _rng(1).integers(0, cfg.vocab, 9).astype(np.int32)
    eng, outs = _serve(model, params, [prompt], max_new_tokens=1,
                       paged=paged)
    assert len(outs[0]) == 1
    assert all(s is None for s in eng.slots)
    if paged:
        assert all(t is None for t in eng.block_tables)


@pytest.mark.parametrize("paged", [False, True])
def test_eos_at_admission_finishes_immediately(qwen, paged):
    """A request whose *first* prefill-sampled token is eos_id must finish
    at admission, not decode its full budget."""
    cfg, model, params = qwen
    prompt = _rng(1).integers(0, cfg.vocab, 9).astype(np.int32)
    _, outs = _serve(model, params, [prompt], max_new_tokens=8, paged=paged)
    first = outs[0][0]
    eng, outs = _serve(model, params, [prompt], max_new_tokens=8,
                       paged=paged, eos_id=first)
    assert outs[0] == (first,)
    assert eng.ticks == 0  # no decode step ever ran


# --------------------------------------------------- prompt-length guard


@pytest.mark.parametrize("paged", [False, True])
def test_too_long_prompt_rejected_at_submit(qwen, paged):
    """Prompts that cannot fit used to crash deep in the splice/scatter
    path with a cryptic negative-pad / out-of-range error; submit() now
    rejects them with an actionable message."""
    cfg, model, params = qwen
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, paged=paged)
    rng = _rng(2)
    long_prompt = rng.integers(0, cfg.vocab, 70).astype(np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(0, long_prompt))
    boundary = rng.integers(0, cfg.vocab, 64).astype(np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(1, boundary))  # no room for a generated token
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(2, np.zeros(0, np.int32)))
    ok = Request(3, rng.integers(0, cfg.vocab, 63).astype(np.int32),
                 max_new_tokens=2)
    eng.submit(ok)
    eng.run_until_drained()
    assert ok.done


# ----------------------------------------------- pool pressure (chunked)


def test_chunked_admission_counts_mid_prefill_growth(qwen):
    """Regression: admission control must count the decode-growth horizon
    of slots still mid-chunked-prefill (tracked in prefill_tasks, not
    slots) — otherwise a small pool over-admits and a promoted request's
    decode-time ensure_capacity crashes mid-stream."""
    cfg, model, params = qwen
    rng = _rng(7)
    eng = ServingEngine(model, params, max_batch=2, max_seq=16,
                        paged=True, page_size=4, num_pages=6,
                        prefill_chunk=4, prefill_budget=4,
                        prefix_caching=False)
    # A is mid-prefill (2 chunks) when B's admission check runs; B is small
    # enough to fit unless A's remaining growth (2 pages) is counted, and
    # long-lived enough to hold its pages while A crosses page boundaries
    eng.submit(Request(0, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                       max_new_tokens=8))
    eng.submit(Request(1, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                       max_new_tokens=8))
    done = eng.run_until_drained()  # must not raise OutOfPagesError
    assert len(done) == 2 and all(len(r.output) >= 1 for r in done)
    assert eng.pool.pages_in_use() == 0


def test_monolithic_prefix_hit_traces_bounded(qwen):
    """Regression: on the monolithic path the reused-prefix length is a
    shape dim of prefill_with_prefix, so hits are rounded down to
    power-of-two page counts — a shared-prefix mixed-length workload must
    not retrace per distinct hit length."""
    cfg, model, params = qwen
    rng = _rng(9)
    shared = rng.integers(0, cfg.vocab, 48).astype(np.int32)
    # distinct total lengths -> distinct unclipped hit lengths
    prompts = [shared[:n] for n in (9, 17, 25, 33, 41, 47)] + [
        np.concatenate([shared[:40],
                        rng.integers(0, cfg.vocab, 3).astype(np.int32)])]
    eng, _ = _serve(model, params, prompts, paged=True, page_size=4,
                    prefill_chunk=0)
    sfx_variants = {t for t in eng._traced if t[0] == "prefill_sfx"}
    prefixes = {t[1] for t in sfx_variants}
    # reused prefix lengths are powers of two pages: {4, 8, 16, 32}
    assert all(p % 4 == 0 and (p // 4) & (p // 4 - 1) == 0
               for p in prefixes)
    assert eng.prefill_trace_count() < len(prompts) + 2
    assert eng.prefix_tokens_reused > 0


# ------------------------------------------------------- latency metrics


def test_latency_stats_populated(qwen):
    cfg, model, params = qwen
    rng = _rng(5)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 30)]
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        prefill_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=4))
    eng.run_until_drained(keep_finished=True)
    lat = eng.latency_stats()
    assert lat["n_requests"] == 2
    assert lat["ttft_p95_s"] > 0 and lat["itl_p50_s"] >= 0
    st = eng.stats()
    assert st["chunked"] and st["bucketed"]
    assert st["prefill_tokens_computed"] == sum(len(p) for p in prompts)
