import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# host-platform mesh for the tensor-parallel serving tests (the dry-run
# subprocess sets its own XLA_FLAGS; CI's multi-device job inherits this)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
