import os
import sys

# keep tests on 1 device (the dry-run subprocess sets its own XLA_FLAGS)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
