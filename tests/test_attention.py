"""Flash attention (pure-JAX lowering path) vs O(S^2) reference, fwd + bwd,
plus hypothesis property tests on the streaming-softmax invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.attention import (decode_attention, flash_attention,
                                    reference_attention)

RNG = np.random.default_rng(7)


def _qkv(B, Sq, Sk, H, Hkv, D, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Sk, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Sk, Hkv, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D,causal,window", [
    (2, 128, 128, 4, 2, 32, True, 0),
    (2, 100, 100, 4, 4, 16, True, 24),
    (1, 64, 256, 4, 1, 32, True, 0),
    (2, 60, 90, 2, 2, 16, False, 0),
])
def test_flash_fwd_bwd_vs_reference(B, Sq, Sk, H, Hkv, D, causal, window):
    q, k, v = _qkv(B, Sq, Sk, H, Hkv, D)
    f = lambda q, k, v: flash_attention(q, k, v, causal=causal,
                                        window=window, chunk_q=32, chunk_k=48)
    r = lambda q, k, v: reference_attention(q, k, v, causal=causal,
                                            window=window)
    np.testing.assert_allclose(np.asarray(f(q, k, v)),
                               np.asarray(r(q, k, v)), atol=1e-5, rtol=1e-5)
    g1 = jax.grad(lambda *a: (f(*a) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (r(*a) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    seq=st.integers(8, 96),
    heads=st.sampled_from([(2, 1), (2, 2), (4, 2)]),
    chunk=st.integers(8, 64),
    causal=st.booleans(),
)
def test_flash_chunk_invariance(seq, heads, chunk, causal):
    """Property: the result must not depend on the chunking."""
    H, Hkv = heads
    rng = np.random.default_rng(seq * 1000 + chunk)
    q = jnp.asarray(rng.normal(size=(1, seq, H, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, seq, Hkv, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, seq, Hkv, 16)), jnp.float32)
    a = flash_attention(q, k, v, causal=causal, chunk_q=chunk, chunk_k=chunk)
    b = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.1, 100.0))
def test_flash_scale_invariance_of_softmax(scale):
    """Property: softmax normalization — outputs are convex combinations of
    v rows, so outputs lie within [min(v), max(v)] per dim."""
    rng = np.random.default_rng(int(scale * 10))
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8)) * scale, jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    o = flash_attention(q, k, v, causal=True, chunk_q=16, chunk_k=16)
    assert np.isfinite(np.asarray(o)).all()
    assert np.asarray(o).max() <= float(v.max()) + 1e-4
    assert np.asarray(o).min() >= float(v.min()) - 1e-4


def test_decode_matches_full_attention():
    B, S, H, Hkv, D = 2, 48, 4, 2, 16
    q, k, v = _qkv(B, 1, S, H, Hkv, D)
    cpos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    o1 = decode_attention(q[:, 0], k, v, cpos, pos)
    o2 = reference_attention(q, k, v, causal=True)[:, 0]
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-5)


def test_decode_respects_window():
    B, S, H, D = 1, 32, 2, 8
    q, k, v = _qkv(B, 1, S, H, H, D)
    cpos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    o_win = decode_attention(q[:, 0], k, v, cpos, pos, window=8)
    # equivalent: zero out the cache beyond the window
    o_ref = reference_attention(q, k, v, causal=True, window=8)[:, 0]
    np.testing.assert_allclose(np.asarray(o_win), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)
