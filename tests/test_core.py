"""Paper-core behaviour: predictors learn, losses are correct, the QLMIO
agent improves over random, the simulator is deterministic and calibrated."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.d3qn import D3QNConfig
from repro.core.feature_store import compute_features
from repro.core.predictors import (Predictor, PredictorConfig, focal_loss,
                                   huber_loss)
from repro.core.qlmio import QLMIO, QLMIOConfig
from repro.data.taskgen import splits
from repro.sim.cemllm import greedy_latencies, make_servers
from repro.sim.miobench import SERVER_CLASSES, generate, summary


@pytest.fixture(scope="module")
def small_world():
    bench = generate(seed=0, n_tasks=300)
    f_img, f_text = compute_features(bench.tasks, profile="tiny",
                                     cache_dir=None)
    tr, va, te = splits(bench.tasks.n)
    return bench, (f_img, f_text), (tr, va, te)


def _flat(bench, f_text, f_img, ids):
    C = len(SERVER_CLASSES)
    t = np.repeat(ids, C)
    c = np.tile(np.arange(C), len(ids))
    return {"f_text": f_text[t], "f_img": f_img[t],
            "model_id": bench.model_id[c], "device_id": bench.device_id[c],
            "label": (bench.score[t, c] == 1).astype(np.int64),
            "latency_s": bench.latency_s[t, c].astype(np.float32)}


def test_focal_loss_matches_ce_at_gamma0():
    logits = jnp.asarray([[2.0, -1.0], [-0.5, 1.5]])
    labels = jnp.asarray([0, 1])
    fl = focal_loss(logits, labels, alpha=0.5, gamma=0.0)
    ce = -jax.nn.log_softmax(logits)[jnp.arange(2), labels].mean() * 0.5
    np.testing.assert_allclose(float(fl), float(ce), rtol=1e-5)


def test_huber_quadratic_then_linear():
    assert float(huber_loss(jnp.asarray([0.5]), jnp.asarray([0.0]))) == \
        pytest.approx(0.125)
    assert float(huber_loss(jnp.asarray([3.0]), jnp.asarray([0.0]))) == \
        pytest.approx(2.5)


def test_predictors_learn(small_world):
    bench, (f_img, f_text), (tr, va, te) = small_world
    cfg = PredictorConfig(epochs=6, batch=128)
    mgqp = Predictor("quality", 8, 8, cfg, feat_dim=f_text.shape[1])
    hist = mgqp.fit(_flat(bench, f_text, f_img, tr),
                    _flat(bench, f_text, f_img, va))
    # learning: focal loss drops and accuracy is well above chance (the
    # paper-fidelity accuracy target lives in benchmarks/fig6, which uses
    # the full "fast" encoder profile and 50 epochs)
    assert hist[-1]["train_loss"] < hist[0]["train_loss"]
    assert hist[-1]["train_acc"] > 0.55
    milp = Predictor("latency", 8, 8, cfg, feat_dim=f_text.shape[1])
    hist = milp.fit(_flat(bench, f_text, f_img, tr),
                    _flat(bench, f_text, f_img, va))
    # MAE must beat predicting the global mean
    lat = bench.latency_s[tr].reshape(-1)
    base_mae = np.abs(lat - lat.mean()).mean()
    assert hist[-1]["train_mae_s"] < base_mae


def test_miobench_calibration():
    s = summary(generate(seed=0))  # full 3,377 tasks (matches benchmarks)
    j = s["jetson_orin_nano"]
    assert 0.55 < j["accuracy"] < 0.75  # paper: 66.7 %
    assert 0.18 < j["timeout_rate"] < 0.35  # paper: 26.3 %
    c = s["rtx5090"]
    assert c["accuracy"] > 0.85 and c["timeout_rate"] == 0.0
    assert c["latency_p95_s"] < 10.0  # paper Fig. 1(b)


def test_miobench_deterministic():
    a = generate(seed=3, n_tasks=100)
    b = generate(seed=3, n_tasks=100)
    np.testing.assert_array_equal(a.latency_s, b.latency_s)
    np.testing.assert_array_equal(a.score, b.score)


def test_greedy_latency_is_reasonable(small_world):
    bench, _, (tr, _, _) = small_world
    servers = make_servers(5, bench)
    tg = greedy_latencies(bench, servers, tr[:20])
    assert (tg > 0).all()


def test_qlmio_trains_and_beats_random(small_world):
    bench, features, (tr, va, te) = small_world
    servers = make_servers(5, bench)
    zeros = np.zeros((bench.tasks.n, len(SERVER_CLASSES)), np.float32)
    # oracle predictions (perfect MILP/MGQP) keep this test fast + stable
    milp_preds = bench.latency_s.astype(np.float32)
    mgqp_preds = (bench.score == 1).astype(np.float32)
    cfg = QLMIOConfig(episodes=40, users=10, seed=0,
                      agent=D3QNConfig(eps_decay_steps=250, batch=64))
    q = QLMIO(bench, servers, features, milp_preds, mgqp_preds, cfg)
    hist = q.train(tr)
    res = q.evaluate(te, trials=3)
    heur = B.evaluate_heuristics(bench, servers, te, 10, 3)
    assert res["avg_reward"] > heur["random"]["avg_reward"]
    assert res["completion_rate"] > heur["random"]["completion_rate"]
    # learning happened
    assert np.mean([h["avg_reward"] for h in hist[-10:]]) > \
        np.mean([h["avg_reward"] for h in hist[:10]])


def test_qlmio_ablation_state_shapes(small_world):
    bench, features, (tr, _, _) = small_world
    servers = make_servers(5, bench)
    zeros = np.zeros((bench.tasks.n, len(SERVER_CLASSES)), np.float32)
    for kw in [dict(use_milp=False), dict(use_mgqp=False),
               dict(use_milp=False, use_mgqp=False),
               dict(use_task_features=False, use_milp=False,
                    use_mgqp=False)]:
        cfg = QLMIOConfig(episodes=2, users=5, seed=0, **kw)
        q = QLMIO(bench, servers, features, zeros, zeros, cfg)
        q.train(tr)  # must run without error


def test_failure_injection_reroutes():
    """A failed server makes every task on it time out — the fault-tolerance
    hook the serving layer keys off."""
    bench = generate(seed=1, n_tasks=60)
    servers = make_servers(5, bench)
    from repro.sim.cemllm import Episode
    failed = np.zeros(servers.n, bool)
    failed[0] = True
    ep = Episode(bench, servers, np.arange(10), np.random.default_rng(0),
                 failed=failed)
    rec = ep.step(0)
    assert not rec["success"] and rec["timeout"]
