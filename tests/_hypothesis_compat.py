"""Optional-hypothesis shim (satellite of ISSUE 1).

``pytest.importorskip("hypothesis")`` at module scope would skip entire
test modules; these stand-ins instead make only the ``@given`` property
tests skip at runtime when the dependency is absent, so the plain tests
in the same module still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<strategy>(...)`` call and returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            # plain (*args, **kwargs) signature so pytest does not treat
            # the hypothesis-bound parameters as fixtures
            def stub(*args, **kwargs):
                pytest.skip("hypothesis not installed")

            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco
