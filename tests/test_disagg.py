"""Disaggregated prefill/decode: KV snapshot export/import correctness
(bit-identical cross-engine resume under bf16/int8 and chunked/monolithic
prefill), refcount/CoW integrity of in-flight snapshots, prefix-trie
re-registration on the receiving pool, destination-priced migration cost
(int8 tiers pay ~half), cluster-level charged transfers with ``kv_migrate``
spans, the backlog-triggered rebalance policy, and the router's third
dispatch shape (prefill-here/decode-there)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving.cluster import Cluster, build_continuum
from repro.serving.engine import Request, ServingEngine
from repro.serving.request import ContinuumRequest
from repro.serving.kv_cache import ceil_blocks, full_blocks
from repro.serving.router import QLMIORouter, ServerHandle
from repro.serving.telemetry import Telemetry
from repro.sim import cost_model as cm


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return ServingEngine(model, params, **kw)


def _prompt(cfg, n=23, seed=0):
    return np.random.default_rng(seed).integers(1, cfg.vocab, n).astype(
        np.int64)


def _decode_until(eng, uid, min_tokens):
    """Step until request ``uid`` is in a decode slot with at least
    ``min_tokens`` output tokens."""
    req = None
    for _ in range(10_000):
        slot = eng.slot_of_request(uid)
        if slot is not None:
            req = eng.slots[slot]
            if len(req.output) >= min_tokens:
                return req
        eng.step()
    raise AssertionError(f"request {uid} never reached {min_tokens} tokens")


# ------------------------------------------------- bit-identical resume


@pytest.mark.parametrize("kv_dtype,chunk", [
    ("bf16", 8), ("bf16", 0), ("int8", 8), ("int8", 0)])
def test_migrate_bit_identity(qwen, kv_dtype, chunk):
    """Prefill on engine A, decode on engine B: greedy tokens match the
    single-engine run exactly, with no prefill pass on B — for both KV
    precisions, chunked and monolithic prefill, and both a fresh import
    and a re-import whose prompt blocks already sit in B's trie."""
    cfg, model, params = qwen
    prompt = _prompt(cfg)
    B = _engine(model, params, kv_dtype=kv_dtype, prefill_chunk=chunk)
    base_req = Request(0, prompt.copy(), max_new_tokens=12)
    B.submit(base_req)
    B.run_until_drained()
    base = tuple(base_req.output)
    assert len(base) == 12
    B.reset_prefix_cache()  # cold trie: the import must carry everything

    A = _engine(model, params, kv_dtype=kv_dtype, prefill_chunk=chunk)
    js = (1, 4) if (kv_dtype == "bf16" and chunk) else (1,)
    for uid, j in enumerate(js, start=1):
        req = Request(uid, prompt.copy(), max_new_tokens=12)
        A.submit(req)
        _decode_until(A, uid, j)
        pc_before = B.prefill_tokens_computed
        moved, snap = A.evacuate(uid)
        assert moved is req and req.imported is snap
        assert A.slot_of_request(uid) is None
        assert snap.kv_dtype == kv_dtype
        assert snap.num_tokens == len(prompt) + len(req.output) - 1
        B.submit(req)
        B.run_until_drained()
        assert tuple(req.output) == base
        # decode-phase admission: B never ran a prefill pass
        assert B.prefill_tokens_computed == pc_before
    # export/import byte accounting moved real pages
    assert A.metrics.counter("kv_exported_pages").value > 0
    assert B.metrics.counter("kv_imported_pages").value > 0
    assert (A.metrics.counter("kv_export_bytes").value
            == A.metrics.counter("kv_exported_pages").value * A.page_bytes())


def test_midstream_resume_exact_token(qwen):
    """Evacuation after j decoded tokens resumes at exactly output[-1]:
    the destination produces precisely the remaining tokens."""
    cfg, model, params = qwen
    prompt = _prompt(cfg, seed=3)
    B = _engine(model, params)
    base_req = Request(0, prompt.copy(), max_new_tokens=10)
    B.submit(base_req)
    B.run_until_drained()
    base = tuple(base_req.output)
    B.reset_prefix_cache()

    A = _engine(model, params)
    req = Request(1, prompt.copy(), max_new_tokens=10)
    A.submit(req)
    _decode_until(A, 1, 4)
    A.evacuate(1)
    j = len(req.output)
    assert tuple(req.output) == base[:j]
    d0 = B.metrics.counter("decode_tokens").value
    B.submit(req)
    B.run_until_drained()
    assert tuple(req.output) == base
    assert B.metrics.counter("decode_tokens").value - d0 == len(base) - j


# ------------------------------------------- snapshot / pool integrity


def test_snapshot_survives_source_eviction(qwen):
    """An in-flight snapshot is a self-contained host copy: churning the
    source pool (eviction + page reuse) after export cannot corrupt it,
    and export itself leaks no refcounts."""
    cfg, model, params = qwen
    prompt = _prompt(cfg, seed=5)
    B = _engine(model, params)
    base_req = Request(0, prompt.copy(), max_new_tokens=8)
    B.submit(base_req)
    B.run_until_drained()
    base = tuple(base_req.output)
    B.reset_prefix_cache()

    # tiny pool so the churn below recycles the evacuated request's pages
    A = _engine(model, params, num_pages=1 + 2 * ceil_blocks(64, 8))
    req = Request(1, prompt.copy(), max_new_tokens=8)
    A.submit(req)
    _decode_until(A, 1, 2)
    ref_before = list(A.pool.ref)
    snap = A.export_kv(1)
    assert list(A.pool.ref) == ref_before  # refs held then fully released
    k_before = {n: v.copy() for n, v in snap.leaves.items()}
    A.evacuate(1)
    for uid in range(2, 6):  # churn: unrelated prompts recycle the pages
        other = Request(uid, _prompt(cfg, n=31, seed=100 + uid),
                        max_new_tokens=8)
        A.submit(other)
    A.run_until_drained()
    assert A.pool.stats()["evictions"] > 0 or A.pool.pages_in_use() == 0
    for name, v in snap.leaves.items():
        np.testing.assert_array_equal(v, k_before[name])
    B.submit(req)
    B.run_until_drained()
    assert tuple(req.output) == base


def test_prefix_reregistration_gives_receiver_hits(qwen):
    """Importing a snapshot re-registers its prompt blocks in the
    receiving pool's trie: a later same-prompt request on the receiver
    reuses them without recomputation."""
    cfg, model, params = qwen
    prompt = _prompt(cfg, n=24, seed=7)  # 3 exact pages
    A = _engine(model, params)
    B = _engine(model, params)
    req = Request(1, prompt.copy(), max_new_tokens=8)
    A.submit(req)
    _decode_until(A, 1, 1)
    A.evacuate(1)
    B.submit(req)
    B.run_until_drained()
    first = tuple(req.output)
    assert B.prefix_tokens_reused == 0  # cold import, nothing local yet

    again = Request(2, prompt.copy(), max_new_tokens=8)
    B.submit(again)
    B.run_until_drained()
    assert tuple(again.output) == first
    assert B.prefix_tokens_reused > 0
    assert B.pool.stats()["prefix_hits"] > 0


def test_import_validation(qwen):
    """Geometry/page-size mismatches and non-mid-decode requests are
    rejected at submit; export demands a decode-phase request."""
    cfg, model, params = qwen
    A = _engine(model, params)
    req = Request(1, _prompt(cfg), max_new_tokens=8)
    A.submit(req)
    with pytest.raises(ValueError, match="not in decode phase"):
        A.export_kv(1)  # still queued
    _decode_until(A, 1, 1)
    _, snap = A.evacuate(1)

    wrong_ps = _engine(model, params, page_size=16)
    with pytest.raises(ValueError, match="page_size"):
        wrong_ps.submit(req)
    done = Request(2, _prompt(cfg), max_new_tokens=8, output=[1, 2])
    done.imported = snap
    done.done = True
    B = _engine(model, params)
    with pytest.raises(ValueError, match="mid-decode"):
        B.submit(done)


# --------------------------------------------------- migration pricing


def test_int8_destination_halves_migrate_cost():
    """Satellite: migration is priced at the destination's kv_dtype, so
    an int8 edge tier receives ~half the bytes (and, bytes-dominated,
    ~half the time) a bf16 destination would."""
    prof = cm.MODELS["qwen3vl-30b"]
    src, dst = cm.DEVICES["rtx5090"], cm.DEVICES["jetson_orin_nano"]
    n = 4096
    b_bf16 = cm.kv_migrate_bytes(prof, n, "bf16")
    b_int8 = cm.kv_migrate_bytes(prof, n, "int8")
    L, hkv, dh = prof.kv_layout
    assert b_bf16 == n * 2 * L * hkv * dh * 2
    assert b_bf16 / b_int8 > 1.5  # 2x values, minus the fp32 scale rows
    t_bf16 = cm.migrate_s(prof, n, src, dst, kv_dtype="bf16")
    t_int8 = cm.migrate_s(prof, n, src, dst, kv_dtype="int8")
    assert t_int8 < t_bf16
    assert t_bf16 / t_int8 > 1.5  # bytes dominate the shared RTT at 4k ctx


def test_latency_terms_migrate_term():
    """latency_terms grows a migrate_s term: zero for the pure shapes,
    the cost-model roofline for split prefill/decode devices, and
    latency_s stays the exact sum."""
    dev_d = cm.DEVICES["jetson_orin_nano"]
    dev_p = cm.DEVICES["rtx5090"]
    prof = cm.MODELS["qwen3vl-8b"]
    pure = cm.latency_terms(dev_d, prof, 512, 0.5)
    assert pure["migrate_s"] == 0.0
    same = cm.latency_terms(dev_d, prof, 512, 0.5, prefill_device=dev_d)
    assert same["migrate_s"] == 0.0
    split = cm.latency_terms(dev_d, prof, 512, 0.5, prefill_device=dev_p,
                             migrate_kv_dtype="int8")
    want = cm.migrate_s(prof, 512, dev_p, dev_d, kv_dtype="int8")
    assert split["migrate_s"] == pytest.approx(want)
    assert split["total_s"] == pytest.approx(
        split["prefill_s"] + split["decode_s"] + split["link_s"]
        + split["migrate_s"])
    assert cm.latency_s(dev_d, prof, 512, 0.5, prefill_device=dev_p,
                        migrate_kv_dtype="int8") == pytest.approx(
        split["total_s"])
    # prefill priced on the prefill device (faster than the edge decode)
    assert split["prefill_s"] < pure["prefill_s"]


# ------------------------------------------------- cluster-level moves


@pytest.fixture(scope="module")
def twin_cluster():
    """Two cloud-class handles sharing arch + weights (KV-compatible,
    bit-identical capable), with tracing on."""
    tm = Telemetry(trace=True)
    handles = build_continuum([(2, 2)], arch="qwen2-0.5b", param_seed=0,
                              telemetry=tm, max_seq=64, page_size=8)
    return Cluster(handles, timeout_s=60.0), tm


def test_cluster_charged_migration(twin_cluster):
    """A planned prefill-on-0/decode-on-1 dispatch produces the same
    tokens as the pure run, moves the record to the decode server, emits
    a kv_migrate span with real bytes, and pays the link time on the
    virtual clock."""
    cl, tm = twin_cluster
    cl.reset()
    h0, h1 = cl.handles
    prompt = _prompt(h0.cfg, seed=11)
    uid = cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=10,
                                     task=0, server=0))
    cl.drain()
    pure = cl.collect()[0]
    base = tuple(cl.records[uid]["req"].output)

    cl.reset()
    uid = cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=10,
                                     task=0, server=0, decode_server=1))
    cl.drain()
    rec = cl.collect()[0]
    req = cl.records[uid]["req"]
    assert tuple(req.output) == base
    assert cl.records[uid]["server"] == 1
    assert not rec["timeout"]
    spans = [e for e in tm.tracer.events if e.get("name") == "kv_migrate"]
    assert spans, "migration must be visible as a kv_migrate span"
    s = spans[-1]
    assert s["args"]["bytes"] > 0 and s["args"]["pages"] > 0
    assert s["args"]["src"] == h0.name and s["args"]["dst"] == h1.name
    # bytes are destination-priced pages
    assert (s["args"]["bytes"]
            == s["args"]["pages"] * h1.engine.page_bytes())
    assert h0.engine.metrics.counter("kv_migrate_out_bytes").value \
        == h1.engine.metrics.counter("kv_migrate_in_bytes").value \
        == s["args"]["bytes"]
    # the transfer is charged on the virtual clock: same decode speed on
    # both handles, so the split run can only be slower than the pure one
    assert rec["e2e_s"] > pure["e2e_s"]


def test_cluster_rebalance_threshold(twin_cluster):
    """rebalance() evacuates from a handle whose backlog crosses the
    threshold — and leaves a fleet under the threshold alone."""
    cl, tm = twin_cluster
    cl.reset()
    h0 = cl.handles[0]
    prompt = _prompt(h0.cfg, seed=13)
    for k in range(6):  # pile everything onto handle 0
        cl.submit(ContinuumRequest(tokens=prompt, max_new_tokens=10,
                                   task=k, server=0))
    cl.advance_to(h0.uplink_s() + 6 * h0.decode_tick_s)
    assert h0._load()["backlog_s"] > 0
    assert cl.rebalance(threshold_s=1e9) == []  # nobody over threshold
    moves = cl.rebalance(threshold_s=1e-6)
    assert len(moves) == 1
    assert moves[0]["src"] == 0 and moves[0]["dst"] == 1
    assert moves[0]["bytes"] > 0
    cl.drain()
    recs = cl.collect()
    assert all(not r["timeout"] for r in recs)
    moved = next(r for r in recs if r["uid"] == moves[0]["uid"])
    assert moved["server"] == 1 and moved["n_tokens"] == 10


def test_predict_disagg_terms(twin_cluster):
    """The disaggregated predictor decomposes into the expected terms and
    its migrate term matches the cost-model link roofline."""
    cl, _ = twin_cluster
    cl.reset()
    total, terms = cl.predict_disagg_e2e_s(0, 1, 23, 10)
    assert set(terms) == {"queue", "prefill", "migrate", "queue_decode",
                          "decode", "media", "link"}
    assert total == pytest.approx(sum(terms.values()))
    hd = cl.handles[1]
    pages = ceil_blocks(24, hd.engine.page_size)
    want = cm.migrate_link_s(pages * hd.engine.page_bytes(),
                             cl.handles[0].device, hd.device)
    assert terms["migrate"] == pytest.approx(float(want))


# -------------------------------------------------- router third shape


def _stub_router(latencies, migrate, **kw):
    servers = [ServerHandle(name=f"s{i}", model_id=0, device_id=0,
                            is_cloud=False,
                            execute=lambda t, v=v: (v, True))
               for i, v in enumerate(latencies)]
    return QLMIORouter(servers, milp_pred=lambda t, s: latencies[s],
                       mgqp_pred=lambda t, s: 0.9,
                       migrate_pred=migrate, **kw)


def test_router_plan_prefers_cheap_disagg_pair():
    """plan() picks prefill-here/decode-there when the pair beats every
    pure shape, and reports the mapping the cluster submit needs."""
    r = _stub_router([10.0, 10.0],
                     migrate=lambda t, sp, sd: 2.0)
    p = r.plan(0)
    assert p["prefill_server"] is not None
    assert p["server"] != p["prefill_server"]


def test_router_plan_falls_back_to_pure():
    """Without migrate_pred — or when every pair is incompatible (None)
    or more expensive — plan() degrades to the pure argmax route()."""
    r = _stub_router([1.0, 5.0], migrate=None)
    p = r.plan(0)
    assert p == {"server": 0, "prefill_server": None, "draft_server": None,
                 "utility": pytest.approx(p["utility"]),
                 "predicted_s": pytest.approx(p["predicted_s"])}
    r2 = _stub_router([1.0, 5.0], migrate=lambda t, sp, sd: None)
    assert r2.plan(0)["prefill_server"] is None
    r3 = _stub_router([1.0, 5.0], migrate=lambda t, sp, sd: 50.0)
    p3 = r3.plan(0)
    assert (p3["server"], p3["prefill_server"]) == (0, None)


def test_router_plan_skips_unhealthy():
    """A dead server appears in no shape — pure or pair."""
    r = _stub_router([1.0, 5.0], migrate=lambda t, sp, sd: 0.5)
    r.health.dead_until[0] = 100.0  # server 0 in cooldown
    p = r.plan(0)
    assert p["server"] == 1 and p["prefill_server"] is None


# --------------------------------------------------- shared block math


def test_block_math_helpers():
    assert ceil_blocks(0, 8) == 0
    assert ceil_blocks(1, 8) == 1
    assert ceil_blocks(8, 8) == 1
    assert ceil_blocks(9, 8) == 2
    assert full_blocks(7, 8) == 0
    assert full_blocks(8, 8) == 1
    assert full_blocks(15, 8) == 1
