"""Optimizer, checkpointing (fault tolerance), sharding-rule invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, schedule_lr)
from repro.train.checkpoint import (latest_step, list_checkpoints,
                                    load_checkpoint, save_checkpoint)


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=None, schedule="constant")
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: (p["w"] ** 2).sum())(params)
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_bf16_master_copy():
    cfg = AdamWConfig(lr=0.01, warmup_steps=0, clip_norm=None,
                      schedule="constant", weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master is not None  # fp32 master for low-precision params
    grads = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    p2, s2, _ = adamw_update(cfg, params, grads, state)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2.master["w"].dtype == jnp.float32
    # master accumulates sub-bf16-resolution updates
    assert float(jnp.abs(s2.master["w"] - 1.0).max()) > 0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    n2 = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    np.testing.assert_allclose(float(n2), 1.0, rtol=1e-3)


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule_lr(cfg, jnp.asarray(0))) == 0.0
    assert float(schedule_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule_lr(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_ratio, rel=1e-3)


# ----------------------------------------------------------- checkpointing


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones(3, np.int32), "none": None},
            "tup": (np.float32(1.5), np.zeros(2))}
    save_checkpoint(d, 5, tree)
    step, loaded = load_checkpoint(d)
    assert step == 5
    np.testing.assert_array_equal(loaded["w"], tree["w"])
    np.testing.assert_array_equal(loaded["nested"]["b"], tree["nested"]["b"])
    assert loaded["nested"]["none"] is None
    assert isinstance(loaded["tup"], tuple)


def test_checkpoint_keep_n_and_resume(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, s, {"w": np.full(3, s, np.float32)}, keep=3)
    assert list_checkpoints(d) == [3, 4, 5]
    assert latest_step(d) == 5
    step, tree = load_checkpoint(d)
    assert step == 5 and tree["w"][0] == 5


def test_checkpoint_preemption_safe(tmp_path):
    """A stale tmp dir from a killed writer must not break loading and gets
    cleaned up by the next successful save."""
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": np.ones(2, np.float32)})
    os.makedirs(os.path.join(d, "ckpt_0000000002.tmp.999.123"))
    assert latest_step(d) == 1  # tmp dir invisible
    save_checkpoint(d, 3, {"w": np.ones(2, np.float32)})
    assert not any(".tmp." in n for n in os.listdir(d))


# ------------------------------------------------------------- sharding


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 64), axis_size=st.sampled_from([2, 4, 8, 16]))
def test_pspec_divisibility_invariant(dim, axis_size):
    """Property: a mesh axis is only assigned to dims it divides."""
    from repro.distributed.sharding import _leaf_pspec
    from repro.nn.spec import TensorSpec

    class FakeMesh:
        def __init__(self, n):
            self.shape = {"model": n, "data": 2}
            self.axis_names = ("data", "model")

    spec = TensorSpec((dim, 32), ("mlp", "embed"))
    ps = _leaf_pspec(spec, {"mlp": "model", "embed": None},
                     FakeMesh(axis_size))
    if dim % axis_size == 0:
        assert ps[0] == "model"
    else:
        assert ps[0] is None
