"""Dev loop: one reduced forward/train/prefill/decode per arch on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import build_model

ids = sys.argv[1:] or ARCH_IDS
for arch_id in ids:
    cfg = reduced(get_config(arch_id))
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key, jnp.float32)
    B, S = 2, 64
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.cross_attention:
        batch["encoder_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    loss = jax.jit(lambda p, b: m.train_loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), (arch_id, loss)
    logits, cache = jax.jit(m.prefill)(params, {k: v for k, v in batch.items()
                                                if k != "labels"})
    assert logits.shape == (B, cfg.vocab) and np.isfinite(
        np.asarray(logits, np.float32)).all(), arch_id
    step_batch = {"tokens": jnp.zeros((B,), jnp.int32),
                  "pos": jnp.full((B,), S - 1, jnp.int32)}
    # decode against an abstract-shaped cache built from prefill
    logits2, cache2 = jax.jit(m.serve_step)(params, cache, step_batch)
    assert logits2.shape == (B, cfg.vocab) and np.isfinite(
        np.asarray(logits2, np.float32)).all(), arch_id
    print(f"OK {arch_id}: loss={float(loss):.3f}")
print("all good")
