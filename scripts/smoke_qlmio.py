"""Dev loop: end-to-end paper pipeline on a tiny budget."""
import time

import numpy as np

from repro.core.feature_store import compute_features
from repro.core.predictors import Predictor, PredictorConfig
from repro.core.qlmio import QLMIO, QLMIOConfig
from repro.core import baselines as B
from repro.core.d3qn import D3QNConfig
from repro.data.taskgen import splits
from repro.sim.cemllm import make_servers
from repro.sim.miobench import SERVER_CLASSES, generate

t0 = time.time()
bench = generate(seed=0, n_tasks=400)
tr, va, te = splits(bench.tasks.n)
f_img, f_text = compute_features(bench.tasks, profile="tiny", cache_dir=None)
print(f"[{time.time()-t0:.0f}s] features {f_img.shape}")

# ---- predictor training data: task x server-class pairs
def flat(ids):
    C = len(SERVER_CLASSES)
    t = np.repeat(ids, C)
    c = np.tile(np.arange(C), len(ids))
    return {"f_text": f_text[t], "f_img": f_img[t],
            "model_id": bench.model_id[c], "device_id": bench.device_id[c],
            "label": (bench.score[t, c] == 1).astype(np.int64),
            "latency_s": bench.latency_s[t, c].astype(np.float32)}

cfgp = PredictorConfig(epochs=8, batch=128)
milp = Predictor("latency", 8, 8, cfgp, feat_dim=f_text.shape[1])
h = milp.fit(flat(tr), flat(va))
print(f"[{time.time()-t0:.0f}s] MILP val MAE {h[-1]['val_mae_s']:.2f}s")
mgqp = Predictor("quality", 8, 8, cfgp, feat_dim=f_text.shape[1])
h = mgqp.fit(flat(tr), flat(va))
print(f"[{time.time()-t0:.0f}s] MGQP val acc {h[-1]['val_acc']:.3f}")

# ---- predictions for all tasks x classes
C = len(SERVER_CLASSES)
allb = {"f_text": np.repeat(f_text, C, 0), "f_img": np.repeat(f_img, C, 0),
        "model_id": np.tile(bench.model_id, bench.tasks.n),
        "device_id": np.tile(bench.device_id, bench.tasks.n)}
milp_preds = milp.predict(allb).reshape(-1, C)
mgqp_preds = mgqp.predict(allb).reshape(-1, C)

servers = make_servers(5, bench)
cfg = QLMIOConfig(episodes=60, users=10, seed=0,
                  agent=D3QNConfig(eps_decay_steps=400))
q = QLMIO(bench, servers, (f_img, f_text), milp_preds, mgqp_preds, cfg)
hist = q.train(tr, verbose=True, log_every=20)
res = q.evaluate(te, trials=5)
print(f"[{time.time()-t0:.0f}s] QLMIO test:", res)
heur = B.evaluate_heuristics(bench, servers, te, 10, 5)
for k, v in heur.items():
    print(k, v)
