"""Regenerate the data tables of EXPERIMENTS.md from results/*.json.
Hand-written narrative sections live in docs/experiments_*.md fragments."""
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import rows  # noqa: E402

RESULTS = "results"


def load(name):
    p = os.path.join(RESULTS, name)
    return json.load(open(p)) if os.path.exists(p) else None


def dryrun_section():
    recs = load("dryrun.json") or []
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skipped"]
    out = ["## §Dry-run", ""]
    out.append(f"{len(ok)} cells lowered+compiled, {len(skip)} skipped "
               f"(long_500k on pure full-attention archs), "
               f"{sum(r['status'] == 'error' for r in recs)} errors. "
               "Meshes: 16x16 (256 chips) and 2x16x16 (512 chips). "
               "Per-device artifacts from `compiled.memory_analysis()` / "
               "the trip-count-aware HLO analyzer:")
    out.append("")
    out.append("| arch | shape | mesh | args GB/dev | temps GB/dev | "
               "flops/dev | HBM bytes/dev | collective B/dev |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = r.get("memory", {})
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {m.get('argument_size_in_bytes', 0) / 1e9:.2f} "
            f"| {m.get('temp_size_in_bytes', 0) / 1e9:.2f} "
            f"| {ro['flops_per_device']:.2e} "
            f"| {ro['bytes_per_device']:.2e} "
            f"| {ro['collective_bytes_per_device']:.2e} |")
    out.append("")
    out.append("Skipped cells: " + "; ".join(
        sorted({f"{r['arch']} x {r['shape']}" for r in skip})) + ".")
    return "\n".join(out)


def roofline_section():
    recs = load("dryrun.json") or []
    table = rows(recs)
    out = ["## §Roofline", ""]
    out.append("Terms per (arch x shape), single-pod 16x16 (256 chips), "
               "v5e constants 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI. "
               "`useful` = MODEL_FLOPS / HLO_FLOPS (6*N*D or 6*N_active*D); "
               "`roofline frac` = t_compute / max(term).")
    out.append("")
    out.append("| arch | shape | t_compute s | t_memory s | t_collective s |"
               " bottleneck | useful | roofline frac | lever |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in table:
        if r["mesh"] != "16x16":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} "
            f"| {r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} "
            f"| {r['bottleneck']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['lever']} |")
    out.append("")
    out.append("Multi-pod (2x16x16) terms are recorded in "
               "results/roofline_table.json; the pod axis extends data "
               "parallelism, so per-device terms halve for batch-sharded "
               "shapes and stay flat for batch-1 cells.")
    return "\n".join(out)


PERF_INTRO = """Method: per iteration we (1) read the dominant roofline term
from the dry-run artifact, (2) napkin-math candidate changes, (3) re-lower +
re-compile the cell with the change, (4) record confirmed/refuted.  Stopping
rule: three consecutive <5% moves on the dominant term.  Cells were nominated
by benchmarks/roofline.py: A = worst useful-FLOPS ratio, D = most
collective-bound, B = most representative of the paper's serving technique
(big-model decode), C = worst memory term in the first sweep.

Summary (dominant-term, before -> after of the best variant):

| cell | arch x shape | dominant term | before | after | x | status |
|---|---|---|---|---|---|---|
| A | qwen2-0.5b train_4k | memory (s) | 10.6 | 0.89 | 12.0x | confirmed (pure-DP plan; useful-FLOPS ratio 0.13 -> 0.85) |
| B | chameleon-34b decode_32k | collective (s) | 2.06 | 0.0033 | 625x | confirmed (grouped-GQA einsum, never repeat the cache) |
| C | xlstm-1.3b prefill_32k | collective (s) | 8.29 | 8.29 | 1.0x | 3 variants refuted — chunk resizing moves <5%, forced qkv-gather regressed 3x; lever identified: TP psums on d_in projections (needs sequence pipelining or fused block kernel) |
| D | qwen2-moe-a2.7b train_4k | collective (s) | 132 | 104 | 1.26x | partially confirmed (dispatch sharding constraint); chunked dispatch refuted; next lever: shard_map expert-parallel all-to-all |

Refuted hypotheses kept below — they are as informative as the wins
(notably: GSPMD-auto context parallelism costs 11x in collectives for a
14-head model, and the first memory-term reading of cell C was estimator
pessimism about in-place DUS fusions, fixed in the analyzer and re-measured).
"""


def perf_section():
    log = load("perf_log.json") or []
    out = ["## §Perf — hillclimb log (hypothesis -> change -> measure)", "",
           PERF_INTRO, ""]
    cells = {}
    for r in log:
        cells.setdefault(r["cell"], []).append(r)
    for cell, recs in sorted(cells.items()):
        first = recs[0]
        out.append(f"### Cell {cell}: {first['arch']} x {first['shape']}")
        out.append("")
        base = None
        for r in recs:
            if r.get("status") != "ok":
                continue
            ro = r["roofline"]
            line = (f"* **{r['variant']}** — {r['hypothesis']}\n"
                    f"  * measured: t_compute {ro['t_compute_s']:.3g}s, "
                    f"t_memory {ro['t_memory_s']:.3g}s, "
                    f"t_collective {ro['t_collective_s']:.3g}s "
                    f"(bottleneck: {ro['bottleneck']})")
            if base is not None:
                for term in ("t_memory_s", "t_collective_s", "t_compute_s"):
                    if base[term] > 0:
                        d = ro[term] / base[term]
                        line += f"; {term[2:-2]} x{d:.2f} vs baseline"
            else:
                base = ro
            out.append(line)
        out.append("")
    return "\n".join(out)


def paper_claims_section():
    out = ["## §Paper-claims", ""]
    fig1 = load("fig1_device_disparity.json")
    if fig1:
        j = fig1["jetson_orin_nano"]
        c = fig1["rtx5090"]
        out.append(f"* **Fig. 1 (device disparity)**: Jetson acc "
                   f"{j['accuracy']:.1%} / timeout {j['timeout_rate']:.1%} "
                   f"(paper 66.7% / 26.3%); RTX5090 acc {c['accuracy']:.1%}, "
                   f"0 timeouts, p95 latency {c['latency_p95_s']:.1f}s "
                   f"(paper ~90%, <10s).")
    f5 = load("fig5_milp.json")
    if f5:
        out.append(f"* **Fig. 5 (MILP)**: val MAE "
                   f"{f5['history'][-1]['val_mae_s']:.2f}s "
                   f"(paper ~3.70s; frozen encoders here are seeded-random "
                   f"— DESIGN.md §4).")
    f6 = load("fig6_mgqp.json")
    if f6:
        best = max(h["val_acc"] for h in f6["history"])
        out.append(f"* **Fig. 6 (MGQP)**: best val accuracy {best:.1%} "
                   f"(paper 85.46%).")
    f7 = load("fig7_qlmio_convergence.json")
    if f7:
        h = f7["history"]
        tail = h[-max(1, len(h) // 10):]
        import numpy as np
        out.append(f"* **Fig. 7 (convergence)**: reward rises "
                   f"{h[0]['avg_reward']:.2f} -> "
                   f"{np.mean([x['avg_reward'] for x in tail]):.2f}; "
                   f"completion "
                   f"{np.mean([x['completion_rate'] for x in tail]):.1%} "
                   f"(paper ~90%).")
    f8 = load("fig8_comparison.json")
    if f8:
        best_red, best_key = 0.0, None
        for key, row in f8.items():
            if "qlmio" not in row or "all_cloud" not in row:
                continue
            red = 1 - (row["qlmio"]["avg_latency_s"]
                       / row["all_cloud"]["avg_latency_s"])
            if red > best_red:
                best_red, best_key = red, key
        if best_key:
            r = f8[best_key]
            out.append(
                f"* **Fig. 8 (comparison)**: best latency reduction vs "
                f"All-Cloud {best_red:.1%} at {best_key} (paper: up to "
                f"80.8% vs All-Cloud, 58.1% vs D3QN); completion ratio vs "
                f"All-Cloud "
                f"{r['qlmio']['completion_rate'] / max(r['all_cloud']['completion_rate'], 1e-9):.2f} "
                f"(paper: ~matching).")
    f9 = load("fig9_ablation.json")
    if f9 and "qlmio" in f9:
        out.append(
            f"* **Fig. 9 (ablation)**: latency QLMIO "
            f"{f9['qlmio']['avg_latency_s']:.1f}s vs no-MILP "
            f"{f9['no_milp']['avg_latency_s']:.1f}s vs no-MGQP "
            f"{f9['no_mgqp']['avg_latency_s']:.1f}s vs no-both "
            f"{f9['no_both']['avg_latency_s']:.1f}s; completion "
            f"{f9['qlmio']['completion_rate']:.1%} / "
            f"{f9['no_milp']['completion_rate']:.1%} / "
            f"{f9['no_mgqp']['completion_rate']:.1%} / "
            f"{f9['no_both']['completion_rate']:.1%} — same ordering as the "
            f"paper (both modules help; MGQP carries completion, MILP "
            f"carries latency).")
    b = load("miobench_stats.json")
    if b:
        out.append(f"* **MIOBench**: {b['n_records']} records from "
                   f"{b['n_tasks']} tasks x 3 server classes "
                   f"(paper: 10,131 / 3,377), fields per Table II.")
    return "\n".join(out)


HEADER = """# EXPERIMENTS

Reproduction + performance record for the QLMIO framework build
(DESIGN.md has the system inventory; benchmarks/ has one entry per paper
figure).  All tables below are regenerated by
``python scripts/make_experiments_md.py`` from ``results/*.json``.

Benchmark budget used for the paper-claim numbers:
``BENCH_BUDGET={budget}`` (see benchmarks/common.py; `fast` = full MIOBench +
full-width frozen encoders + 300 episodes; the paper's own settings are
`paper` = 50 epochs / 12000 episodes).
"""


def main():
    budget = os.environ.get("BENCH_BUDGET", "smoke")
    parts = [HEADER.format(budget=budget), dryrun_section(), "",
             roofline_section(), "", perf_section(), "",
             paper_claims_section(), ""]
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
