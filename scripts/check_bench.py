"""CI benchmark-regression gate.

Compares a ``kernel_bench.py --json`` output against the checked-in
``benchmarks/baseline.json`` with per-metric tolerance and fails (exit 1)
on regression, so serving-latency and throughput numbers cannot rot
silently.

Usage:
    python benchmarks/kernel_bench.py serving paged_kv --json bench.json
    python scripts/check_bench.py bench.json
    python scripts/check_bench.py bench.json --update   # refresh baseline

Several bench JSONs can be gated in one run — they are shallow-merged in
argument order (later files win on key collisions), so the fig10 replay's
``cost_model`` prediction-error metrics ride the same baseline as the
kernel bench numbers:

    python scripts/check_bench.py bench.json fig10_continuum_replay.json

Baseline schema — one entry per gated metric, addressed by a dotted path
into the bench JSON:

    "serving.chunked.ttft_p95_s": {
        "value": 1.43,        # baseline measurement
        "better": "lower",    # which direction is an improvement
        "max_ratio": 3.0,     # regression when worse by > this factor
        "max_abs": 0.0        # ... or by > this absolute slack
    }

A metric regresses only when it is worse than ``value`` by more than
*both* slacks (ratio for scale-free drift, abs for near-zero baselines).
Wall-clock metrics get generous ratios (shared CI runners are noisy);
deterministic metrics (XLA trace counts, roofline throughput) are tight.
``--update`` rewrites every ``value`` from the current measurement and
keeps the tolerances, for intentional performance-characteristic changes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks", "baseline.json")


def lookup(tree: dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check_metric(name: str, spec: dict, measured) -> "str | None":
    """None if within tolerance, else a human-readable failure line."""
    if measured is None:
        return f"{name}: missing from the bench JSON"
    base = float(spec["value"])
    new = float(measured)
    better = spec.get("better", "lower")
    max_ratio = float(spec.get("max_ratio", 1.0))
    max_abs = float(spec.get("max_abs", 0.0))
    if better == "lower":
        limit = max(base * max_ratio, base + max_abs)
        if new > limit:
            return (f"{name}: {new:.4g} exceeds baseline {base:.4g} "
                    f"(limit {limit:.4g})")
    elif better == "higher":
        limit = min(base / max_ratio, base - max_abs)
        if new < limit:
            return (f"{name}: {new:.4g} below baseline {base:.4g} "
                    f"(limit {limit:.4g})")
    else:
        raise ValueError(f"{name}: unknown direction {better!r}")
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench_json", nargs="+",
                    help="bench JSON file(s): kernel_bench.py --json "
                         "output, benchmark result JSONs; shallow-merged "
                         "in order")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite baseline values from this measurement")
    ap.add_argument("--only", default=None,
                    help="comma-separated dotted-path prefixes; gate only "
                         "the baseline metrics under them (for partial "
                         "bench runs, e.g. --only fig15,tp)")
    args = ap.parse_args(argv)

    bench: dict = {}
    for path in args.bench_json:
        with open(path) as f:
            bench.update(json.load(f))
    with open(args.baseline) as f:
        baseline = json.load(f)

    metrics = baseline["metrics"]
    if args.only:
        prefixes = [p.strip() for p in args.only.split(",") if p.strip()]
        metrics = {name: spec for name, spec in metrics.items()
                   if any(name == p or name.startswith(p + ".")
                          for p in prefixes)}
        if not metrics:
            print(f"check_bench: no baseline metrics match --only "
                  f"{args.only!r}", file=sys.stderr)
            return 1
    if args.update:
        missing = []
        for name, spec in metrics.items():
            measured = lookup(bench, name)
            if measured is None:
                missing.append(name)
            else:
                spec["value"] = float(measured)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=1)
            f.write("\n")
        print(f"check_bench: baseline updated ({len(metrics)} metrics)"
              + (f"; NOT measured: {missing}" if missing else ""))
        return 1 if missing else 0

    failures = []
    for name, spec in metrics.items():
        err = check_metric(name, spec, lookup(bench, name))
        status = "FAIL" if err else "ok"
        measured = lookup(bench, name)
        shown = "missing" if measured is None else f"{float(measured):.4g}"
        print(f"check_bench,{status},{name},measured={shown},"
              f"baseline={spec['value']:.4g}")
        if err:
            failures.append(err)
    if failures:
        print(f"check_bench: {len(failures)} regression(s):",
              file=sys.stderr)
        for err in failures:
            print(f"  {err}", file=sys.stderr)
        return 1
    print(f"check_bench: all {len(metrics)} metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
