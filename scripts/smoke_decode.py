"""Verify serve_step is consistent with prefill: logits for token S must
match prefill over S+1 tokens."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import build_model

import dataclasses

ids = sys.argv[1:] or ARCH_IDS
for arch_id in ids:
    # capacity drops legitimately differ between batched prefill and decode;
    # raise the factor so the consistency check isolates cache correctness
    cfg = dataclasses.replace(reduced(get_config(arch_id)),
                              capacity_factor=16.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 33
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    extra = {}
    if cfg.cross_attention:
        extra["encoder_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    full_logits, _ = jax.jit(m.prefill)(params, {"tokens": toks, **extra})
    _, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :S], **extra})
    # grow attn caches by one slot so the new token has a slot to write to
    def grow(c):
        out = dict(c)
        for k in ("k", "v"):
            if k in out:
                pad = [(0, 0)] * out[k].ndim
                pad[-3] = (0, 1)
                out[k] = jnp.pad(out[k], pad)
        if "pos_map" in out:
            out["pos_map"] = jnp.pad(out["pos_map"], ((0, 0), (0, 1)),
                                     constant_values=-1)
        return out

    cache = grow(cache)
    step_logits, _ = jax.jit(m.serve_step)(
        params, cache, {"tokens": toks[:, S],
                        "pos": jnp.full((B,), S, jnp.int32)})
    a = np.asarray(full_logits, np.float32)
    b = np.asarray(step_logits, np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    status = "OK " if err < 2e-2 else "FAIL"
    print(f"{status} {arch_id}: rel_err={err:.2e}")
