#!/usr/bin/env bash
# CI entry point: install deps, run the tier-1 suite, the decode smoke
# test, the continuum replay smoke, and the benchmark regression gate.
# Mirrors .github/workflows/ci.yml so the same commands run locally:
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${CI_SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install --upgrade pip
    python -m pip install "jax[cpu]" numpy pytest pytest-timeout hypothesis \
        msgpack zstandard
fi

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# multi-device host mesh: the tensor-parallel serving tests and the
# fig15 live-identity part shard real engines over this emulated mesh
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

# lint (same commands as the CI lint job; skipped when ruff is absent)
if command -v ruff >/dev/null 2>&1; then
    ruff check .
    ruff format --check .
else
    echo "ci.sh: ruff not installed; skipping lint (CI runs it)"
fi

python -m pytest -x -q
python scripts/smoke_decode.py

# serving prefill smoke: TTFT/ITL p95, prefill trace counts, paged-decode
# throughput, the int8-KV sections (paged_kv.int8 bytes/token +
# throughput, serving.chunked_int8 run) and the speculative multi-token-
# verify rows (verify vs sequential tokens/s at k in {2,4,8}, bf16+int8,
# kernel-vs-oracle error); gated below together with the fig10
# cost-model metric, and uploaded as a CI artifact
mkdir -p results
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/kernel_bench.py \
    serving paged_kv speculative --json results/bench.json

# continuum replay smoke with tracing: QLMIO over real ServingEngines must
# beat the all-cloud baseline on mean e2e latency at a matching completion
# rate; the exported Perfetto trace (also a CI artifact) must render a
# per-stage report, and the emitted JSON carries the cost-model
# prediction-error metric for the regression gate
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/fig10_continuum_replay.py \
    --trace results/fig10_trace.json
python scripts/trace_report.py results/fig10_trace.json

# disaggregated prefill/decode smoke with tracing: QLMIO extended with
# KV migration (prefill-here/decode-there dispatch + mid-stream
# evacuation) must beat static QLMIO on mean e2e at an equal-or-better
# completion rate, with at least one charged kv_migrate span in the
# exported trace (also a CI artifact)
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/fig12_disaggregation.py \
    --smoke --trace results/fig12_trace.json
python scripts/trace_report.py results/fig12_trace.json

# speculative decoding smoke with tracing: QLMIO extended with the
# fourth dispatch shape (edge drafts / cloud verifies, plus colocated
# cloud speculation) must beat all-cloud on measured mean ITL at an
# equal-or-better completion rate, with live acceptance telemetry
# (spec_tokens counters + draft/verify spans) in the exported trace
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/fig14_speculative.py \
    --smoke --trace results/fig14_trace.json
python scripts/trace_report.py results/fig14_trace.json

# 100-engine scale-out smoke with tracing: 10k Poisson-arrival requests
# replayed over 100 sim-backend engines on the event-heap clock; asserts
# the O(active) property (identical trace -> identical handle-step count
# on a 10- vs 90-engine fleet, gated below) and that per-token streaming
# strictly improves measured TTFT; the exported trace (a CI artifact)
# must render a per-stage + queue-wait report
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/fig13_scaleout.py \
    --smoke --trace results/fig13_trace.json
python scripts/trace_report.py results/fig13_trace.json

# tensor-parallel smoke: live engines sharded over the 8-way host mesh
# must emit bit-identical token streams at TP in {1,2,4,8}; the cost
# model's TP rooflines (deterministic tp.* rows) must scale; and the
# continuum replay with a TP=4 cloud must beat the flat fleet on mean
# e2e at an equal-or-better completion rate (fig15.* rows)
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/fig15_tensor_parallel.py \
    --smoke

# benchmark regression gate: kernel/serving numbers + the fig10 replay's
# cost_model.mean_abs_pct_err + the fig12 migration headline metrics +
# the fig13 scale-out headline metrics (incl. the deterministic
# fig13.oactive_steps_large O(active) gate) + the fig14 speculative
# headline metrics (measured ITL reduction, live acceptance) + the fig15
# tensor-parallel rows (deterministic tp.* rooflines, TP-cloud replay),
# all vs. benchmarks/baseline.json
python scripts/check_bench.py results/bench.json \
    results/fig10_continuum_replay.json results/fig12_disaggregation.json \
    results/fig13_scaleout.json results/fig14_speculative.json \
    results/fig15_tensor_parallel.json

# multimodal split-point smoke: the QLMIO-chosen per-request split (raw-
# ship vs edge-encode) must beat both fixed policies on mean e2e latency
# at an equal completion rate, over live engines with real media segments
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/fig11_multimodal_split.py --smoke
