#!/usr/bin/env bash
# CI entry point: install deps, run the tier-1 suite, then the decode
# consistency smoke test.  Mirrors .github/workflows/ci.yml so the same
# commands run locally: bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${CI_SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install --upgrade pip
    python -m pip install "jax[cpu]" numpy pytest hypothesis msgpack zstandard
fi

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python scripts/smoke_decode.py
# serving prefill smoke: mixed-length TTFT/ITL + compile-count rows
# (bucketed+chunked scheduler vs. legacy recompile-storm path)
PYTHONPATH=".:${PYTHONPATH}" python benchmarks/kernel_bench.py serving
