"""Continuum trace report: per-stage latency decomposition + calibration.

Reads a trace JSON exported by ``repro.serving.telemetry.Telemetry``
(Chrome trace-event format plus the dispatch audit riding along as extra
top-level keys) and prints:

  * per-stage p50/p95 latency decomposition — every span category/name
    pair (uplink, queue, prefill, decode, downlink, prefill_chunk,
    tick, ...) over its recorded durations;
  * per-engine utilization — busy fraction (span-covered time / trace
    horizon) per traced process, with a coarse timeline;
  * top-N slowest requests — by summed lifecycle span duration per
    (engine, request) thread, with their per-stage breakdown;
  * cost-model calibration — prediction-error percentiles from the
    dispatch audit (predicted vs. measured e2e), the paper's
    "latency is hard to predict" claim as a measured number.

Usage:
    python benchmarks/fig10_continuum_replay.py --trace t.json
    python scripts/trace_report.py t.json [--top 5]

The same file loads in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` for interactive inspection.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

import numpy as np

_US = 1e6


def _pct(xs, q):
    return float(np.percentile(xs, q)) if len(xs) else 0.0


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def spans(trace: dict) -> "list[dict]":
    return [ev for ev in trace.get("traceEvents", [])
            if ev.get("ph") == "X"]


def process_names(trace: dict) -> dict:
    """pid -> process name from the trace's metadata events."""
    return {ev["pid"]: ev["args"]["name"]
            for ev in trace.get("traceEvents", [])
            if ev.get("ph") == "M" and ev.get("name") == "process_name"}


def stage_summary(trace: dict) -> "list[dict]":
    """Per-(category, name) duration percentiles over every span."""
    groups: dict = defaultdict(list)
    for ev in spans(trace):
        groups[(ev.get("cat", "?"), ev["name"])].append(ev["dur"] / _US)
    out = []
    for (cat, name), durs in sorted(groups.items()):
        out.append({"cat": cat, "name": name, "count": len(durs),
                    "p50_s": _pct(durs, 50), "p95_s": _pct(durs, 95),
                    "total_s": float(np.sum(durs))})
    return out


def engine_utilization(trace: dict, buckets: int = 20) -> "list[dict]":
    """Busy fraction per engine from its ``tick`` spans: the share of the
    trace horizon covered by engine ticks (ticks only run while the
    engine has work), plus a coarse busy-fraction timeline."""
    names = process_names(trace)
    ticks: dict = defaultdict(list)
    horizon = 0.0
    for ev in spans(trace):
        horizon = max(horizon, (ev["ts"] + ev["dur"]) / _US)
        if ev["name"] == "tick":
            ticks[ev["pid"]].append((ev["ts"] / _US, ev["dur"] / _US))
    out = []
    for pid in sorted(ticks):
        ts = ticks[pid]
        busy = sum(d for _, d in ts)
        hist = np.zeros(buckets)
        if horizon > 0:
            w = horizon / buckets
            for t0, d in ts:
                b0, b1 = int(t0 / w), min(int((t0 + d) / w), buckets - 1)
                for b in range(b0, b1 + 1):  # overlap per bucket
                    lo, hi = b * w, (b + 1) * w
                    hist[b] += max(0.0, min(t0 + d, hi) - max(t0, lo))
            hist /= w
        out.append({"engine": names.get(pid, f"pid{pid}"),
                    "busy_s": busy,
                    "busy_frac": busy / horizon if horizon else 0.0,
                    "timeline": np.clip(hist, 0.0, 1.0)})
    return out


def queue_wait(trace: dict, buckets: int = 20) -> dict:
    """Admission-queue pressure: percentiles of the per-request ``queue``
    lifecycle span (submit -> batch admission) plus a fleet-aggregate
    depth timeline from the ``queue_depth`` counter samples each engine
    emits per tick."""
    waits = [ev["dur"] / _US for ev in spans(trace)
             if ev.get("cat") == "lifecycle" and ev["name"] == "queue"]
    samples = [(ev["ts"] / _US, sum(int(v) for v in
                                    ev.get("args", {}).values()))
               for ev in trace.get("traceEvents", [])
               if ev.get("ph") == "C" and ev["name"] == "queue_depth"]
    timeline = np.zeros(buckets)
    peak = 0
    if samples:
        horizon = max(t for t, _ in samples) or 1.0
        counts = np.zeros(buckets)
        for t, depth in samples:
            b = min(int(t / horizon * buckets), buckets - 1)
            timeline[b] += depth
            counts[b] += 1
            peak = max(peak, depth)
        timeline = np.divide(timeline, np.maximum(counts, 1))
    return {"n": len(waits), "p50_s": _pct(waits, 50),
            "p95_s": _pct(waits, 95), "max_s": max(waits, default=0.0),
            "samples": len(samples), "peak_depth": peak,
            "timeline": timeline}


def migration_traffic(trace: dict) -> "dict[str, dict]":
    """KV pages moved per engine, from ``kv_migrate`` spans: bytes/pages
    received (the span's pid is the destination) and sent (matched on the
    span's ``src`` process name)."""
    names = process_names(trace)
    traffic: dict = defaultdict(lambda: {"in_bytes": 0, "out_bytes": 0,
                                         "in_pages": 0, "moves": 0})
    for ev in spans(trace):
        if ev["name"] != "kv_migrate":
            continue
        a = ev.get("args", {})
        dst = traffic[names.get(ev["pid"], f"pid{ev['pid']}")]
        dst["in_bytes"] += int(a.get("bytes", 0))
        dst["in_pages"] += int(a.get("pages", 0))
        dst["moves"] += 1
        if a.get("src"):
            traffic[a["src"]]["out_bytes"] += int(a.get("bytes", 0))
    return dict(traffic)


def speculation(trace: dict, buckets: int = 20) -> "list[dict]":
    """Per-engine speculative-decoding rollup from the ``spec_tokens``
    counter samples each speculative engine emits per tick: drafted /
    accepted / wasted token totals, the overall acceptance rate, and a
    coarse acceptance-rate timeline (accepted/drafted per time bucket).
    Empty for traces without speculative engines."""
    names = process_names(trace)
    samples: dict = defaultdict(list)
    horizon = 0.0
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "C" and ev["name"] == "spec_tokens":
            a = ev.get("args", {})
            t = ev["ts"] / _US
            horizon = max(horizon, t)
            samples[ev["pid"]].append((t, int(a.get("drafted", 0)),
                                       int(a.get("accepted", 0)),
                                       int(a.get("emitted", 0))))
    out = []
    for pid in sorted(samples):
        ss = samples[pid]
        drafted = sum(s[1] for s in ss)
        accepted = sum(s[2] for s in ss)
        emitted = sum(s[3] for s in ss)
        dr, ac = np.zeros(buckets), np.zeros(buckets)
        for t, d, a, _ in ss:
            b = min(int(t / (horizon or 1.0) * buckets), buckets - 1)
            dr[b] += d
            ac[b] += a
        out.append({"engine": names.get(pid, f"pid{pid}"),
                    "drafted": drafted, "accepted": accepted,
                    "wasted": drafted - accepted, "emitted": emitted,
                    "acceptance": accepted / drafted if drafted else 0.0,
                    "timeline": np.divide(ac, np.maximum(dr, 1))})
    return out


def slow_requests(trace: dict, top: int = 5) -> "list[dict]":
    """Top-N slowest requests by summed lifecycle+transfer span time on
    their (engine, request-uid) thread."""
    names = process_names(trace)
    per_req: dict = defaultdict(dict)
    for ev in spans(trace):
        if ev.get("cat") not in ("lifecycle", "transfer"):
            continue
        per_req[(ev["pid"], ev["tid"])][ev["name"]] = ev["dur"] / _US
    ranked = sorted(per_req.items(), key=lambda kv: -sum(kv[1].values()))
    return [{"engine": names.get(pid, f"pid{pid}"), "uid": tid,
             "total_s": sum(stages.values()), "stages": stages}
            for (pid, tid), stages in ranked[:top]]


def _bar(frac_row, width: int = 1) -> str:
    glyphs = " .:-=+*#%@"
    return "".join(glyphs[min(int(f * (len(glyphs) - 1) + 0.5),
                              len(glyphs) - 1)] * width for f in frac_row)


def report(trace: dict, top: int = 5) -> str:
    lines = []
    stages = stage_summary(trace)
    lines.append("== per-stage latency decomposition (seconds) ==")
    if stages:
        lines.append(f"{'stage':<28}{'count':>7}{'p50':>10}{'p95':>10}"
                     f"{'total':>10}")
        for s in stages:
            lines.append(f"{s['cat'] + '/' + s['name']:<28}"
                         f"{s['count']:>7}{s['p50_s']:>10.4f}"
                         f"{s['p95_s']:>10.4f}{s['total_s']:>10.2f}")
    else:
        lines.append("(no spans recorded — was tracing enabled?)")

    util = engine_utilization(trace)
    lines.append("")
    lines.append("== per-engine utilization (tick-covered time) ==")
    for u in util:
        lines.append(f"{u['engine']:<36}{100 * u['busy_frac']:>6.1f}%  "
                     f"[{_bar(u['timeline'])}]")

    qw = queue_wait(trace)
    lines.append("")
    lines.append("== admission queue wait (submit -> batch admission) ==")
    if qw["n"]:
        lines.append(f"n={qw['n']}  p50={qw['p50_s']:.4f}s  "
                     f"p95={qw['p95_s']:.4f}s  max={qw['max_s']:.4f}s")
    else:
        lines.append("(no queue spans in this trace)")
    if qw["samples"]:
        depth = qw["timeline"]
        scale = max(float(depth.max()), 1.0)
        lines.append(f"fleet queue depth (mean of {qw['samples']} samples, "
                     f"peak {qw['peak_depth']}):")
        lines.append(f"{'depth':<10}{depth.mean():>6.2f} avg  "
                     f"[{_bar(np.clip(depth / scale, 0.0, 1.0))}]")

    traffic = migration_traffic(trace)
    if traffic:
        lines.append("")
        lines.append("== kv migration traffic (wire bytes, destination "
                     "precision) ==")
        for name in sorted(traffic):
            t = traffic[name]
            lines.append(f"{name:<36} in {t['in_bytes']:>9} B "
                         f"({t['in_pages']} pages, {t['moves']} moves)  "
                         f"out {t['out_bytes']:>9} B")

    spec = speculation(trace)
    if spec:
        lines.append("")
        lines.append("== speculative decoding (drafted / accepted / wasted "
                     "tokens, acceptance timeline) ==")
        for sp in spec:
            lines.append(
                f"{sp['engine']:<36}drafted {sp['drafted']:>6}  "
                f"accepted {sp['accepted']:>6}  wasted {sp['wasted']:>6}  "
                f"rate {sp['acceptance']:.3f}  [{_bar(sp['timeline'])}]")
        # join the draft/verify engine spans into the same p50/p95 view
        # as the rest of the stage decomposition
        for s in stages:
            if s["name"] in ("draft_tick", "verify_tick"):
                lines.append(f"{s['cat'] + '/' + s['name']:<36}"
                             f"n={s['count']:<6} p50={s['p50_s']:.4f}s  "
                             f"p95={s['p95_s']:.4f}s  "
                             f"total={s['total_s']:.2f}s")

    slow = slow_requests(trace, top)
    lines.append("")
    lines.append(f"== top-{top} slow requests ==")
    for r in slow:
        parts = ", ".join(f"{k}={v:.4f}" for k, v in
                          sorted(r["stages"].items(), key=lambda kv: -kv[1]))
        lines.append(f"uid {r['uid']:>5} on {r['engine']:<32}"
                     f"{r['total_s']:>9.4f}s  ({parts})")

    err = trace.get("prediction_error") or {}
    lines.append("")
    lines.append("== cost-model calibration (predicted vs measured e2e) ==")
    if err.get("n"):
        lines.append(f"n={err['n']}  "
                     f"mean|err|={err['mean_abs_pct_err']:.1f}%  "
                     f"p50|err|={err['p50_abs_pct_err']:.1f}%  "
                     f"p95|err|={err['p95_abs_pct_err']:.1f}%  "
                     f"bias={err['mean_signed_pct_err']:+.1f}%")
    else:
        lines.append("(no completed audited dispatches in this trace)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace_json", help="Telemetry.export output")
    ap.add_argument("--top", type=int, default=5,
                    help="slow requests to list (default 5)")
    args = ap.parse_args(argv)
    print(report(load_trace(args.trace_json), top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
