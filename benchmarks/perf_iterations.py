"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> measure.

Runs the three chosen cells (worst roofline fraction / most collective-bound
/ most representative of the paper's serving technique) through explicit
before/after variants and appends every iteration to results/perf_log.json.

Must run as its own process (512 placeholder devices):
  PYTHONPATH=src:. python -m benchmarks.perf_iterations [--only A]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)

import argparse  # noqa: E402
import json  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# (cell_id, arch, shape, variant_name, hypothesis, run_cell kwargs)
ITERATIONS = [
    ("A", "qwen2-0.5b", "train_4k", "baseline",
     "14 heads don't divide the 16-wide model axis, so ALL attention "
     "compute (QKV/O projections + score matmuls) replicates 16x across "
     "the model axis; expect flops/dev ~4x above the perfectly sharded "
     "value.", {}),
    ("A", "qwen2-0.5b", "train_4k", "context_parallel",
     "Shard the sequence dim of tokens/labels over `model` (context "
     "parallelism): attention becomes seq-local per shard, dividing the "
     "replicated attention flops by up to 16; predicted flops/dev "
     "9.5e13 -> ~2.5e13 (mlp/vocab terms unchanged).",
     {"seq_shard": True}),
    ("B", "chameleon-34b", "decode_32k", "baseline_repeat_kv",
     "GQA decode with jnp.repeat materializes the 8x-inflated KV cache "
     "(64 q-heads / 8 kv-heads): each layer round-trips 8x cache bytes "
     "and the repeated tensor is resharded across the model axis -> "
     "collective-bound decode.", {"cfg_overrides": {"decode_repeat_kv": True}}),
    ("B", "chameleon-34b", "decode_32k", "grouped_gqa_einsum",
     "Group q as [B, Hkv, G, D] and contract against the un-repeated "
     "cache: cache bytes/step drop 8x and the all-gather of the repeated "
     "KV disappears; predicted t_memory ~8x down, collective term "
     "dominated only by logits/activation psums.", {}),
    ("C", "xlstm-1.3b", "prefill_32k", "baseline_chunk256",
     "mLSTM chunkwise materializes [b,h,Q,Q] fp32 decay/score blocks in "
     "HBM per chunk; total QQ bytes scale as S*Q, so Q=256 dominates the "
     "memory term.", {}),
    ("C", "xlstm-1.3b", "prefill_32k", "chunk128",
     "Halve the chunk to Q=128 (still MXU-aligned): QQ-block bytes "
     "halve; predict t_memory ~415s -> ~210s with unchanged useful "
     "FLOPs.", {"cfg_overrides": {"scan_chunk": 128}}),
    ("C", "xlstm-1.3b", "prefill_32k", "chunk64",
     "Q=64: another 2x fewer QQ bytes, but sub-MXU tiles (64<128) start "
     "wasting systolic occupancy on real TPU; measure the memory-term "
     "win to weigh against it.", {"cfg_overrides": {"scan_chunk": 64}}),
    # --- round 2 (hypotheses updated from round-1 measurements) ---
    ("A", "qwen2-0.5b", "train_4k", "full_dp",
     "Round-1 CP was REFUTED: GSPMD inserted 8x more collective traffic "
     "than it saved in compute.  New hypothesis: a 0.5B model doesn't "
     "need TP at all — map batch over BOTH mesh axes (pure DP-256, "
     "params+attention replicated, ZeRO-1 over all 256 chips).  "
     "Attention compute divides by 256 instead of 16; grads all-reduce "
     "1GB bf16 -> ~0.08s collective.",
     {"rules_override": {"batch": ("data", "model"), "mlp": None,
                         "vocab": None, "heads": None, "kv_heads": None}}),
    ("C", "xlstm-1.3b", "prefill_32k", "chunk512",
     "Round-1 chunk-shrink was REFUTED: memory term GREW (415->448s as "
     "Q fell), so the dominant traffic is the per-chunk [b,h,dk,dv] "
     "fp32 state round-trip (nc proportional), not the QQ blocks.  New "
     "hypothesis: DOUBLE the chunk to 512 -> half the state round-trips; "
     "predict t_memory ~415 -> ~230s.",
     {"cfg_overrides": {"scan_chunk": 512}}),
    ("C", "xlstm-1.3b", "prefill_32k", "chunk1024",
     "Q=1024: quarter the state round-trips; QQ-block traffic (~S*Q) "
     "starts to bite back; measure the crossover.",
     {"cfg_overrides": {"scan_chunk": 1024}}),
    ("D", "qwen2-moe-a2.7b", "train_4k", "baseline",
     "The [E,C,d] MoE dispatch/combine tensors are all-reduced whole "
     "(2TB+/layer-set per device): GSPMD picks a replicated layout for "
     "the gather-built dispatch buffer.", {}),
    ("D", "qwen2-moe-a2.7b", "train_4k", "dispatch_sharding",
     "Pin the capacity dim of the dispatch/combine tensors to `data` "
     "with with_sharding_constraint (C aligned to 128): cross-shard "
     "token movement becomes all-to-all/all-gather of token rows; "
     "predict collective bytes down >10x.",
     {"cfg_overrides": {"moe_dispatch_axes": ("data",)}}),
    # --- round 3 ---
    ("B", "chameleon-34b", "decode_32k", "no_f32_cache_cast",
     "Round-2 left decode memory-bound at 0.55s/token — far above the "
     "~4ms cache read.  The explicit v_cache.astype(f32) in the combine "
     "einsum materializes an fp32 copy of the cache per layer; use "
     "preferred_element_type instead.  Predict t_memory down ~2x.", {}),
    ("C", "xlstm-1.3b", "prefill_32k", "gather_qkv",
     "Round-2 (refined analyzer) shows cell C is COLLECTIVE-bound "
     "(8.3s): each mLSTM block psums q/k/v projections that contract "
     "the model-sharded d_in.  Replicate the conv output once (one "
     "all-gather) and make wq/wk/wv column-parallel: 3 psums -> 1 "
     "gather per block; predict t_collective ~8.3 -> ~4s.",
     {"cfg_overrides": {"xlstm_gather_qkv": True}}),
    ("D", "qwen2-moe-a2.7b", "train_4k", "dispatch_shard_chunked",
     "Round-2 still 109GB/dev temps: the global [E, C, d] buffers are "
     "materialized at full capacity.  Scan tokens through the MoE in 8 "
     "chunks (C divides by 8): dispatch buffers shrink 8x; collective "
     "and temp memory should follow.",
     {"cfg_overrides": {"moe_dispatch_axes": ("data",),
                        "moe_scan_chunks": 8}}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="cell id A/B/C")
    ap.add_argument("--name", default=None, help="single variant name")
    args = ap.parse_args()
    path = os.path.join(RESULTS, "perf_log.json")
    log = json.load(open(path)) if os.path.exists(path) else []
    done = {(r["cell"], r["variant"]) for r in log}
    for cell, arch, shape, name, hypothesis, kw in ITERATIONS:
        if args.only and cell != args.only:
            continue
        if args.name and name != args.name:
            continue
        if (cell, name) in done:
            print(f"[perf] {cell}/{name} cached", flush=True)
            continue
        print(f"[perf] {cell} {arch} x {shape} :: {name}", flush=True)
        rec = dryrun.run_cell(arch, shape, multi_pod=False, verbose=True,
                              tag=name, **kw)
        rec.update({"cell": cell, "variant": name, "hypothesis": hypothesis})
        log.append(rec)
        json.dump(log, open(path, "w"), indent=1)
    # summary
    print("perf,cell,variant,t_compute_s,t_memory_s,t_collective_s,bottleneck")
    for r in log:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        print(f"perf,{r['cell']},{r['variant']},{ro['t_compute_s']:.3e},"
              f"{ro['t_memory_s']:.3e},{ro['t_collective_s']:.3e},"
              f"{ro['bottleneck']}")


if __name__ == "__main__":
    main()
