"""Fig. 7: QLMIO training convergence (reward, loss, latency, completion)
in the 15-server / 30-user configuration."""
import numpy as np

import json

from benchmarks.common import budget, emit, trained_predictors, world

from repro.core.d3qn import D3QNConfig
from repro.core.qlmio import QLMIO, QLMIOConfig
from repro.sim.cemllm import make_servers


def _cached(tag):
    from benchmarks.common import RESULTS
    import os as _os
    p = _os.path.join(RESULTS, tag + '.json')
    if _os.environ.get('BENCH_REUSE', '1') != '0' and _os.path.exists(p):
        return json.load(open(p))
    return None


def run(n_servers: int = 15, users: int = 30):
    q = None
    cached = _cached("fig7_qlmio_convergence")
    if cached is not None:
        hist = cached["history"]
    else:
        b = budget()
        bench, feats, split_ids = world()
        tr, va, te = split_ids
        milp_preds, mgqp_preds, _, _ = trained_predictors(bench, feats,
                                                          split_ids)
        servers = make_servers(n_servers, bench)
        episodes = b["episodes"]
        cfg = QLMIOConfig(episodes=episodes, users=users, seed=0,
                          agent=D3QNConfig(
                              eps_decay_steps=max(episodes * users // 2,
                                                  500)))
        q = QLMIO(bench, servers, feats, milp_preds, mgqp_preds, cfg)
        hist = q.train(tr)
    print("fig7,episode,avg_reward,avg_latency_s,completion_rate,loss")
    stride = max(1, len(hist) // 40)
    for h in hist[::stride]:
        print(f"fig7,{h['episode']},{h['avg_reward']:.3f},"
              f"{h['avg_latency_s']:.2f},{h['completion_rate']:.3f},"
              f"{h['loss']:.4f}")
    tail = hist[-max(1, len(hist) // 10):]
    print(f"fig7,converged_reward,{np.mean([h['avg_reward'] for h in tail]):.3f}")
    print(f"fig7,converged_completion,"
          f"{np.mean([h['completion_rate'] for h in tail]):.3f} "
          f"(paper: ~0.90)")
    emit("fig7_qlmio_convergence", {"history": hist})
    return q, hist


if __name__ == "__main__":
    run()
