"""Fig. 10 (repo extension): quality-latency tradeoff with REAL engines.

The paper's headline comparison (Fig. 8) runs offloading policies against
the closed-form CEMLLM-Sim; this benchmark replays the same MIOBench
arrival traces against **live ServingEngines** — paged KV cache, chunked
prefill, continuous batching — on a cloud-edge continuum under the
discrete-event harness (repro/serving/cluster.py).  Policies see the same
cost-model observations as in the sim (backend parity); latency/TTFT are
*measured* from real token generation under a virtual clock, and quality
comes from the success predictors.

CI-smoke entry: ``python benchmarks/fig10_continuum_replay.py`` finishes
on CPU in under a minute with tiny configs and asserts that QLMIO beats
the all-cloud baseline on mean e2e latency at a matching completion rate.
Sweep sizes scale with ``BENCH_BUDGET`` (smoke | fast | paper).

``--trace PATH`` additionally exports the qlmio replay's full telemetry
(request lifecycle spans, engine ticks, dispatch audit) as Perfetto-
loadable Chrome trace JSON — feed it to ``scripts/trace_report.py``.
The dispatch audit runs either way, so the emitted JSON always carries
``cost_model`` prediction-error percentiles (gated in
``benchmarks/baseline.json``).
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit  # noqa: E402

from repro.core.baselines import all_cloud_policy, greedy_policy  # noqa: E402
from repro.data.taskgen import CATEGORIES  # noqa: E402
from repro.serving.cluster import (  # noqa: E402
    Cluster,
    EngineBackend,
    build_continuum,
)
from repro.serving.telemetry import Telemetry  # noqa: E402
from repro.sim import cost_model as cm  # noqa: E402
from repro.sim.cemllm import make_servers_from_spec, run_policy  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate  # noqa: E402

# continuum spec ([(server_class, count), ...]): 1 cloud + 2 edge tiers
SPEC = [(2, 1), (1, 1), (0, 1)]

BUDGETS = {
    # arrival_dt tuned so the single cloud engine saturates under the
    # all-cloud policy while the continuum still absorbs the trace
    "smoke": dict(n_tasks=200, users=32, arrival_dt=0.01,
                  weights=(0.0, 1.0, 4.0)),
    "fast": dict(n_tasks=800, users=64, arrival_dt=0.01,
                 weights=(0.0, 0.25, 1.0, 2.0, 4.0)),
    "paper": dict(n_tasks=3377, users=128, arrival_dt=0.01,
                  weights=(0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0)),
}


def analytic_predictors(bench):
    """Idealized MILP/MGQP: the quarantined cost model evaluated without
    noise — [n_tasks, n_classes] latency estimates and success probs."""
    C = len(SERVER_CLASSES)
    aff = cm.category_affinity(len(CATEGORIES), C)
    t_hat = np.zeros((bench.tasks.n, C))
    b_hat = np.zeros((bench.tasks.n, C))
    for c, (dev, mdl) in enumerate(SERVER_CLASSES):
        t_hat[:, c] = cm.latency_s(cm.DEVICES[dev], cm.MODELS[mdl],
                                   bench.tasks.text_len,
                                   bench.tasks.difficulty)
        b_hat[:, c] = cm.success_prob(cm.MODELS[mdl], bench.tasks.difficulty,
                                      aff[bench.tasks.category, c])
    return t_hat, b_hat


def qlmio_policy(t_hat, b_hat, servers, w):
    """The QLMIO scoring rule (router Eq. 21 shape) over episode state."""
    cls = servers.cls

    def policy(ep):
        total = t_hat[ep.current_task, cls] + ep.queue_s
        u = -total / max(total.min(), 1e-6) + w * (
            3.0 * b_hat[ep.current_task, cls] - 2.0)
        return int(np.argmax(u))

    return policy


def milp_policy(t_hat, servers):
    """Latency-only: argmin predicted total latency."""
    cls = servers.cls

    def policy(ep):
        return int(np.argmin(t_hat[ep.current_task, cls] + ep.queue_s))

    return policy


def mgqp_policy(b_hat, servers):
    """Quality-only: argmax predicted success probability."""
    cls = servers.cls

    def policy(ep):
        return int(np.argmax(b_hat[ep.current_task, cls]))

    return policy


def run(trace_path: "str | None" = None):
    b = BUDGETS[os.environ.get("BENCH_BUDGET", "smoke")]
    bench = generate(seed=0, n_tasks=b["n_tasks"])
    servers = make_servers_from_spec(SPEC, bench)
    t_hat, b_hat = analytic_predictors(bench)
    rng = np.random.default_rng(0)
    tasks = rng.choice(bench.tasks.n, b["users"], replace=False)

    t0 = time.time()
    # dispatch audit always on (it feeds the gated cost_model metric);
    # span recording only when a trace export was requested
    tm = Telemetry(trace=bool(trace_path))
    handles = build_continuum(SPEC, seed=0, telemetry=tm)
    cluster = Cluster(handles)
    print(f"fig10,continuum,{len(handles)}_live_engines,"
          f"build_s,{time.time() - t0:.1f}")

    def replay(policy):
        cluster.reset()
        backend = EngineBackend(cluster, bench, servers,
                                arrival_dt=b["arrival_dt"])
        out = run_policy(policy, bench, servers, tasks,
                         np.random.default_rng(1), backend=backend)
        out["per_server_requests"] = [
            h.engine.latency_stats()["n_requests"] for h in handles]
        out["tokens_generated"] = int(sum(
            sum(len(r.output) for r in h.engine.finished)
            for h in handles))
        return out

    results = {}
    print("fig10,method,avg_e2e_s,p95_e2e_s,avg_ttft_s,completion_rate,"
          "per_server_requests")
    for name, policy in [
            ("all_cloud", all_cloud_policy(servers)),
            ("greedy", greedy_policy()),
            ("milp_only", milp_policy(t_hat, servers)),
            ("mgqp_only", mgqp_policy(b_hat, servers)),
            ("qlmio", qlmio_policy(t_hat, b_hat, servers, w=1.0))]:
        r = replay(policy)
        results[name] = r
        print(f"fig10,{name},{r['avg_latency_s']:.3f},"
              f"{r['p95_latency_s']:.3f},{r.get('avg_ttft_s', 0.0):.3f},"
              f"{r['completion_rate']:.3f},{r['per_server_requests']}")

    # the telemetry still holds the last (qlmio) replay — capture its
    # cost-model calibration and trace before the tradeoff sweep resets it
    pred_err = tm.prediction_error()
    print(f"fig10,cost_model,n={pred_err['n']},"
          f"mean_abs_pct_err,{pred_err['mean_abs_pct_err']:.2f},"
          f"p95_abs_pct_err,{pred_err['p95_abs_pct_err']:.2f}")
    if trace_path:
        tm.export(trace_path)
        print(f"fig10,trace,{trace_path},"
              f"{len(tm.tracer.events)}_events")

    # quality-latency tradeoff curve: sweep the QLMIO quality weight
    curve = []
    for w in b["weights"]:
        r = replay(qlmio_policy(t_hat, b_hat, servers, w))
        curve.append({"quality_weight": w,
                      "avg_e2e_s": r["avg_latency_s"],
                      "completion_rate": r["completion_rate"]})
        print(f"fig10,tradeoff,w={w},{r['avg_latency_s']:.3f},"
              f"{r['completion_rate']:.3f}")

    q, ac = results["qlmio"], results["all_cloud"]
    red = 1.0 - q["avg_latency_s"] / max(ac["avg_latency_s"], 1e-9)
    comp = q["completion_rate"] / max(ac["completion_rate"], 1e-9)
    print(f"fig10,headline,latency_reduction_vs_all_cloud,{red:.3f},"
          f"completion_vs_cloud,{comp:.3f},wall_s,{time.time() - t0:.1f}")
    emit("fig10_continuum_replay", {"results": results, "tradeoff": curve,
                                    "latency_reduction_vs_all_cloud": red,
                                    "completion_vs_cloud": comp,
                                    "cost_model": pred_err})
    # acceptance: real-engine QLMIO beats all-cloud on mean e2e latency at
    # a matching completion rate (paper Sec. V-F, now with live engines)
    assert q["avg_latency_s"] < ac["avg_latency_s"], \
        f"QLMIO {q['avg_latency_s']:.3f}s !< all-cloud " \
        f"{ac['avg_latency_s']:.3f}s"
    assert comp >= 0.95, f"completion ratio {comp:.3f} < 0.95"
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the qlmio replay's telemetry as Chrome "
                         "trace JSON (view in Perfetto, or feed to "
                         "scripts/trace_report.py)")
    run(trace_path=ap.parse_args().trace)
