"""Fig. 15 (repo extension): tensor-parallel serving as a continuum axis.

Three parts, one knob — the mesh width ``tp`` of distributed/tp.py:

  * **live decode**  — the real ``ServingEngine`` sharded over a
    host-platform mesh (``xla_force_host_platform_device_count``) at
    TP in {1, 2, 4, 8}: the emitted greedy streams must be bit-identical
    to the unsharded engine at every width (the all-gather TP scheme's
    contract), with measured wall decode throughput reported.  Wall
    numbers on an emulated CPU mesh measure XLA overhead, not speedup —
    identity is the assertion, the cost model below is the speedup.
  * **rooflines**    — the cost model's TP terms on the cloud class
    (rtx5090 / qwen3vl-30b): single-stream and wide-batch decode
    throughput and prefill at TP in {1, 2, 4, 8}, weights/KV bytes and
    FLOPs divided by ``tp`` plus the per-layer all-gather term on
    ``ici_bw`` — deterministic, gated tightly in baseline.json (the
    ``tp.*`` rows).
  * **continuum replay** — a bursty arrival trace over a sim-backend
    fleet (3 jetson edges + 1 cloud) replayed twice: flat cloud (tp=1)
    vs ``build_continuum(tp=4)`` where *only the sharded cloud* absorbs
    the burst.  TP must cut mean e2e at an equal-or-better completion
    rate — the gated ``fig15.*`` rows.

CI-smoke entry: ``python benchmarks/fig15_tensor_parallel.py --smoke``
finishes on CPU in a couple of minutes and asserts all of the above.
"""
import os
import dataclasses
import sys
import time

# the live part needs the host mesh *before* jax imports
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving.cluster import Cluster, build_continuum  # noqa: E402
from repro.serving.engine import Request, ServingEngine  # noqa: E402
from repro.serving.request import ContinuumRequest  # noqa: E402
from repro.distributed.tp import ShardedServing, serving_mesh  # noqa: E402
from repro.sim import cost_model as cm  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate  # noqa: E402

ARCH = "llama3.2-3b"  # dense GQA: heads/kv/mlp all shard at 2 and 4
TP_WIDTHS = (1, 2, 4, 8)

BUDGETS = {
    "smoke": dict(n_tasks=200, users=48, burst=8, burst_gap_s=0.40,
                  decode_cap=10, prompt_cap=40, live_tokens=8, live_reqs=3),
    "fast": dict(n_tasks=800, users=96, burst=10, burst_gap_s=0.35,
                 decode_cap=12, prompt_cap=48, live_tokens=12, live_reqs=4),
    "paper": dict(n_tasks=3377, users=256, burst=12, burst_gap_s=0.30,
                  decode_cap=14, prompt_cap=48, live_tokens=16, live_reqs=4),
}


# ------------------------------------------------------------ live mesh


def live_identity(b) -> dict:
    """Sharded decode at each width vs. the unsharded engine: the token
    streams must match exactly; wall tokens/s is reported for context."""
    cfg = reduced(get_config(ARCH))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 12).astype(np.int32)
               for _ in range(b["live_reqs"])]

    def serve(mesh=None):
        eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                            mesh=mesh)
        reqs = [Request(i, p.copy(), max_new_tokens=b["live_tokens"])
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        t0 = time.time()
        eng.run_until_drained()
        wall = time.time() - t0
        toks = sum(len(r.output) for r in reqs)
        return [tuple(r.output) for r in reqs], toks / max(wall, 1e-9)

    base, base_tps = serve()
    out = {"arch": ARCH, "widths": {}}
    print("fig15,live,tp,shards,identical,decode_tok_s")
    for tp in TP_WIDTHS:
        mesh = serving_mesh(tp)
        shards = ShardedServing(model, mesh).tp_shards
        got, tps = serve(mesh)
        ident = got == base
        out["widths"][f"tp{tp}"] = {"identical": bool(ident),
                                    "shards": list(shards),
                                    "decode_tok_s": tps}
        print(f"fig15,live,{tp},{'/'.join(shards) or 'replicated'},"
              f"{ident},{tps:.1f}")
        assert ident, f"TP={tp} stream diverged from single-device decode"
    out["base_decode_tok_s"] = base_tps
    return out


# ------------------------------------------------------- cost rooflines


def tp_rooflines() -> dict:
    """Deterministic TP scaling under the cost model on the cloud class:
    the ``tp.*`` rows the regression gate pins."""
    dev_name, prof_name = SERVER_CLASSES[-1]
    dev, prof = cm.DEVICES[dev_name], cm.MODELS[prof_name]
    ctx = 512.0

    def decode_tok_s(tp, batch=1):
        # one batched tick: weights stream once, each slot streams its
        # context; TP divides the bytes and adds the collective term
        weights = prof.n_active * prof.bytes_per_param
        kv = cm.kv_bytes_per_token(prof, "bf16") * ctx * batch
        tick = (weights + kv) / (dev.mem_bw * cm._EFF)
        if tp > 1:
            tick = tick / tp + float(cm.tp_collective_s(dev, prof, batch,
                                                        tp))
        return batch / tick

    out = {"device": dev.name, "profile": prof.name, "widths": {}}
    print("fig15,roofline,tp,decode_tok_s,wide32_tok_s,prefill_tok_s")
    for tp in TP_WIDTHS:
        d1 = decode_tok_s(tp)
        d32 = decode_tok_s(tp, batch=32)
        pf = 1.0 / float(cm.prefill_s(dev, prof, 1.0, tp=tp))
        out["widths"][f"tp{tp}"] = {"decode_tok_s": d1,
                                    "wide_batch_tok_s": d32,
                                    "prefill_tok_s": pf}
        print(f"fig15,roofline,{tp},{d1:.1f},{d32:.1f},{pf:.1f}")
    w = out["widths"]
    out["decode_speedup_tp4"] = w["tp4"]["decode_tok_s"] / \
        w["tp1"]["decode_tok_s"]
    out["decode_speedup_tp8"] = w["tp8"]["decode_tok_s"] / \
        w["tp1"]["decode_tok_s"]
    out["wide_batch_speedup_tp4"] = w["tp4"]["wide_batch_tok_s"] / \
        w["tp1"]["wide_batch_tok_s"]
    out["prefill_speedup_tp4"] = w["tp4"]["prefill_tok_s"] / \
        w["tp1"]["prefill_tok_s"]
    # narrow interconnects wash the win out: the same device with a
    # PCIe-class ici (jetson's 8 GB/s vs the cloud GPU's NVLink-class
    # 32 GB/s) scales strictly worse at every width
    narrow = dataclasses.replace(dev, ici_bw=cm.DEVICES[
        "jetson_orin_nano"].ici_bw)
    cd = [float(cm.decode_s(dev, prof, 1.0, tp=tp)) for tp in (1, 8)]
    nd = [float(cm.decode_s(narrow, prof, 1.0, tp=tp)) for tp in (1, 8)]
    out["cloud_tp8_speedup"] = cd[0] / cd[1]
    out["narrow_ici_tp8_speedup"] = nd[0] / nd[1]
    return out


# ------------------------------------------------------ continuum burst


def replay_burst(b, bench, tp) -> dict:
    """Bursty arrivals over 3 edges + 1 cloud (sim backend), greedy
    service+backlog dispatch; ``tp`` shards the cloud class only."""
    spec = [(0, 3), (2, 1)]
    handles = build_continuum(spec, backend="sim", max_batch=4,
                              max_seq=128, tp=tp)
    cluster = Cluster(handles)
    cls = np.array([SERVER_CLASSES.index((h.device.name, h.profile.name))
                    for h in handles])
    dtick = np.array([h.decode_tick_s for h in handles])
    ptok = np.array([h.prefill_tok_s for h in handles])
    link = np.array([h.up_s + h.down_s for h in handles])
    vocab = handles[0].cfg.vocab
    rng = np.random.default_rng(0)
    tasks = [int(t) for t in rng.choice(bench.tasks.n, b["users"],
                                        replace=False)]
    backlog = np.zeros(len(handles))
    t_prev = 0.0
    routed_cloud = 0
    for k, task in enumerate(tasks):
        t = (k // b["burst"]) * b["burst_gap_s"]
        cluster.advance_to(t)
        backlog = np.maximum(0.0, backlog - (t - t_prev))
        t_prev = t
        r = np.random.default_rng(1_000_003 * (task + 1))
        L = int(np.clip(bench.tasks.text_len[task], 8, b["prompt_cap"]))
        toks = r.integers(0, vocab, L).astype(np.int32)
        budget = int(np.clip(
            round(bench.tasks.difficulty[task] * b["decode_cap"]), 2,
            b["decode_cap"]))
        service = L * ptok + budget * dtick + link
        total = service + backlog
        s = int(np.argmin(total))
        routed_cloud += bool(handles[s].is_cloud)
        quality_ok = int(bench.score[task, int(cls[s])]) == 1
        cluster.submit(ContinuumRequest(
            tokens=toks, max_new_tokens=budget, arrival_s=t, task=task,
            quality_ok=quality_ok, server=s,
            predicted_s=float(total[s])))
        backlog[s] += L * ptok[s] + budget * dtick[s] / 4
    cluster.drain()
    recs = cluster.collect()
    return {"mean_e2e_s": float(np.mean([r["e2e_s"] for r in recs])),
            "p95_e2e_s": float(np.percentile(
                [r["e2e_s"] for r in recs], 95)),
            "completion_rate": float(np.mean(
                [r["success"] for r in recs])),
            "cloud_share": routed_cloud / len(tasks),
            "cloud_decode_tick_s": float(dtick[-1])}


def run():
    budget = "smoke" if "--smoke" in sys.argv[1:] else \
        os.environ.get("BENCH_BUDGET", "smoke")
    b = BUDGETS[budget]
    t0 = time.time()

    live = live_identity(b)
    roof = tp_rooflines()

    bench = generate(seed=0, n_tasks=b["n_tasks"])
    flat = replay_burst(b, bench, tp=None)
    tp4 = replay_burst(b, bench, tp=4)
    red = 1.0 - tp4["mean_e2e_s"] / max(flat["mean_e2e_s"], 1e-12)
    print("fig15,replay,policy,mean_e2e_s,p95_e2e_s,completion,"
          "cloud_share")
    for name, r in (("flat", flat), ("tp4_cloud", tp4)):
        print(f"fig15,replay,{name},{r['mean_e2e_s']:.4f},"
              f"{r['p95_e2e_s']:.4f},{r['completion_rate']:.3f},"
              f"{r['cloud_share']:.3f}")
    print(f"fig15,headline,e2e_reduction_vs_flat,{red:.3f},"
          f"decode_speedup_tp4,{roof['decode_speedup_tp4']:.3f},"
          f"wall_s,{time.time() - t0:.1f}")

    emit("fig15_tensor_parallel", {
        "fig15": {
            "results": {"flat": flat, "tp_cloud": tp4},
            "e2e_reduction_vs_flat": red,
            "completion_tp": tp4["completion_rate"],
            "live": live,
        },
        "tp": {k: roof[k] for k in
               ("decode_speedup_tp4", "decode_speedup_tp8",
                "wide_batch_speedup_tp4", "prefill_speedup_tp4",
                "narrow_ici_tp8_speedup", "cloud_tp8_speedup")},
    })

    # acceptance: bit-identity already asserted per width in
    # live_identity(); the TP terms must actually scale, the sharded
    # cloud must absorb the burst, and narrow interconnects must pay
    assert 2.0 < roof["decode_speedup_tp4"] <= 4.0
    assert roof["wide_batch_speedup_tp4"] > 2.0
    # prefill is compute-dense, so its per-token base is small enough
    # that the all-gather term dominates: sublinear on purpose
    assert 1.3 < roof["prefill_speedup_tp4"] <= 4.0
    assert roof["narrow_ici_tp8_speedup"] < roof["cloud_tp8_speedup"]
    assert tp4["cloud_decode_tick_s"] < flat["cloud_decode_tick_s"]
    assert tp4["mean_e2e_s"] < flat["mean_e2e_s"], \
        f"tp cloud {tp4['mean_e2e_s']:.4f} !< flat {flat['mean_e2e_s']:.4f}"
    assert tp4["completion_rate"] >= flat["completion_rate"]
    return {"live": live, "roofline": roof, "flat": flat, "tp4": tp4}


if __name__ == "__main__":
    run()
