"""Fig. 11 (repo extension): split-point offloading for multimodal requests.

MoA-Off / CE-CoLLM observe that for a multimodal LLM request the
interesting offloading decision sits *inside* the request: where does each
media input cross the cloud-edge boundary?  Ship the raw image/audio over
the server's uplink and encode it there (**raw-ship**), or run the
modality encoder on the user's edge device and ship keep-top-k-compressed
features (**edge-encode**)?  This benchmark replays multimodal MIOBench
traces — prompts are *typed segment lists*: real procedural media encoded
by the live ``models/mm_encoder.py`` into embedding spans, interleaved
with text tokens — against live ``ServingEngine``s under the continuum
harness (repro/serving/cluster.py), comparing both fixed split policies
with the QLMIO-chosen per-request split (``cost_model.best_split`` folded
into the routing scores).

Media costs are charged at paper scale (ViT-B/whisper encoder rooflines,
per-modality ``PAYLOAD_BYTES``) via ``MEDIA_SCALE``, the media analog of
the harness's ``time_scale``: the engines generate real tokens from real
injected features while the virtual clock prices the profiled hardware.

CI-smoke entry: ``python benchmarks/fig11_multimodal_split.py --smoke``
finishes on CPU well under a minute and asserts the QLMIO split choice
beats both fixed policies on mean e2e latency at an equal completion
rate.
"""
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit  # noqa: E402
from benchmarks.fig10_continuum_replay import analytic_predictors  # noqa: E402

from repro.models.mm_encoder import (  # noqa: E402
    MMEncoderConfig,
    encode_audio,
    encode_image,
    init_mm_encoder,
)
from repro.serving.cluster import Cluster, build_continuum  # noqa: E402
from repro.serving.request import ContinuumRequest  # noqa: E402
from repro.serving.segments import EmbedSegment, TextSegment  # noqa: E402
from repro.serving.telemetry import Telemetry  # noqa: E402
from repro.sim import cost_model as cm  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate  # noqa: E402

# continuum spec: 1 cloud (thin WAN, fast compute) + 2 LAN edge tiers
SPEC = [(2, 1), (1, 1), (0, 1)]

# the user's device: strong enough that edge-encoding beats pushing raw
# media through the cloud's thin WAN link, weak enough that raw-shipping
# to a LAN edge server (which encodes faster) wins there — the split
# decision is genuinely request- and server-dependent
USER_DEVICE = cm.DeviceProfile("user_edge_device", 3e12, 30e9, 12.5e6,
                               0.004)

# media charged at paper scale on reduced-scale engines (time_scale analog)
MEDIA_SCALE = 30.0

KEEP_RATIO = 1 / 3  # keep-top-k compression knob (feature-uplink bytes)

BUDGETS = {
    "smoke": dict(n_tasks=200, users=24, arrival_dt=0.05, decode_cap=8),
    "fast": dict(n_tasks=800, users=64, arrival_dt=0.05, decode_cap=10),
    "paper": dict(n_tasks=3377, users=128, arrival_dt=0.05, decode_cap=12),
}

AUDIO_FRAMES, AUDIO_MEL = 24, 16


def encode_media(bench, tasks, d_model: int, seed: int = 0):
    """Run the live tiny encoder over every task's procedural media once;
    returns {task: (EmbedSegment, MediaSpec) | None}."""
    enc_cfg = MMEncoderConfig(d_model=d_model, img_size=32, patch=8,
                              audio_dim=AUDIO_MEL, keep_ratio=KEEP_RATIO)
    params = init_mm_encoder(enc_cfg, jax.random.PRNGKey(seed + 17))
    img_ids = [t for t in tasks if bench.tasks.modality_name(t) == "image"]
    au_ids = [t for t in tasks if bench.tasks.modality_name(t) == "audio"]
    out = {t: None for t in tasks}
    if img_ids:
        feats = np.asarray(encode_image(
            enc_cfg, params, bench.tasks.images(img_ids, 32)), np.float32)
        spec = cm.media_spec("image", KEEP_RATIO)
        for t, f in zip(img_ids, feats):
            out[t] = (EmbedSegment(f, "image", spec.raw_bytes,
                                   spec.feature_bytes), spec)
    if au_ids:
        frames = np.stack([bench.tasks.audio(t, AUDIO_FRAMES, AUDIO_MEL)
                           for t in au_ids])
        feats = np.asarray(encode_audio(enc_cfg, params, frames),
                           np.float32)
        spec = cm.media_spec("audio", KEEP_RATIO)
        for t, f in zip(au_ids, feats):
            out[t] = (EmbedSegment(f, "audio", spec.raw_bytes,
                                   spec.feature_bytes), spec)
    return out


def run():
    budget = "smoke" if "--smoke" in sys.argv[1:] else \
        os.environ.get("BENCH_BUDGET", "smoke")
    b = BUDGETS[budget]
    bench = generate(seed=0, n_tasks=b["n_tasks"])
    t_hat, b_hat = analytic_predictors(bench)
    rng = np.random.default_rng(0)
    tasks = [int(t) for t in rng.choice(bench.tasks.n, b["users"],
                                        replace=False)]

    t0 = time.time()
    # base links carry the *text* payload only (request up, response
    # down); media bytes are charged per request by the chosen split via
    # media_delay_s — the default 300 KB payload would double-charge them
    tm = Telemetry(trace=False)  # dispatch audit only (media term incl.)
    handles = build_continuum(SPEC, seed=0, telemetry=tm,
                              payload_bytes=2 * cm.PAYLOAD_BYTES["text"])
    cluster = Cluster(handles)
    vocab = handles[0].cfg.vocab
    media = encode_media(bench, tasks, handles[0].cfg.d_model)
    n_media = sum(m is not None for m in media.values())
    print(f"fig11,continuum,{len(handles)}_live_engines,"
          f"{n_media}/{len(tasks)}_media_tasks,build_s,{time.time()-t0:.1f}")

    def text_span(task: int) -> np.ndarray:
        L = int(np.clip(bench.tasks.text_len[task], 1, 24))
        r = np.random.default_rng(1_000_003 * (task + 1))
        return r.integers(0, vocab, L).astype(np.int32)

    def gen_budget(task: int, server: int) -> int:
        out = cm.expected_out_tokens(handles[server].profile,
                                     float(bench.tasks.difficulty[task]))
        return int(np.clip(round(out / 40.0), 2, b["decode_cap"]))

    # server class of each handle, for the analytic predictor tables
    class_devices = [d for d, _ in SERVER_CLASSES]
    cls = np.array([class_devices.index(h.device.name) for h in handles])

    def split_costs(task: int):
        """[n_servers] dicts of scaled split costs, or None (text-only)."""
        m = media[task]
        if m is None:
            return None
        _, spec = m
        return [
            {k: v * MEDIA_SCALE for k, v in
             cm.split_point_s(spec, USER_DEVICE, h.device).items()}
            for h in handles]

    def replay(mode: str):
        """mode: 'raw' | 'edge' (forced split) | 'auto' (QLMIO-chosen)."""
        cluster.reset()
        t = 0.0
        choices = {"raw": 0, "edge": 0, "none": 0}
        for task in tasks:
            costs = split_costs(task)
            backlog = np.array([h._load()["backlog_s"] for h in handles])
            lat = t_hat[task, cls] + backlog
            if costs is not None:
                per_server = [c[mode] if mode != "auto" else min(c.values())
                              for c in costs]
                lat = lat + np.asarray(per_server)
            total = np.maximum(lat, 1e-9)
            u = -total / max(total.min(), 1e-6) + (
                3.0 * b_hat[task, cls] - 2.0)
            s = int(np.argmax(u))
            if costs is None:
                choices["none"] += 1
                delay, segs = 0.0, None
                toks = text_span(task)
            else:
                c = costs[s]
                choice = mode if mode != "auto" else min(c, key=c.get)
                choices[choice] += 1
                delay = c[choice]
                seg, _ = media[task]
                segs, toks = [seg, TextSegment(text_span(task))], None
            quality_ok = int(bench.score[task, int(cls[s])]) == 1
            budget_tok = gen_budget(task, s)
            if segs is not None:
                L = len(segs[0].features) + len(segs[1].tokens)
            else:
                L = len(toks)
            # predict before submit: the queue term must exclude this
            # request; the audit joins the measured e2e at collect()
            predicted, terms = handles[s].predict_e2e_s(
                L, budget_tok, media_delay_s=delay)
            uid = cluster.submit(ContinuumRequest(
                tokens=toks, segments=segs, max_new_tokens=budget_tok,
                arrival_s=t, task=task, quality_ok=quality_ok,
                media_delay_s=delay, server=s,
                predicted_s=float(predicted)))
            tm.record_dispatch(task=task, server=s, t=t,
                               predicted_s=predicted, uid=uid, terms=terms,
                               policy_est_s=float(total[s]))
            t += b["arrival_dt"]
            cluster.advance_to(t)
        cluster.drain()
        recs = cluster.collect()
        e2e = [r["e2e_s"] for r in recs]
        return {"mean_e2e_s": float(np.mean(e2e)),
                "p95_e2e_s": float(np.percentile(e2e, 95)),
                "completion_rate": float(np.mean(
                    [r["success"] for r in recs])),
                "split_choices": choices,
                "cost_model": tm.prediction_error()}

    results = {}
    print("fig11,policy,mean_e2e_s,p95_e2e_s,completion_rate,"
          "splits(raw/edge/none)")
    for mode, name in [("raw", "all_raw_ship"), ("edge", "all_edge_encode"),
                       ("auto", "qlmio_split")]:
        r = replay(mode)
        results[name] = r
        ch = r["split_choices"]
        print(f"fig11,{name},{r['mean_e2e_s']:.3f},{r['p95_e2e_s']:.3f},"
              f"{r['completion_rate']:.3f},"
              f"{ch['raw']}/{ch['edge']}/{ch['none']}")

    err = results["qlmio_split"]["cost_model"]
    print(f"fig11,cost_model,n={err['n']},"
          f"mean_abs_pct_err,{err['mean_abs_pct_err']:.2f},"
          f"p95_abs_pct_err,{err['p95_abs_pct_err']:.2f}")

    q = results["qlmio_split"]
    raw, edge = results["all_raw_ship"], results["all_edge_encode"]
    red_raw = 1.0 - q["mean_e2e_s"] / max(raw["mean_e2e_s"], 1e-9)
    red_edge = 1.0 - q["mean_e2e_s"] / max(edge["mean_e2e_s"], 1e-9)
    print(f"fig11,headline,e2e_reduction_vs_raw,{red_raw:.3f},"
          f"vs_edge,{red_edge:.3f},wall_s,{time.time() - t0:.1f}")
    emit("fig11_multimodal_split", {"results": results,
                                    "e2e_reduction_vs_raw_ship": red_raw,
                                    "e2e_reduction_vs_edge_encode": red_edge})
    # acceptance: the per-request QLMIO split choice beats both fixed
    # policies on mean e2e at an equal-or-better completion rate
    assert q["mean_e2e_s"] < raw["mean_e2e_s"], \
        f"qlmio {q['mean_e2e_s']:.3f}s !< all-raw {raw['mean_e2e_s']:.3f}s"
    assert q["mean_e2e_s"] < edge["mean_e2e_s"], \
        f"qlmio {q['mean_e2e_s']:.3f}s !< all-edge {edge['mean_e2e_s']:.3f}s"
    assert q["completion_rate"] >= max(raw["completion_rate"],
                                       edge["completion_rate"])
    return results


if __name__ == "__main__":
    run()
