"""Fig. 14 (repo extension): speculative decoding across the continuum.

Decode is one token per tick per slot, so the per-tick decode roofline
is the hard ITL floor under every e2e number the QLMIO tradeoff
optimizes.  Draft-k/verify-once speculation attacks that floor: a small
draft model proposes ``spec_k`` tokens, the target scores them in one
paged multi-token verify pass (kernels/paged_verify.py), and each tick
emits 1..k+1 bit-identical greedy tokens.  In the continuum it is also
a new split point — an edge engine can run the draft steps and ship
only token ids uplink while the cloud verifies — which the router
prices as a fourth dispatch shape next to raw-ship/edge-encode (PR 4)
and prefill-here/decode-there (PR 7).

Three policies over the same bursty MIOBench arrival trace, on a fleet
of live ``ServingEngine``s sharing one reduced arch + weight init:

  * **all_cloud**   — every request to the plain cloud handle (the
                      one-token-per-tick ITL floor);
  * **cloud_spec**  — every request to the cloud handle with colocated
                      speculation (draft + verify on the same device);
  * **qlmio_spec**  — QLMIO utility over every dispatch shape: pure
                      per-server, colocated speculation, and the
                      edge-drafts/cloud-verifies pair, each priced by
                      ``Cluster.predict_spec_e2e_s`` with the verify
                      engine's live measured acceptance rate fed back.

The speculative engines really draft/verify (the emitted stream is the
verify pass's argmax), while the virtual clock charges
``cost_model.speculative_tick_s`` — so the measured ITL reduction is
acceptance-discounted by what the draft model actually achieves, not by
an assumed rate.

CI-smoke entry: ``python benchmarks/fig14_speculative.py --smoke
--trace out.json`` finishes on CPU in about a minute and asserts the
speculative policies beat all-cloud on measured mean ITL at an
equal-or-better completion rate, with live acceptance telemetry in the
exported trace.
"""
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit  # noqa: E402
from benchmarks.fig10_continuum_replay import analytic_predictors  # noqa: E402

from repro.serving.cluster import Cluster, EngineHandle  # noqa: E402
from repro.serving.request import ContinuumRequest  # noqa: E402
from repro.serving.telemetry import Telemetry  # noqa: E402
from repro.sim import cost_model as cm  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate  # noqa: E402

ARCH = "qwen2-0.5b"
SPEC_K = 2  # draft depth: k=2 keeps the verify overhead below the
#             expected acceptance gain at the ~0.5 rate the reduced
#             draft actually achieves (see kernel_bench speculative)

BUDGETS = {
    "smoke": dict(n_tasks=200, users=24, burst=6, burst_gap_s=0.40,
                  decode_cap=12, prompt_cap=40),
    "fast": dict(n_tasks=800, users=64, burst=8, burst_gap_s=0.40,
                 decode_cap=12, prompt_cap=40),
    "paper": dict(n_tasks=3377, users=192, burst=10, burst_gap_s=0.35,
                  decode_cap=14, prompt_cap=48),
}

W_QUALITY = 4.0


def build_fleet(tm: Telemetry) -> "list[EngineHandle]":
    """One edge tier + three cloud handles over the same reduced arch and
    shared weights: plain decode, colocated speculation, and a verify
    handle whose draft steps are priced on the edge device (the
    edge-drafts/cloud-verifies shape — only token ids ride the uplink)."""
    edge_dev = cm.DEVICES["jetson_orin_nano"]
    cloud_dev = cm.DEVICES["rtx3090ti"]
    draft_prof = cm.MODELS["qwen3vl-2b"]
    cloud_prof = cm.MODELS["qwen3vl-8b"]
    kw = dict(seed=0, telemetry=tm,
              payload_bytes=2 * cm.PAYLOAD_BYTES["text"])
    cloud_kw = dict(is_cloud=True, max_batch=4, **kw)
    return [
        EngineHandle("edge-0 (jetson/plain)", ARCH, edge_dev, draft_prof,
                     is_cloud=False, **kw),
        EngineHandle("cloud-plain (3090ti)", ARCH, cloud_dev, cloud_prof,
                     **cloud_kw),
        EngineHandle("cloud-spec (3090ti)", ARCH, cloud_dev, cloud_prof,
                     draft_profile=draft_prof, spec_k=SPEC_K, **cloud_kw),
        EngineHandle("cloud-spec-edgedraft (3090ti)", ARCH, cloud_dev,
                     cloud_prof, draft_profile=draft_prof,
                     draft_device=edge_dev, spec_k=SPEC_K, **cloud_kw),
    ]


def run():
    budget = "smoke" if "--smoke" in sys.argv[1:] else \
        os.environ.get("BENCH_BUDGET", "smoke")
    trace_path = None
    argv = sys.argv[1:]
    if "--trace" in argv:
        trace_path = argv[argv.index("--trace") + 1]
    b = BUDGETS[budget]
    bench = generate(seed=0, n_tasks=b["n_tasks"])
    _, b_hat = analytic_predictors(bench)
    rng = np.random.default_rng(0)
    tasks = [int(t) for t in rng.choice(bench.tasks.n, b["users"],
                                        replace=False)]

    t0 = time.time()
    tm = Telemetry(trace=trace_path is not None)
    handles = build_fleet(tm)
    cluster = Cluster(handles)
    vocab = handles[0].cfg.vocab
    class_devices = [d for d, _ in SERVER_CLASSES]
    cls = np.array([class_devices.index(h.device.name) for h in handles])
    # speculative pairs whose *priced* draft device matches the handle's
    # configured one (charged tick == predicted tick by construction):
    # colocated cloud speculation and the edge-drafts/cloud-verifies pair
    spec_pairs = []
    for sv, hv in enumerate(handles):
        if hv.spec_tick_s is None:
            continue
        if hv.draft_device is hv.device:
            spec_pairs.append((sv, sv))
        else:
            spec_pairs.extend(
                (sa, sv) for sa, ha in enumerate(handles)
                if sa != sv and ha.device.name == hv.draft_device.name)
    print(f"fig14,continuum,{len(handles)}_live_engines,arch,{ARCH},"
          f"spec_k,{SPEC_K},spec_pairs,{spec_pairs},"
          f"build_s,{time.time() - t0:.1f}")

    def prompt(task: int) -> np.ndarray:
        L = int(np.clip(bench.tasks.text_len[task], 1, b["prompt_cap"]))
        r = np.random.default_rng(1_000_003 * (task + 1))
        return r.integers(0, vocab, L).astype(np.int32)

    def gen_budget(task: int, server: int) -> int:
        out = cm.expected_out_tokens(handles[server].profile,
                                     float(bench.tasks.difficulty[task]))
        return int(np.clip(round(out / 40.0), 4, b["decode_cap"]))

    def replay(policy: str):
        """policy: 'all_cloud' | 'cloud_spec' | 'qlmio_spec'."""
        cluster.reset()
        n_spec = 0
        for k, task in enumerate(tasks):
            t = (k // b["burst"]) * b["burst_gap_s"]
            cluster.advance_to(t)
            toks = prompt(task)
            if policy == "all_cloud":
                s, draft_server = 1, None
            elif policy == "cloud_spec":
                s, draft_server = 2, 2
            else:
                # (total_s, quality, server, draft_server) per shape
                shapes = []
                for si, h in enumerate(handles):
                    if h.spec_tick_s is not None:
                        continue  # spec handles dispatch via their pair
                    tot, _ = h.predict_e2e_s(len(toks),
                                             gen_budget(task, si))
                    shapes.append((tot, float(b_hat[task, cls[si]]),
                                   si, None))
                for sa, sv in spec_pairs:
                    r = cluster.predict_spec_e2e_s(
                        sa, sv, len(toks), gen_budget(task, sv))
                    if r is None:
                        continue
                    shapes.append((r[0], float(b_hat[task, cls[sv]]),
                                   sv, sa))
                norm = max(min(e[0] for e in shapes), 1e-6)
                best = max(shapes, key=lambda e: -e[0] / norm
                           + W_QUALITY * (3.0 * e[1] - 2.0))
                _, _, s, draft_server = best
            n_spec += draft_server is not None
            quality_ok = int(bench.score[task, int(cls[s])]) == 1
            budget_tok = gen_budget(task, s)
            predicted, terms = handles[s].predict_e2e_s(
                len(toks), budget_tok)
            uid = cluster.submit(ContinuumRequest(
                tokens=toks, max_new_tokens=budget_tok, arrival_s=t,
                task=task, quality_ok=quality_ok, server=s,
                draft_server=draft_server, predicted_s=float(predicted)))
            tm.record_dispatch(task=task, server=s, t=t,
                               predicted_s=predicted, uid=uid, terms=terms)
        cluster.drain()
        recs = cluster.collect()
        itl = [(r["e2e_s"] - r["ttft_s"]) / (r["n_tokens"] - 1)
               for r in recs if r["success"] and r["n_tokens"] > 1]
        acc = {h.name: h.engine.acceptance_rate() for h in handles
               if getattr(h.engine, "speculative", False)
               and h.engine.stats()["spec_tokens_drafted"] > 0}
        return {"mean_itl_s": float(np.mean(itl)),
                "p95_itl_s": float(np.percentile(itl, 95)),
                "mean_e2e_s": float(np.mean([r["e2e_s"] for r in recs])),
                "completion_rate": float(np.mean(
                    [r["success"] for r in recs])),
                "n_spec_dispatches": int(n_spec),
                "acceptance": acc}

    results = {}
    print("fig14,policy,mean_itl_s,p95_itl_s,mean_e2e_s,completion_rate,"
          "spec_dispatches")
    for name in ("all_cloud", "cloud_spec", "qlmio_spec"):
        r = replay(name)
        results[name] = r
        print(f"fig14,{name},{r['mean_itl_s']:.5f},{r['p95_itl_s']:.5f},"
              f"{r['mean_e2e_s']:.3f},{r['completion_rate']:.3f},"
              f"{r['n_spec_dispatches']}")
        if name == "qlmio_spec" and trace_path is not None:
            tm.export(trace_path)
            n_verify = sum(e.get("name") == "verify_tick"
                           for e in tm.tracer.events)
            print(f"fig14,trace,{trace_path},verify_tick_spans,{n_verify}")

    ac, cs, qs = (results["all_cloud"], results["cloud_spec"],
                  results["qlmio_spec"])
    red = 1.0 - qs["mean_itl_s"] / max(ac["mean_itl_s"], 1e-12)
    acc_rates = list(qs["acceptance"].values())
    mean_acc = float(np.mean(acc_rates)) if acc_rates else 0.0
    print(f"fig14,headline,itl_reduction_vs_all_cloud,{red:.3f},"
          f"acceptance,{mean_acc:.3f},wall_s,{time.time() - t0:.1f}")
    emit("fig14_speculative", {"fig14": {
        "results": results,
        "itl_reduction_vs_all_cloud": red,
        "completion_spec": qs["completion_rate"],
        "acceptance_rate": mean_acc,
        "n_spec_dispatches": qs["n_spec_dispatches"],
    }})
    # acceptance: speculation must lower the measured mean ITL at an
    # equal-or-better completion rate, via real (live-verified, traced)
    # speculative dispatches with a live-measured acceptance rate
    assert qs["mean_itl_s"] < ac["mean_itl_s"], \
        f"qlmio_spec ITL {qs['mean_itl_s']:.5f} !< " \
        f"all_cloud {ac['mean_itl_s']:.5f}"
    assert cs["mean_itl_s"] < ac["mean_itl_s"]
    assert qs["completion_rate"] >= ac["completion_rate"]
    assert qs["n_spec_dispatches"] > 0, "no speculative dispatches"
    assert 0.0 < mean_acc <= 1.0
    return results


if __name__ == "__main__":
    run()
