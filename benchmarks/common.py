"""Shared benchmark setup: bench/features/predictors with disk caching."""
from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.feature_store import compute_features  # noqa: E402
from repro.core.predictors import Predictor, PredictorConfig  # noqa: E402
from repro.data.taskgen import splits  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

BUDGETS = {
    # (n_tasks, encoder_profile, predictor_epochs, qlmio_episodes, trials)
    "smoke": dict(n_tasks=400, profile="tiny", epochs=6, episodes=60,
                  trials=5),
    "fast": dict(n_tasks=3377, profile="fast", epochs=30, episodes=300,
                 trials=30),
    "paper": dict(n_tasks=3377, profile="paper", epochs=50, episodes=12000,
                  trials=100),
}


def budget() -> dict:
    return BUDGETS[os.environ.get("BENCH_BUDGET", "smoke")]


def world(seed: int = 0):
    """(bench, (f_img, f_text), (tr, va, te)) under the active budget."""
    b = budget()
    bench = generate(seed=seed, n_tasks=b["n_tasks"])
    f_img, f_text = compute_features(bench.tasks, profile=b["profile"],
                                     cache_dir=os.path.join(RESULTS, "cache"))
    return bench, (f_img, f_text), splits(bench.tasks.n, seed)


def flat_records(bench, f_text, f_img, ids):
    C = len(SERVER_CLASSES)
    t = np.repeat(ids, C)
    c = np.tile(np.arange(C), len(ids))
    return {"f_text": f_text[t], "f_img": f_img[t],
            "model_id": bench.model_id[c], "device_id": bench.device_id[c],
            "label": (bench.score[t, c] == 1).astype(np.int64),
            "latency_s": bench.latency_s[t, c].astype(np.float32)}


def trained_predictors(bench, feats, split_ids, *, epochs=None, seed=0):
    """Train (or load cached) MGQP + MILP; return predictions [N, C]."""
    b = budget()
    epochs = epochs or b["epochs"]
    f_img, f_text = feats
    tr, va, _ = split_ids
    tag = f"preds_{b['profile']}_{bench.tasks.n}_{epochs}_{seed}.npz"
    path = os.path.join(RESULTS, "cache", tag)
    if os.path.exists(path):
        z = np.load(path, allow_pickle=True)
        return (z["milp"], z["mgqp"], json.loads(str(z["hist_milp"])),
                json.loads(str(z["hist_mgqp"])))
    cfgp = PredictorConfig(epochs=epochs, batch=256, seed=seed)
    milp = Predictor("latency", 8, 8, cfgp, feat_dim=f_text.shape[1])
    hist_milp = milp.fit(flat_records(bench, f_text, f_img, tr),
                         flat_records(bench, f_text, f_img, va))
    mgqp = Predictor("quality", 8, 8, cfgp, feat_dim=f_text.shape[1])
    hist_mgqp = mgqp.fit(flat_records(bench, f_text, f_img, tr),
                         flat_records(bench, f_text, f_img, va))
    C = len(SERVER_CLASSES)
    allb = {"f_text": np.repeat(f_text, C, 0),
            "f_img": np.repeat(f_img, C, 0),
            "model_id": np.tile(bench.model_id, bench.tasks.n),
            "device_id": np.tile(bench.device_id, bench.tasks.n)}
    milp_preds = milp.predict(allb).reshape(-1, C).astype(np.float32)
    mgqp_preds = mgqp.predict(allb).reshape(-1, C).astype(np.float32)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez_compressed(path, milp=milp_preds, mgqp=mgqp_preds,
                        hist_milp=json.dumps(hist_milp),
                        hist_mgqp=json.dumps(hist_mgqp))
    return milp_preds, mgqp_preds, hist_milp, hist_mgqp


def emit(name: str, payload: dict):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return payload
