"""Roofline table from the dry-run artifacts (results/dryrun.json).

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line lever on the dominant term.
Also nominates the three hillclimb cells (worst roofline fraction, most
collective-bound, most representative of the paper's serving technique).
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import RESULTS, emit  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.models.counting import model_flops  # noqa: E402

LEVERS = {
    "compute": "shard the replicated attention compute (context/sequence "
               "parallelism over `model`) or cut remat recompute",
    "memory": "move streaming-softmax/SSD inner loops into the Pallas "
              "kernels (VMEM-resident accumulators) to kill score-block "
              "HBM round-trips",
    "collective": "reorder TP activation psums (reduce-scatter + local "
                  "compute), overlap grad all-reduce with backward, or "
                  "drop TP width for this shape",
}


def load(path=None):
    path = path or os.path.join(RESULTS, "dryrun.json")
    return json.load(open(path))


def rows(records):
    out = []
    for r in records:
        if r.get("status") != "ok":
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        roof = r["roofline"]
        mf = model_flops(cfg, shape)
        hlo_global = roof["flops_per_device"] * r["n_chips"]
        terms = {"compute": roof["t_compute_s"], "memory": roof["t_memory_s"],
                 "collective": roof["t_collective_s"]}
        t_max = max(terms.values())
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "t_compute_s": roof["t_compute_s"],
            "t_memory_s": roof["t_memory_s"],
            "t_collective_s": roof["t_collective_s"],
            "bottleneck": roof["bottleneck"],
            "model_flops": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": mf / max(hlo_global, 1.0),
            "roofline_fraction": terms["compute"] / max(t_max, 1e-12),
            "lever": LEVERS[roof["bottleneck"]],
        })
    return out


def run(path=None):
    table = rows(load(path))
    print("roofline,arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
          "bottleneck,useful_ratio,roofline_fraction")
    for r in table:
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
              f"{r['t_collective_s']:.3e},{r['bottleneck']},"
              f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f}")
    single = [r for r in table if r["mesh"] == "16x16"]
    if single:
        worst = min(single, key=lambda r: r["roofline_fraction"])
        coll = max(single, key=lambda r: r["t_collective_s"]
                   / max(r["t_compute_s"], 1e-12))
        print(f"roofline,hillclimb_worst_fraction,{worst['arch']},"
              f"{worst['shape']},{worst['roofline_fraction']:.3f}")
        print(f"roofline,hillclimb_most_collective,{coll['arch']},"
              f"{coll['shape']},"
              f"{coll['t_collective_s'] / max(coll['t_compute_s'], 1e-12):.2f}x")
    emit("roofline_table", {"rows": table})
    return table


if __name__ == "__main__":
    run()
