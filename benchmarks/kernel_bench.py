"""Kernel microbenchmarks.

On CPU, wall-clock measures the interpret path (not TPU performance), so we
report (a) correctness error vs. oracle and (b) the analytic TPU roofline
time for each kernel's workload: FLOPs / 197 TF and bytes / 819 GB/s, the
numbers the §Perf iterations use.

``python benchmarks/kernel_bench.py serving`` runs only the serving-engine
prefill benchmark (mixed-length workload, TTFT/ITL percentiles + XLA
compile counts); ``... serving paged_kv`` adds the analytic paged-KV
memory/throughput section — the CI smoke entry.  ``--json PATH`` writes
every section that ran to one JSON file, the input of the CI benchmark
regression gate (``scripts/check_bench.py`` vs. ``benchmarks/
baseline.json``).  ``--profile DIR`` wraps the timing loops in
``jax.profiler.trace``: the XLA/TPU profile lands in ``DIR`` (open with
TensorBoard or Perfetto), next to the serving-layer traces
``fig10_continuum_replay.py --trace`` exports.
"""
import contextlib
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

from repro.distributed.tp import serving_mesh
from repro.kernels import ops, ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.serving.kv_cache import kv_token_bytes


def _roof(flops, bytes_):
    return max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)


def serving_prefill_bench():
    """Mixed-prompt-length serving workload: the bucketed + chunked prefill
    scheduler vs. the legacy path (exact-shape monolithic prefill).

    The legacy path retraces prefill for every distinct prompt length (a
    recompile storm) and a long prompt's monolithic prefill stalls every
    decoding slot for the whole tick; the fix bounds traces to the bucket
    count and spreads prefill over a per-tick token budget.  Reported:
    wall-clock TTFT/ITL p50/p95 per mode, prefill trace (compile) counts,
    and total wall time — on CPU the wall numbers are dominated by exactly
    the XLA compiles the bucketing removes, which is the point.
    """
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = [3, 5, 9, 13, 17, 23, 29, 31, 37, 41, 45, 49, 53, 57, 60, 62]
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]
    modes = {
        "chunked": dict(prefill_chunk=16),          # the fix (default path)
        "chunked_int8": dict(prefill_chunk=16, kv_dtype="int8"),
        "bucketed_monolithic": dict(prefill_chunk=0),
        "legacy": dict(prefill_chunk=0, bucket_prompts=False),
    }
    print("serving,mode,ttft_p50_ms,ttft_p95_ms,itl_p50_ms,itl_p95_ms,"
          "prefill_traces,wall_s")
    out = {}
    for mode, kw in modes.items():
        eng = ServingEngine(model, params, max_batch=4, max_seq=64,
                            paged=True, page_size=8, **kw)
        t0 = time.time()
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=8))
        eng.run_until_drained(keep_finished=True)
        wall = time.time() - t0
        lat = eng.latency_stats()
        traces = eng.prefill_trace_count()
        out[mode] = {**lat, "prefill_traces": traces, "wall_s": wall,
                     **{k: v for k, v in eng.stats().items()
                        if k.startswith("prefill")}}
        print(f"serving,{mode},{lat['ttft_p50_s']*1e3:.1f},"
              f"{lat['ttft_p95_s']*1e3:.1f},{lat['itl_p50_s']*1e3:.1f},"
              f"{lat['itl_p95_s']*1e3:.1f},{traces},{wall:.1f}")
    ratio = (out["legacy"]["ttft_p95_s"]
             / max(out["chunked"]["ttft_p95_s"], 1e-9))
    print(f"serving,ttft_p95_speedup_chunked_vs_legacy,{ratio:.2f}x,"
          f"traces {out['legacy']['prefill_traces']}"
          f"->{out['chunked']['prefill_traces']}")
    emit("serving_prefill", {"workload_lens": lens, "modes": out,
                             "ttft_p95_speedup": ratio})
    return out


def paged_kv_bench():
    """KV memory footprint + decode throughput (analytic, deterministic):
    dense pads every slot to max_seq while the paged pool sizes to the
    workload's live tokens, and the int8 pool (kv_dtype="int8": symmetric
    per-row int8 values + fp32 scales, repro/kernels/quant.py) carries
    ``Dh + 4`` bytes per head row against bf16's ``2 * Dh`` — halving the
    per-tick decode KV stream *and* the pool footprint.  Workload: 8
    slots, lengths 0.5-8k, max_seq 8k, L=32 layers of the flash-decode
    shape used in ``run``."""
    H, Hkv, D, bs_pg = 8, 2, 128, 64
    L, max_seq = 32, 8192
    lens = [512, 1024, 1536, 2048, 3072, 4096, 6144, 8192]
    tok_bytes = kv_token_bytes(L, Hkv, D, "bf16")  # K+V bf16, all layers
    tok_bytes_i8 = kv_token_bytes(L, Hkv, D, "int8")
    layer_bytes = kv_token_bytes(1, Hkv, D, "bf16")  # decode streams 1 layer
    layer_bytes_i8 = kv_token_bytes(1, Hkv, D, "int8")
    dense_bytes = len(lens) * max_seq * tok_bytes
    paged_pages = sum(-(-n // bs_pg) for n in lens)
    paged_bytes = (1 + paged_pages) * bs_pg * tok_bytes
    int8_bytes = (1 + paged_pages) * bs_pg * tok_bytes_i8
    dense_step_s = _roof(2 * 2 * H * D * sum(lens),
                         sum(max_seq for _ in lens) * layer_bytes)
    paged_step_s = _roof(2 * 2 * H * D * sum(lens),
                         sum(lens) * layer_bytes)
    int8_step_s = _roof(2 * 2 * H * D * sum(lens),
                        sum(lens) * layer_bytes_i8)
    print("paged_kv,metric,dense,paged,ratio")
    print(f"paged_kv,kv_bytes_per_layer_stack,{dense_bytes},{paged_bytes},"
          f"{dense_bytes / paged_bytes:.2f}")
    print(f"paged_kv,decode_roofline_tok_s,{len(lens) / dense_step_s:.0f},"
          f"{len(lens) / paged_step_s:.0f},"
          f"{dense_step_s / paged_step_s:.2f}")
    print("paged_kv,metric,bf16,int8,ratio")
    print(f"paged_kv,kv_bytes_per_token,{tok_bytes},{tok_bytes_i8},"
          f"{tok_bytes / tok_bytes_i8:.2f}")
    print(f"paged_kv,int8_decode_roofline_tok_s,"
          f"{len(lens) / paged_step_s:.0f},{len(lens) / int8_step_s:.0f},"
          f"{paged_step_s / int8_step_s:.2f}")
    return emit("paged_kv_memory", {
        "workload_lens": lens, "max_seq": max_seq, "block_size": bs_pg,
        "dense_kv_bytes": dense_bytes, "paged_kv_bytes": paged_bytes,
        "memory_ratio": dense_bytes / paged_bytes,
        "dense_decode_tok_s": len(lens) / dense_step_s,
        "paged_decode_tok_s": len(lens) / paged_step_s,
        "int8": {
            "kv_bytes_per_token_bf16": tok_bytes,
            "kv_bytes_per_token_int8": tok_bytes_i8,
            "kv_bytes_per_token_ratio": tok_bytes / tok_bytes_i8,
            "pool_bytes_int8": int8_bytes,
            "pool_bytes_ratio": paged_bytes / int8_bytes,
            "decode_tok_s": len(lens) / int8_step_s,
            "decode_tok_s_ratio": paged_step_s / int8_step_s,
        },
    })


def speculative_bench():
    """Multi-token verification vs k+1 sequential paged decode steps.

    The verify kernel scores ``k`` drafted tokens plus the last accepted
    token in one pass: the paged KV stream is read *once* for all k+1
    query rows, where sequential decode re-reads it every step — so the
    roofline tokens/s scales ~(k+1)x on the memory-bound side, in bf16
    and (halved stream) fused-dequant int8.  What the decode loop
    actually gains is acceptance-discounted: a tick emits
    ``expected_accepted(k, a)`` tokens (cost_model), so the effective
    ITL is swept over acceptance rates here.  Correctness: CPU-interpret
    kernel vs the jnp gather oracle, finite + max-err reported per k."""
    from repro.kernels.quant import quantize_kv
    from repro.sim.cost_model import expected_accepted

    B, H, Hkv, D = 1, 8, 2, 128
    S2, bs_pg = 8192, 64
    NB = S2 // bs_pg
    rng = np.random.default_rng(7)
    kp = jnp.asarray(rng.normal(size=(1 + NB, bs_pg, Hkv, D)), jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(1 + NB, bs_pg, Hkv, D)), jnp.bfloat16)
    bt = jnp.arange(1, NB + 1, dtype=jnp.int32)[None]  # [1, NB]
    kp8, kps = quantize_kv(kp)
    vp8, vps = quantize_kv(vp)
    layer = kv_token_bytes(1, Hkv, D, "bf16")
    layer_i8 = kv_token_bytes(1, Hkv, D, "int8")
    out = {"workload": f"B{B}xS{S2}xH{H}xbs{bs_pg}"}
    print("speculative,k,seq_tok_s,verify_tok_s,speedup,verify_tok_s_int8,"
          "max_err,max_err_int8")
    for k in (2, 4, 8):
        T = k + 1
        # sequential: T decode passes, each streams the whole paged KV
        seq_s = T * _roof(2 * 2 * H * S2 * D, B * S2 * layer)
        seq_s_i8 = T * _roof(2 * 2 * H * S2 * D, B * S2 * layer_i8)
        # verify: one pass, KV streamed once for all T query rows
        ver_s = _roof(2 * 2 * H * T * S2 * D, B * S2 * layer)
        ver_s_i8 = _roof(2 * 2 * H * T * S2 * D, B * S2 * layer_i8)
        # correctness on a prefix+draft layout: drafts occupy the last T
        # positions of the sequence, queries attend causally over both
        pos = jnp.full((B,), S2 - T, jnp.int32)
        q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.bfloat16)
        o = ops.paged_verify(q, kp, vp, bt, pos)
        err = float(jnp.max(jnp.abs(
            o.astype(jnp.float32)
            - ref.paged_verify_ref(q, kp, vp, bt, pos)
            .astype(jnp.float32))))
        o8 = ops.paged_verify_quant(q, kp8, vp8, kps, vps, bt, pos)
        err8 = float(jnp.max(jnp.abs(
            o8.astype(jnp.float32)
            - ref.paged_verify_quant_ref(q, kp8, vp8, kps, vps, bt, pos)
            .astype(jnp.float32))))
        out[f"k{k}"] = {
            "seq_tok_s": T / seq_s, "verify_tok_s": T / ver_s,
            "verify_speedup": seq_s / ver_s,
            "seq_tok_s_int8": T / seq_s_i8,
            "verify_tok_s_int8": T / ver_s_i8,
            "verify_speedup_int8": seq_s_i8 / ver_s_i8,
            "max_err": err, "max_err_int8": err8,
            # acceptance-swept effective ITL: one verify tick emits
            # expected_accepted(k, a) tokens on average
            "effective_itl_us": {
                f"a{a:.1f}": ver_s / float(expected_accepted(k, a)) * 1e6
                for a in (0.3, 0.5, 0.7, 0.9)},
        }
        r = out[f"k{k}"]
        print(f"speculative,{k},{r['seq_tok_s']:.0f},"
              f"{r['verify_tok_s']:.0f},{r['verify_speedup']:.2f},"
              f"{r['verify_tok_s_int8']:.0f},{err:.2e},{err8:.2e}")
    return emit("speculative_verify", out)


def run():
    rng = np.random.default_rng(0)
    rows = []

    # flash attention, one v5e-chip-sized tile of work
    B, S, H, Hkv, D = 1, 2048, 8, 2, 128
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    t0 = time.time()
    o = ops.flash_attention(q, k, v, block_q=256, block_k=256)
    err = float(jnp.max(jnp.abs(
        o.astype(jnp.float32)
        - ref.flash_attention_ref(q, k, v).astype(jnp.float32))))
    flops = 2 * 2 * B * H * S * S / 2 * D
    byts = (q.size + 2 * k.size + o.size) * 2
    rows.append(("flash_attention", f"B{B}xS{S}xH{H}xD{D}", err,
                 _roof(flops, byts), time.time() - t0))

    # flash decode
    S2 = 8192
    q1 = jnp.asarray(rng.normal(size=(B, H, D)), jnp.bfloat16)
    kc = jnp.asarray(rng.normal(size=(B, S2, Hkv, D)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(size=(B, S2, Hkv, D)), jnp.bfloat16)
    cpos = jnp.broadcast_to(jnp.arange(S2), (B, S2)).astype(jnp.int32)
    pos = jnp.full((B,), S2 - 1, jnp.int32)
    t0 = time.time()
    o = ops.flash_decode(q1, kc, vc, cpos, pos, block_k=512)
    err = float(jnp.max(jnp.abs(
        o.astype(jnp.float32)
        - ref.flash_decode_ref(q1, kc, vc, cpos, pos).astype(jnp.float32))))
    flops = 2 * 2 * B * H * S2 * D
    byts = 2 * kc.size * 2
    rows.append(("flash_decode", f"B{B}xS{S2}xH{H}", err, _roof(flops, byts),
                 time.time() - t0))

    # paged flash decode: same contraction as flash_decode but K/V gathered
    # through a block table over a page pool (repro/serving/kv_cache.py)
    bs_pg = 64
    NB = S2 // bs_pg
    n_pages = 1 + NB  # null page + one sequence's pages
    kp = jnp.asarray(rng.normal(size=(n_pages, bs_pg, Hkv, D)), jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(n_pages, bs_pg, Hkv, D)), jnp.bfloat16)
    bt = jnp.arange(1, NB + 1, dtype=jnp.int32)[None]  # [1, NB]
    t0 = time.time()
    o = ops.paged_decode(q1, kp, vp, bt, pos)
    err = float(jnp.max(jnp.abs(
        o.astype(jnp.float32)
        - ref.paged_decode_ref(q1, kp, vp, bt, pos).astype(jnp.float32))))
    flops = 2 * 2 * B * H * S2 * D
    byts = 2 * B * S2 * Hkv * D * 2  # K+V bf16: same bytes, no gather copy
    paged_roof = _roof(flops, byts)
    rows.append(("paged_decode", f"B{B}xS{S2}xH{H}xbs{bs_pg}", err,
                 paged_roof, time.time() - t0))

    # fused-dequant paged decode: pages stay int8 in HBM (half the KV
    # stream), per-row fp32 scales ride as extra VMEM operands
    from repro.kernels.quant import quantize_kv
    kp8, kps = quantize_kv(kp)
    vp8, vps = quantize_kv(vp)
    t0 = time.time()
    o = ops.paged_decode_quant(q1, kp8, vp8, kps, vps, bt, pos)
    err = float(jnp.max(jnp.abs(
        o.astype(jnp.float32)
        - ref.paged_decode_quant_ref(q1, kp8, vp8, kps, vps, bt,
                                     pos).astype(jnp.float32))))
    byts_i8 = B * S2 * kv_token_bytes(1, Hkv, D, "int8")
    rows.append(("paged_decode_int8", f"B{B}xS{S2}xH{H}xbs{bs_pg}", err,
                 _roof(flops, byts_i8), time.time() - t0))

    paged = paged_kv_bench()
    spec = speculative_bench()

    # SSD scan
    b2, S3, h2, p2, n2 = 1, 1024, 8, 64, 64
    x = jnp.asarray(rng.normal(size=(b2, S3, h2, p2)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b2, S3, h2)), jnp.float32)
    a_neg = -jnp.asarray(rng.uniform(0.1, 1.0, (h2,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b2, S3, n2)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b2, S3, n2)), jnp.float32)
    t0 = time.time()
    y = ops.ssd_scan(x, dt, a_neg, Bm, Cm, chunk=256)
    err = float(jnp.max(jnp.abs(y - ref.ssd_scan_ref(x, dt, a_neg, Bm, Cm))))
    Q = 256
    flops = b2 * h2 * (S3 / Q) * (2 * Q * Q * n2 + 2 * Q * Q * p2
                                  + 4 * Q * p2 * n2)
    byts = 4 * (x.size + Bm.size + Cm.size + y.size)
    rows.append(("mamba2_ssd", f"S{S3}xh{h2}xp{p2}xn{n2}", err,
                 _roof(flops, byts), time.time() - t0))

    # grouped matmul
    E, C, K, N = 16, 256, 1024, 1024
    xg = jnp.asarray(rng.normal(size=(E, C, K)), jnp.bfloat16)
    wg = jnp.asarray(rng.normal(size=(E, K, N)), jnp.bfloat16)
    t0 = time.time()
    g = ops.grouped_matmul(xg, wg)
    err = float(jnp.max(jnp.abs(
        g.astype(jnp.float32)
        - ref.grouped_matmul_ref(xg, wg).astype(jnp.float32))))
    rows.append(("moe_gmm", f"E{E}x{C}x{K}x{N}", err,
                 _roof(2 * E * C * K * N, 2 * (xg.size + wg.size + g.size)),
                 time.time() - t0))

    # rmsnorm
    xr = jnp.asarray(rng.normal(size=(4096, 2048)), jnp.bfloat16)
    sc = jnp.asarray(rng.normal(size=(2048,)), jnp.float32)
    t0 = time.time()
    r = ops.rmsnorm(xr, sc)
    err = float(jnp.max(jnp.abs(
        r.astype(jnp.float32)
        - ref.rmsnorm_ref(xr, sc).astype(jnp.float32))))
    rows.append(("rmsnorm", "4096x2048", err,
                 _roof(4 * xr.size, 2 * 2 * xr.size), time.time() - t0))

    print("kernel,name,workload,max_err_vs_oracle,tpu_roofline_us,"
          "cpu_interpret_s")
    for name, wl, err, roof_s, wall in rows:
        print(f"kernel,{name},{wl},{err:.2e},{roof_s*1e6:.1f},{wall:.1f}")
    emit("kernel_bench", {"rows": [
        {"name": n, "workload": w, "err": e, "tpu_roofline_us": r_ * 1e6,
         "cpu_wall_s": wl} for n, w, e, r_, wl in rows]})
    serving = serving_prefill_bench()
    return {"kernels": {n: {"workload": w, "err": e,
                            "tpu_roofline_us": r_ * 1e6, "cpu_wall_s": wl}
                        for n, w, e, r_, wl in rows},
            "paged_kv": paged, "speculative": spec, "serving": serving}


def _flag_value(args: "list[str]", flag: str) -> "str | None":
    if flag not in args:
        return None
    i = args.index(flag)
    if i + 1 >= len(args):
        raise SystemExit(f"kernel_bench: {flag} needs a value")
    value = args[i + 1]
    del args[i:i + 2]
    return value


def main(argv: "list[str]") -> dict:
    """CLI: positional section names (``serving``, ``paged_kv``; none =
    full kernel sweep) + optional ``--json PATH`` writing every section
    that ran to one file for ``scripts/check_bench.py``, and optional
    ``--profile DIR`` recording a ``jax.profiler.trace`` around the
    timing loops (kernel-level XLA/TPU profile)."""
    args = list(argv)
    json_path = _flag_value(args, "--json")
    profile_dir = _flag_value(args, "--profile")
    sections = [a for a in args if not a.startswith("-")]
    unknown = [s for s in sections
               if s not in ("serving", "paged_kv", "speculative")]
    if unknown:
        raise SystemExit(f"kernel_bench: unknown section(s) {unknown}; "
                         "available: serving, paged_kv, speculative "
                         "(none = full sweep)")
    out = {}
    with contextlib.ExitStack() as stack:
        if profile_dir is not None:
            try:
                stack.enter_context(jax.profiler.trace(profile_dir))
                print(f"kernel_bench: profiling to {profile_dir}")
            except Exception as e:  # profiler backend unavailable
                print(f"kernel_bench: --profile disabled ({e})")
        if "paged_kv" in sections:
            out["paged_kv"] = paged_kv_bench()
        if "speculative" in sections:
            out["speculative"] = speculative_bench()
        if "serving" in sections:
            out["serving"] = serving_prefill_bench()
        if not sections:
            out = run()  # full sweep: kernels + paged_kv + serving
    if json_path:
        # provenance block so a checked-in results file says what ran it:
        # numbers from an emulated host mesh vs a real accelerator are
        # not comparable, and mesh shape pins the TP width benchmarked
        dev = jax.devices()[0]
        out["meta"] = {
            "backend": jax.default_backend(),
            "device_kind": dev.device_kind,
            "device_count": jax.device_count(),
            "mesh_shape": dict(serving_mesh(jax.device_count()).shape),
            "sections": sections or ["full_sweep"],
        }
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"kernel_bench: wrote {json_path}")
    return out


if __name__ == "__main__":
    main(sys.argv[1:])
