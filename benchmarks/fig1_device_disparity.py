"""Fig. 1: generation quality / response latency disparity across devices."""
from benchmarks.common import emit, world

from repro.sim.miobench import SERVER_CLASSES, summary


def run():
    bench, _, _ = world()
    s = summary(bench)
    rows = []
    for dev, _mdl in SERVER_CLASSES:
        r = s[dev]
        rows.append((dev, r["model"], r["accuracy"], r["timeout_rate"],
                     r["latency_p50_s"], r["latency_p95_s"]))
    print("fig1,device,model,accuracy,timeout_rate,lat_p50_s,lat_p95_s")
    for row in rows:
        print("fig1," + ",".join(f"{x:.4f}" if isinstance(x, float) else str(x)
                                 for x in row))
    emit("fig1_device_disparity", s)
    return s


if __name__ == "__main__":
    run()
