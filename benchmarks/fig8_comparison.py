"""Fig. 8: QLMIO vs. All-Cloud / Greedy / D3QN / SAC / QoS-Aware RL across
server counts (5/10/15 @ 30 users) and user counts (10/20/30 @ 15 servers)."""
import dataclasses

import numpy as np

import json
import os

from benchmarks.common import budget, emit, trained_predictors, world

from repro.core import baselines as B
from repro.core.d3qn import D3QNConfig
from repro.core.qlmio import QLMIO, QLMIOConfig
from repro.sim.cemllm import make_servers
from repro.sim.miobench import SERVER_CLASSES


def _train_eval(make, bench, servers, feats, tr, te, users, episodes,
                trials, seed=0):
    cfg = QLMIOConfig(episodes=episodes, users=users, seed=seed,
                      agent=D3QNConfig(
                          eps_decay_steps=max(episodes * users // 2, 500),
                          seed=seed))
    q = make(cfg)
    q.train(tr)
    return q.evaluate(te, users=users, trials=trials)


def _cached(tag):
    from benchmarks.common import RESULTS
    import os as _os
    p = _os.path.join(RESULTS, tag + '.json')
    if _os.environ.get('BENCH_REUSE', '1') != '0' and _os.path.exists(p):
        return json.load(open(p))
    return None


def run():
    results = _cached("fig8_comparison")
    print("fig8,servers,users,method,avg_reward,avg_latency_s,completion_rate")
    if results is None:
        b = budget()
        bench, feats, split_ids = world()
        tr, va, te = split_ids
        milp_preds, mgqp_preds, _, _ = trained_predictors(bench, feats,
                                                          split_ids)
        episodes, trials = b["episodes"], b["trials"]
        zeros = np.zeros((bench.tasks.n, len(SERVER_CLASSES)), np.float32)
        grid = ([(n, 30) for n in (5, 10, 15)] +
                [(15, u) for u in (10, 20)])  # (15,30) in the first block
        results = {}
        for n_servers, users in grid:
            servers = make_servers(n_servers, bench)
            methods = {
                "qlmio": lambda cfg: QLMIO(bench, servers, feats, milp_preds,
                                           mgqp_preds, cfg),
                "d3qn": lambda cfg: QLMIO(
                    bench, servers, feats, zeros, zeros,
                    dataclasses.replace(cfg, use_milp=False, use_mgqp=False,
                                        use_task_features=False)),
                "sac": lambda cfg: B.make_sac(bench, servers, feats, cfg),
                "qos_rl": lambda cfg: B.make_qos_rl(bench, servers, feats,
                                                    tr, cfg),
            }
            row = {}
            for name, make in methods.items():
                row[name] = _train_eval(make, bench, servers, feats, tr, te,
                                        users, episodes, trials)
            row.update(B.evaluate_heuristics(bench, servers, te, users,
                                             trials))
            results[f"{n_servers}s_{users}u"] = row
    for key, row in results.items():
        n_servers, users = key.replace("u", "").split("s_")
        for name, r in row.items():
            if name == "random":
                continue
            print(f"fig8,{n_servers},{users},{name},"
                  f"{r['avg_reward']:.3f},{r['avg_latency_s']:.2f},"
                  f"{r['completion_rate']:.3f}")

    # headline claims (paper Sec. V-F)
    for key, row in results.items():
        q = row["qlmio"]
        red_cloud = 1 - q["avg_latency_s"] / row["all_cloud"]["avg_latency_s"]
        red_greedy = 1 - q["avg_latency_s"] / row["greedy"]["avg_latency_s"]
        print(f"fig8,headline,{key},latency_reduction_vs_all_cloud,"
              f"{red_cloud:.3f},vs_greedy,{red_greedy:.3f},"
              f"completion_vs_cloud,"
              f"{q['completion_rate'] / max(row['all_cloud']['completion_rate'], 1e-9):.3f}")
    emit("fig8_comparison", results)
    return results


if __name__ == "__main__":
    run()
