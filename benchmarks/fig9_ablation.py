"""Fig. 9: ablation — QLMIO without MILP / without MGQP / without both."""


import json

from benchmarks.common import budget, emit, trained_predictors, world

from repro.core.d3qn import D3QNConfig
from repro.core.qlmio import QLMIO, QLMIOConfig
from repro.sim.cemllm import make_servers


def _cached(tag):
    from benchmarks.common import RESULTS
    import os as _os
    p = _os.path.join(RESULTS, tag + '.json')
    if _os.environ.get('BENCH_REUSE', '1') != '0' and _os.path.exists(p):
        return json.load(open(p))
    return None


def run(n_servers: int = 15, users: int = 30):
    b = budget()
    bench, feats, split_ids = world()
    tr, va, te = split_ids
    milp_preds, mgqp_preds, _, _ = trained_predictors(bench, feats, split_ids)
    servers = make_servers(n_servers, bench)
    episodes, trials = b["episodes"], b["trials"]

    variants = {
        "qlmio": {},
        "no_milp": dict(use_milp=False),
        "no_mgqp": dict(use_mgqp=False),
        "no_both": dict(use_milp=False, use_mgqp=False),
    }
    results = _cached("fig9_ablation") or {}
    print("fig9,variant,avg_reward,avg_latency_s,completion_rate")
    for name, kw in variants.items():
        if name not in results:
            cfg = QLMIOConfig(episodes=episodes, users=users, seed=0,
                              agent=D3QNConfig(
                                  eps_decay_steps=max(episodes * users // 2,
                                                      500)),
                              **kw)
            q = QLMIO(bench, servers, feats, milp_preds, mgqp_preds, cfg)
            q.train(tr)
            results[name] = q.evaluate(te, users=users, trials=trials)
        r = results[name]
        print(f"fig9,{name},{r['avg_reward']:.3f},"
              f"{r['avg_latency_s']:.2f},{r['completion_rate']:.3f}")
    full = results["qlmio"]
    for name in ("no_milp", "no_mgqp", "no_both"):
        red = 1 - full["avg_latency_s"] / results[name]["avg_latency_s"]
        dcomp = full["completion_rate"] - results[name]["completion_rate"]
        print(f"fig9,delta_vs_{name},latency_reduction,{red:.3f},"
              f"completion_gain,{dcomp:.3f}")
    emit("fig9_ablation", results)
    return results


if __name__ == "__main__":
    run()
