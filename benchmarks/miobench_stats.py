"""Table II / Sec. V-A: MIOBench dataset statistics."""
import numpy as np

from benchmarks.common import emit, world

from repro.data.taskgen import CATEGORIES
from repro.sim.miobench import SERVER_CLASSES


def run():
    bench, _, _ = world()
    n_cat = len(np.unique(bench.tasks.category))
    stats = {
        "n_tasks": int(bench.tasks.n),
        "n_server_classes": len(SERVER_CLASSES),
        "n_records": int(bench.n_records),
        "n_categories": int(n_cat),
        "score_values": sorted(int(v) for v in np.unique(bench.score)),
        "latency_ms_min": float(bench.latency_s.min() * 1e3),
        "latency_ms_max": float(bench.latency_s.max() * 1e3),
        "fields": ["dataset", "prompt", "device_type", "model_name", "score",
                   "latency_ms", "sample_id", "index", "source"],
    }
    rec = next(iter(bench.records()))
    assert set(rec) == set(stats["fields"])
    print("miobench,n_tasks,n_records,n_categories,score_values")
    print(f"miobench,{stats['n_tasks']},{stats['n_records']},"
          f"{stats['n_categories']},{stats['score_values']}")
    if bench.tasks.n == 3377:
        assert stats["n_records"] == 10131, "paper: 10,131 records"
    emit("miobench_stats", stats)
    return stats


if __name__ == "__main__":
    run()
