"""Benchmark aggregator: one section per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run              # smoke budget
  BENCH_BUDGET=fast  python -m benchmarks.run          # paper-shaped run
  BENCH_BUDGET=paper python -m benchmarks.run          # full-fidelity

Each section prints CSV lines (also written to results/*.json).
"""
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (fig1_device_disparity, fig5_milp, fig6_mgqp,
                            fig7_qlmio_convergence, fig8_comparison,
                            fig9_ablation, fig10_continuum_replay,
                            kernel_bench, miobench_stats, roofline)
    budget = os.environ.get("BENCH_BUDGET", "smoke")
    print(f"# benchmarks (budget={budget}) — sections: miobench, fig1, "
          f"fig5, fig6, fig7, fig8, fig9, fig10, kernels, roofline",
          flush=True)
    sections = [
        ("miobench_stats", miobench_stats.run),
        ("fig1", fig1_device_disparity.run),
        ("fig5", fig5_milp.run),
        ("fig6", fig6_mgqp.run),
        ("fig7", fig7_qlmio_convergence.run),
        ("fig8", fig8_comparison.run),
        ("fig9", fig9_ablation.run),
        ("fig10", fig10_continuum_replay.run),
        ("kernels", kernel_bench.run),
        ("roofline", roofline.run),
    ]
    failures = []
    for name, fn in sections:
        t0 = time.time()
        print(f"## section {name}", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"## section {name} done in {time.time()-t0:.0f}s", flush=True)
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
