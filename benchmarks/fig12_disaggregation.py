"""Fig. 12 (repo extension): disaggregated prefill/decode with live
cross-engine KV migration.

The tentpole question: once KV state is a portable object
(serving/kv_cache.KVSnapshot) and the continuum harness can charge a
page transfer on the virtual clock (Cluster.migrate), does phase-level
collaboration — prefill on the tier with compute, decode on the tier
with capacity, plus mid-stream evacuation when a tier saturates — beat
the static all-or-nothing dispatch the paper's policy uses?

Three policies over the same bursty MIOBench arrival trace, on a fleet
of live ``ServingEngine``s sharing one reduced arch + weight init (so
migrated requests resume bit-identically):

  * **all_cloud**      — every request to the cloud handle (the paper's
                         latency-insensitive upper quality bound);
  * **qlmio_static**   — QLMIO utility over per-server live predictions
                         (``EngineHandle.predict_e2e_s``), each request
                         pinned to one server for both phases;
  * **qlmio_migrate**  — the same dispatch utility, extended with the
                         third shape (prefill-here/decode-there via
                         ``Cluster.predict_disagg_e2e_s``) and a
                         clock-driven mid-stream evacuation sweep
                         (``Cluster.rebalance``) between arrivals.

Migration traffic is priced at the *destination's* KV precision (int8
edge tiers receive ~half the bytes) and shows up as ``kv_migrate``
spans in the exported trace (``--trace out.json``).

CI-smoke entry: ``python benchmarks/fig12_disaggregation.py --smoke``
finishes on CPU in well under a minute and asserts QLMIO-with-migration
beats QLMIO-static on mean e2e at an equal-or-better completion rate,
with at least one real migration executed.
"""
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit  # noqa: E402
from benchmarks.fig10_continuum_replay import analytic_predictors  # noqa: E402

from repro.serving.cluster import Cluster, build_continuum  # noqa: E402
from repro.serving.request import ContinuumRequest  # noqa: E402
from repro.serving.telemetry import Telemetry  # noqa: E402
from repro.sim import cost_model as cm  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate  # noqa: E402

# 1 cloud (fast ticks, thin WAN, 2 slots) + 2 LAN edge tiers; every
# handle runs the same reduced arch + shared weights so the fleet is
# KV-compatible and migration is token-preserving
SPEC = [(2, 1), (1, 1), (0, 1)]
ARCH = "qwen2-0.5b"

# arrivals come in bursts: ``burst`` requests land at the same instant,
# bursts ``burst_gap_s`` apart — the transient overload that makes
# mid-stream evacuation matter (a smooth trickle never queues the cloud)
BUDGETS = {
    "smoke": dict(n_tasks=200, users=40, burst=10, burst_gap_s=0.40,
                  decode_cap=12, prompt_cap=40),
    "fast": dict(n_tasks=800, users=96, burst=10, burst_gap_s=0.40,
                 decode_cap=12, prompt_cap=40),
    "paper": dict(n_tasks=3377, users=256, burst=12, burst_gap_s=0.35,
                  decode_cap=14, prompt_cap=48),
}

# quality weight of the QLMIO utility.  Deliberately quality-leaning:
# hard tasks keep routing to the cloud tier even as its backlog grows
# (the paper's generation-quality side of the tradeoff) — which is
# exactly the regime where decode migration pays, by recovering the
# latency side without giving up the cloud-tier prefill/quality.
W_QUALITY = 4.0

# evacuate from a handle once its backlog crosses this many virtual
# seconds, if a peer offers at least min_gain_s of predicted improvement
REBALANCE_THRESHOLD_S = 0.15
MIN_GAIN_S = 0.01


def run():
    budget = "smoke" if "--smoke" in sys.argv[1:] else \
        os.environ.get("BENCH_BUDGET", "smoke")
    trace_path = None
    argv = sys.argv[1:]
    if "--trace" in argv:
        trace_path = argv[argv.index("--trace") + 1]
    b = BUDGETS[budget]
    bench = generate(seed=0, n_tasks=b["n_tasks"])
    t_hat, b_hat = analytic_predictors(bench)
    rng = np.random.default_rng(0)
    tasks = [int(t) for t in rng.choice(bench.tasks.n, b["users"],
                                        replace=False)]

    t0 = time.time()
    tm = Telemetry(trace=trace_path is not None)
    # text-only payload on the base links; one shared weight init so a
    # migrated request's tokens match the stay-home run bit-for-bit
    handles = build_continuum(SPEC, telemetry=tm, arch=ARCH, param_seed=0,
                              payload_bytes=2 * cm.PAYLOAD_BYTES["text"])
    cluster = Cluster(handles)
    vocab = handles[0].cfg.vocab
    class_devices = [d for d, _ in SERVER_CLASSES]
    cls = np.array([class_devices.index(h.device.name) for h in handles])
    print(f"fig12,continuum,{len(handles)}_live_engines,"
          f"arch,{ARCH},build_s,{time.time() - t0:.1f}")

    def prompt(task: int) -> np.ndarray:
        L = int(np.clip(bench.tasks.text_len[task], 1, b["prompt_cap"]))
        r = np.random.default_rng(1_000_003 * (task + 1))
        return r.integers(0, vocab, L).astype(np.int32)

    def gen_budget(task: int, server: int) -> int:
        out = cm.expected_out_tokens(handles[server].profile,
                                     float(bench.tasks.difficulty[task]))
        return int(np.clip(round(out / 40.0), 4, b["decode_cap"]))

    def replay(policy: str):
        """policy: 'all_cloud' | 'qlmio_static' | 'qlmio_migrate'."""
        cluster.reset()
        n_disagg = n_moves = 0
        for k, task in enumerate(tasks):
            t = (k // b["burst"]) * b["burst_gap_s"]
            if policy == "qlmio_migrate":
                # the evacuation sweep runs with the clock (a backlog
                # spike peaks mid-gap, once a burst reaches decode), not
                # only at arrival instants
                while cluster.t < t - 1e-9:
                    cluster.advance_to(min(cluster.t + 0.1, t))
                    n_moves += len(cluster.rebalance(
                        REBALANCE_THRESHOLD_S, min_gain_s=MIN_GAIN_S))
            cluster.advance_to(t)
            toks = prompt(task)
            # shapes: (total_s, quality, submit_server, decode_server)
            shapes = []
            for s, h in enumerate(handles):
                tot, _ = h.predict_e2e_s(len(toks), gen_budget(task, s))
                shapes.append((tot, float(b_hat[task, cls[s]]), s, None))
            if policy == "qlmio_migrate":
                for sp, hp in enumerate(handles):
                    for sd in range(len(handles)):
                        if sd == sp or not hp.kv_compatible(handles[sd]):
                            continue
                        tot, _ = cluster.predict_disagg_e2e_s(
                            sp, sd, len(toks), gen_budget(task, sp))
                        # quality rides the shared weights: judged where
                        # the request is submitted (the prefill tier)
                        shapes.append((tot, float(b_hat[task, cls[sp]]),
                                       sp, sd))
            if policy == "all_cloud":
                best = shapes[0]
            else:
                norm = max(min(e[0] for e in shapes), 1e-6)
                best = max(shapes, key=lambda e: -e[0] / norm
                           + W_QUALITY * (3.0 * e[1] - 2.0))
            tot, _, s, decode_server = best
            n_disagg += decode_server is not None
            quality_ok = int(bench.score[task, int(cls[s])]) == 1
            budget_tok = gen_budget(task, s)
            predicted, terms = handles[s].predict_e2e_s(
                len(toks), budget_tok)
            uid = cluster.submit(ContinuumRequest(
                tokens=toks, max_new_tokens=budget_tok, arrival_s=t,
                task=task, quality_ok=quality_ok, server=s,
                decode_server=decode_server, predicted_s=float(predicted)))
            tm.record_dispatch(task=task, server=s, t=t,
                               predicted_s=predicted, uid=uid, terms=terms,
                               policy_est_s=float(tot))
            if policy == "qlmio_migrate":
                n_moves += len(cluster.rebalance(
                    REBALANCE_THRESHOLD_S, min_gain_s=MIN_GAIN_S))
        cluster.drain()
        recs = cluster.collect()
        e2e = [r["e2e_s"] for r in recs]
        mig_bytes = {h.name: int(h.engine.metrics.counter(
            "kv_migrate_in_bytes").value) for h in handles}
        return {"mean_e2e_s": float(np.mean(e2e)),
                "p95_e2e_s": float(np.percentile(e2e, 95)),
                "completion_rate": float(np.mean(
                    [r["success"] for r in recs])),
                "n_disagg_dispatches": int(n_disagg),
                "n_rebalance_moves": int(n_moves),
                "kv_migrate_in_bytes": mig_bytes}

    results = {}
    print("fig12,policy,mean_e2e_s,p95_e2e_s,completion_rate,"
          "disagg/rebalance")
    for name in ("all_cloud", "qlmio_static", "qlmio_migrate"):
        r = replay(name)
        results[name] = r
        print(f"fig12,{name},{r['mean_e2e_s']:.3f},{r['p95_e2e_s']:.3f},"
              f"{r['completion_rate']:.3f},"
              f"{r['n_disagg_dispatches']}/{r['n_rebalance_moves']}")
        if name == "qlmio_migrate" and trace_path is not None:
            tm.export(trace_path)
            n_spans = sum(e.get("name") == "kv_migrate"
                          for e in tm.tracer.events)
            print(f"fig12,trace,{trace_path},kv_migrate_spans,{n_spans}")

    st, mig = results["qlmio_static"], results["qlmio_migrate"]
    red = 1.0 - mig["mean_e2e_s"] / max(st["mean_e2e_s"], 1e-9)
    n_migrations = (mig["n_disagg_dispatches"] + mig["n_rebalance_moves"])
    print(f"fig12,headline,e2e_reduction_vs_static,{red:.3f},"
          f"n_migrations,{n_migrations},wall_s,{time.time() - t0:.1f}")
    emit("fig12_disaggregation", {"fig12": {
        "results": results,
        "e2e_reduction_vs_static": red,
        "n_migrations": n_migrations,
        "completion_migrate": mig["completion_rate"],
    }})
    # acceptance: migration-aware QLMIO is at least as good as static
    # QLMIO on mean e2e, at an equal-or-better completion rate, and the
    # improvement comes from real (charged, traced) migrations
    assert mig["mean_e2e_s"] <= st["mean_e2e_s"] * 1.001, \
        f"migrate {mig['mean_e2e_s']:.3f}s !<= static {st['mean_e2e_s']:.3f}s"
    assert mig["completion_rate"] >= st["completion_rate"]
    assert n_migrations > 0, "no migrations executed"
    assert sum(mig["kv_migrate_in_bytes"].values()) > 0
    return results


if __name__ == "__main__":
    run()
