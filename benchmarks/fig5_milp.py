"""Fig. 5: MILP training convergence (Huber loss + MAE, train/val)."""
from benchmarks.common import emit, trained_predictors, world


def run():
    bench, feats, split_ids = world()
    _, _, hist_milp, _ = trained_predictors(bench, feats, split_ids)
    print("fig5,epoch,train_loss,train_mae_s,val_mae_s")
    for h in hist_milp:
        print(f"fig5,{h['epoch']},{h['train_loss']:.4f},"
              f"{h['train_mae_s']:.3f},{h['val_mae_s']:.3f}")
    final = hist_milp[-1]
    print(f"fig5,final_val_mae_s,{final['val_mae_s']:.3f} "
          f"(paper: ~3.70 s)")
    emit("fig5_milp", {"history": hist_milp})
    return hist_milp


if __name__ == "__main__":
    run()
